//! The paper's motivating scenario (§I): multiple "hospital" nodes host
//! domain-specialized corpora (internal medicine / pediatrics / cardiology
//! stand-ins) and a flu-season surge concentrates queries on one domain.
//! CoEdge-RAG routes overflow to sub-optimal-but-capable nodes that share
//! overlapping knowledge, keeping latency bounded at a small quality cost.
//!
//!     cargo run --release --example healthcare_triage

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::workload::SkewPattern;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 60;
    cfg.docs_per_domain = 80;
    cfg.queries_per_slot = 600;
    cfg.slo_s = 10.0;
    cfg.allocator = AllocatorKind::Ppo;
    cfg.s_iid = 0.3; // overlapping knowledge (e.g. cold symptoms)
    cfg.overlap = 0.3;
    let mut co = CoordinatorBuilder::new(cfg).build()?;

    println!("phase 1 — normal operations (balanced case mix), 6 slots");
    co.cfg.skew = SkewPattern::Balanced;
    let normal = co.run(6)?;

    println!("phase 2 — flu season: 80% of queries hit domain 0, 6 slots");
    co.cfg.skew = SkewPattern::Primary { domain: 0, frac: 0.8 };
    let surge = co.run(6)?;

    let mut t = Table::new(&["phase", "R-L", "BERT", "drop%", "makespan(s)", "node load p_j"]);
    for (name, reports) in [("normal", &normal), ("flu surge", &surge)] {
        let n = reports.len() as f64;
        let rl: f64 = reports.iter().map(|r| r.mean_scores.rouge_l).sum::<f64>() / n;
        let bs: f64 = reports.iter().map(|r| r.mean_scores.bert_score).sum::<f64>() / n;
        let dr: f64 = reports.iter().map(|r| r.drop_rate).sum::<f64>() / n * 100.0;
        let mk: f64 = reports.iter().map(|r| r.latency_s).fold(0.0, f64::max);
        let last = reports.last().unwrap();
        t.row(vec![
            name.into(),
            format!("{rl:.3}"),
            format!("{bs:.3}"),
            format!("{dr:.2}"),
            format!("{mk:.2}"),
            last.proportions.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join("/"),
        ]);
    }
    t.print();
    println!("\nDuring the surge the router spreads domain-0 load across nodes");
    println!("with overlapping corpora instead of overloading its home node.");
    Ok(())
}
