//! Train → save → deploy: the full policy life-cycle in one sitting.
//!
//!     cargo run --release --example train_deploy
//!
//! Runs a tiny vectorized PPO farm over two committed scenario fixtures,
//! saves the trained policy as a versioned checkpoint, reloads it as a
//! frozen `ppo-pretrained` allocator through the registry (exactly what
//! `coedge run --allocator ppo-pretrained --checkpoint FILE` does), and
//! replays a fixture with learning off. The replay is byte-deterministic:
//! run this twice and the tables match to the last digit.

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{DatasetKind, ExperimentConfig, PPO_PRETRAINED_KEY};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::experiments::{eval_capacities, EvalProfile};
use coedge_rag::scenario::{load_fixtures, ScenarioRunner};
use coedge_rag::train::{TrainConfig, TrainFarm};

fn main() -> anyhow::Result<()> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios");
    let fixtures = load_fixtures(std::path::Path::new(dir))?;
    let curriculum: Vec<_> = fixtures
        .iter()
        .filter(|f| f.name == "burst_storm" || f.name == "node_churn")
        .cloned()
        .collect();

    // 1. train: 2 fixtures × 2 replicas, 2 epochs, one shared learner
    let tcfg = TrainConfig { replicas: 2, epochs: 2, ..TrainConfig::default() };
    let farm = TrainFarm::new(tcfg, curriculum)?;
    println!("training on {} cells per epoch...", farm.num_cells());
    let report = farm.run()?;

    let mut curve = Table::new(&["epoch", "transitions", "updates", "reward", "R-L", "drop%"]);
    for e in &report.curve {
        curve.row(vec![
            e.epoch.to_string(),
            e.transitions.to_string(),
            e.updates.to_string(),
            format!("{:.4}", e.mean_reward),
            format!("{:.3}", e.rouge_l),
            format!("{:.1}", e.drop_rate * 100.0),
        ]);
    }
    curve.print();

    // 2. save: versioned checkpoint (header pins dims + dataset)
    let ckpt = std::env::temp_dir().join("coedge-train-deploy.ckpt");
    report.save_checkpoint(&ckpt)?;
    println!("\nsaved policy -> {} ({} bytes)", ckpt.display(), std::fs::metadata(&ckpt)?.len());

    // 3. deploy: load as a frozen allocator via the registry override —
    //    the same path `--allocator ppo-pretrained --checkpoint FILE` takes
    let p = EvalProfile::smoke();
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = p.qa_per_domain;
    cfg.docs_per_domain = p.docs_per_domain;
    cfg.queries_per_slot = p.queries_per_slot;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = p.corpus_docs;
    }
    cfg.allocator_override = Some(PPO_PRETRAINED_KEY.to_string());
    cfg.checkpoint = Some(ckpt.clone());
    let caps = eval_capacities(&cfg);
    let mut co = CoordinatorBuilder::new(cfg).capacities(caps).build()?;
    println!("\nreplaying node_churn with frozen allocator {:?}...", PPO_PRETRAINED_KEY);

    let fixture = fixtures.iter().find(|f| f.name == "node_churn").expect("committed fixture");
    let run = ScenarioRunner::new(fixture.scenario.clone()).run(&mut co)?;

    let mut replay = Table::new(&["slot", "queries", "drop%", "R-L", "observed"]);
    for (t, r) in run.reports.iter().enumerate() {
        replay.row(vec![
            t.to_string(),
            r.queries.to_string(),
            format!("{:.1}", r.drop_rate * 100.0),
            format!("{:.3}", r.mean_scores.rouge_l),
            r.feedback.observed.to_string(),
        ]);
    }
    replay.print();
    println!(
        "\nobserved = 0 on every slot: the coordinator skips the feedback \
         phase for frozen allocators, so this replay is byte-stable."
    );
    std::fs::remove_file(&ckpt).ok();
    Ok(())
}
