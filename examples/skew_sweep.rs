//! Workload-skew adaptation demo (paper Fig. 5 in miniature).
//!
//! Trains the PPO identifier on a balanced workload, then sweeps the
//! primary-domain concentration from balanced to highly skewed and
//! compares capacity-aware inter-node scheduling (Algorithm 1) against
//! identification-only routing (the paper's "w/o inter-node" ablation).
//!
//!     cargo run --release --example skew_sweep

use coedge_rag::bench_harness::print_series;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};
use coedge_rag::workload::SkewPattern;

fn build(inter: bool) -> anyhow::Result<Coordinator> {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 50;
    cfg.docs_per_domain = 70;
    cfg.queries_per_slot = 1600;
    cfg.slo_s = 10.0;
    cfg.allocator = AllocatorKind::Ppo;
    cfg.inter_enabled = inter;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 140;
    }
    let mut co = CoordinatorBuilder::new(cfg).build()?;
    // warmup: let the identifier learn the corpus distribution
    co.cfg.skew = SkewPattern::Balanced;
    co.run(6)?;
    Ok(co)
}

fn main() -> anyhow::Result<()> {
    let fracs = [1.0 / 6.0, 0.3, 0.5, 0.7, 0.9];
    let mut rl = [Vec::new(), Vec::new()];
    let mut dr = [Vec::new(), Vec::new()];
    for (bi, inter) in [true, false].into_iter().enumerate() {
        let mut co = build(inter)?;
        for &f in &fracs {
            co.cfg.skew = if f <= 1.0 / 6.0 + 1e-9 {
                SkewPattern::Balanced
            } else {
                SkewPattern::Primary { domain: 3, frac: f }
            };
            let reports = co.run(3)?;
            rl[bi].push(reports.iter().map(|r| r.mean_scores.rouge_l).sum::<f64>() / 3.0);
            dr[bi].push(reports.iter().map(|r| r.drop_rate).sum::<f64>() / 3.0 * 100.0);
            eprintln!("inter={inter} frac={f:.2} done");
        }
    }
    print_series(
        "Rouge-L vs primary-domain concentration",
        "primary_frac",
        &fracs,
        &[("with inter-node", rl[0].clone()), ("w/o inter-node", rl[1].clone())],
    );
    print_series(
        "Drop rate (%) vs primary-domain concentration",
        "primary_frac",
        &fracs,
        &[("with inter-node", dr[0].clone()), ("w/o inter-node", dr[1].clone())],
    );
    Ok(())
}
