//! Chaos drill: replay a committed cluster-dynamics scenario against the
//! paper's 4-node testbed and watch the scheduler route around failures.
//!
//!     cargo run --release --example chaos_drill
//!
//! Loads `scenarios/node_churn.toml` (edge-a degrades, edge-c fails and
//! recovers), runs it through the scenario engine, and prints per-slot
//! events, the live-node mask, and routing proportions. The same replay —
//! pinned byte-for-byte — is what `tests/scenarios.rs` asserts against
//! its golden transcript.

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::scenario::{Scenario, ScenarioRunner};

fn main() -> anyhow::Result<()> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios/node_churn.toml");
    let sc = Scenario::from_toml(&std::fs::read_to_string(path)?)?;
    println!("scenario {:?}: {} events over {:?} slots", sc.name, sc.events.len(), sc.slots);

    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 40;
    cfg.docs_per_domain = 60;
    cfg.queries_per_slot = 200;
    cfg.allocator = AllocatorKind::Mab;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 120;
    }
    let mut co = CoordinatorBuilder::new(cfg).build()?;

    let runner = ScenarioRunner::new(sc);
    let run = runner.run(&mut co)?;

    let mut table = Table::new(&["slot", "queries", "events", "active", "p_j", "drop%", "R-L"]);
    for (t, r) in run.reports.iter().enumerate() {
        let events: Vec<String> =
            runner.scenario().events_at(t).map(|e| e.event.label()).collect();
        table.row(vec![
            t.to_string(),
            r.queries.to_string(),
            if events.is_empty() { "-".into() } else { events.join(" ") },
            r.active.iter().map(|&a| if a { '#' } else { '.' }).collect::<String>(),
            r.proportions.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join("/"),
            format!("{:.1}", r.drop_rate * 100.0),
            format!("{:.3}", r.mean_scores.rouge_l),
        ]);
    }
    table.print();

    // the invariant the whole tier enforces: zero queries on a down node
    let on_down: usize = run
        .reports
        .iter()
        .map(|r| {
            r.outcomes
                .iter()
                .filter(|o| o.node != usize::MAX && !r.active[o.node])
                .count()
        })
        .sum();
    println!("\nqueries routed to down nodes: {on_down} (must be 0)");
    println!(
        "transcript: {} slot records, byte-stable for seed {} — see tests/golden/",
        run.transcript.num_slots(),
        co.cfg.seed
    );
    Ok(())
}
