//! Cache warm-up demo: the same skewed query mix replayed slot after
//! slot, once without caching and once with LRU caches at both levels —
//! prints per-slot hit rates, drop rates and the shrinking
//! generation-memory cap as the retrieval caches fill.
//!
//!     cargo run --release --example cache_warmup

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, CacheSpec, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::workload::SkewPattern;

fn demo_cfg(cache: CacheSpec) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.seed = 23;
    cfg.qa_per_domain = 25;
    cfg.docs_per_domain = 50;
    cfg.queries_per_slot = 120;
    cfg.allocator = AllocatorKind::Mab;
    // a hot domain: most of the slot re-asks the same few dozen queries
    cfg.skew = SkewPattern::Primary { domain: 1, frac: 0.85 };
    cfg.cache = cache.clone();
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 80;
        n.cache = cache.clone();
    }
    cfg
}

fn main() {
    for kind in ["none", "lru"] {
        let cache = CacheSpec { capacity_mb: 16, ..CacheSpec::of_kind(kind) };
        let mut co = CoordinatorBuilder::new(demo_cfg(cache))
            .capacities(vec![CapacityModel { k: 10.0, b: 0.0 }; 4])
            .build()
            .expect("build coordinator");
        println!("\n== cache = {kind} ==");
        let mut table = Table::new(&[
            "slot", "queries", "hit%", "ans-hits", "ret-hits", "drop%", "R-L", "gen-mem-cap",
        ]);
        for t in 0..8 {
            let qids = co.sample_queries(co.cfg.queries_per_slot).expect("sample");
            let r = co.run_slot(&qids).expect("slot");
            let (hit_rate, ans, ret) = match &r.cache {
                Some(c) => (c.hit_rate() * 100.0, c.answer_hits, c.retrieval_hits),
                None => (0.0, 0, 0),
            };
            let min_cap =
                co.nodes.iter().map(|n| n.gen_mem_cap()).fold(1.0f64, f64::min);
            table.row(vec![
                format!("{t}"),
                format!("{}", r.queries),
                format!("{hit_rate:.1}"),
                format!("{ans}"),
                format!("{ret}"),
                format!("{:.1}", r.drop_rate * 100.0),
                format!("{:.3}", r.mean_scores.rouge_l),
                format!("{min_cap:.4}"),
            ]);
        }
        table.print();
    }
    println!("\nWith LRU on, repeats are answered at the coordinator (ans-hits),");
    println!("drops fall under the same load, and the generation-memory cap dips");
    println!("as cache bytes charge the node budget — the paper's latency-quality");
    println!("trade-off widened by a third, cache axis.");
}
