//! The three ROADMAP extension-point examples, compiled by CI so the
//! documented registry API can never silently drift:
//!
//! 1. a custom `Allocator` (`always-zero`) registered without touching
//!    coordinator code;
//! 2. a custom `VectorIndex` (`amnesia-index`, retrieves nothing)
//!    registered without touching cluster code;
//! 3. a custom `QueryCache` (`amnesia-cache`, forgets everything)
//!    registered without touching cache-tier code.
//!
//! Run: `cargo run --release --example custom_extensions`

use coedge_rag::bench_harness::Table;
use coedge_rag::cache::{CacheEntry, CacheSpec, QueryCache};
use coedge_rag::config::{DatasetKind, ExperimentConfig, IndexSpec};
use coedge_rag::coordinator::{Allocator, Assignment, CoordinatorBuilder, SlotContext};
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::vecdb::{Hit, VectorIndex};

/// 1. Custom allocator: every query goes to node 0 (ROADMAP example).
struct AlwaysZero;

impl Allocator for AlwaysZero {
    fn name(&self) -> &str {
        "always-zero"
    }
    fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
        Ok(Assignment::all_to(ctx.batch(), 0))
    }
}

/// 2. Custom index: retrieves nothing (ROADMAP example).
struct AmnesiaIndex;

impl VectorIndex for AmnesiaIndex {
    fn add(&mut self, _id: usize, _v: &[f32]) {}
    fn search(&self, _q: &[f32], _k: usize) -> Vec<Hit> {
        Vec::new()
    }
    fn len(&self) -> usize {
        0
    }
}

/// 3. Custom cache: forgets everything immediately (ROADMAP example).
struct AmnesiaCache;

impl QueryCache for AmnesiaCache {
    fn name(&self) -> &str {
        "amnesia-cache"
    }
    fn get(&mut self, _k: &[i8]) -> Option<CacheEntry> {
        None
    }
    fn insert(&mut self, _k: Vec<i8>, _e: CacheEntry) -> usize {
        0
    }
    fn clear(&mut self) -> usize {
        0
    }
    fn len(&self) -> usize {
        0
    }
    fn bytes(&self) -> usize {
        0
    }
    fn capacity_bytes(&self) -> usize {
        0
    }
}

fn main() -> coedge_rag::Result<()> {
    // a small cluster where every node runs the custom index + cache
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 10;
    cfg.docs_per_domain = 15;
    cfg.queries_per_slot = 24;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 20;
        n.index = IndexSpec::of_kind("amnesia-index");
        n.cache = CacheSpec::of_kind("amnesia-cache");
    }
    cfg.cache = CacheSpec::of_kind("amnesia-cache");

    let mut co = CoordinatorBuilder::new(cfg)
        .register_allocator("always-zero", |_| Ok(Box::new(AlwaysZero)))
        .register_index("amnesia-index", |_| Ok(Box::new(AmnesiaIndex)))
        .register_cache("amnesia-cache", |_| Ok(Box::new(AmnesiaCache)))
        .allocator_kind("always-zero")
        .capacities(vec![CapacityModel { k: 50.0, b: 0.0 }; 4]) // skip profiling
        .build()?;

    println!("custom allocator={:?}, node indexes/caches swapped via registries", co.allocator().name());
    let mut t = Table::new(&["slot", "queries", "to-node-0", "R-L", "drop%"]);
    for slot in 0..3 {
        let qids = co.sample_queries(co.cfg.queries_per_slot)?;
        let r = co.run_slot(&qids)?;
        t.row(vec![
            format!("{slot}"),
            format!("{}", r.queries),
            format!("{:.0}%", r.proportions[0] * 100.0),
            format!("{:.3}", r.mean_scores.rouge_l),
            format!("{:.1}", r.drop_rate * 100.0),
        ]);
        assert!(r.outcomes.iter().all(|o| o.dropped || o.node == 0), "always-zero must route to node 0");
        assert!(r.outcomes.iter().all(|o| o.rel == 0.0), "amnesia index retrieves nothing");
    }
    t.print();
    println!("all three registry extension points exercised — see ROADMAP ARCHITECTURE sections");
    Ok(())
}
