//! Quickstart: build the paper's 4-node edge cluster, run a few slots with
//! the full CoEdge-RAG pipeline (PPO identification → Algorithm-1 routing
//! → intra-node solver → RAG serving), and print quality/latency.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use coedge_rag::bench_harness::{PhaseBreakdown, Table};
use coedge_rag::config::{DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::policy::ppo::Backend;
use coedge_rag::runtime::PolicyRuntime;

fn main() -> anyhow::Result<()> {
    // Load the AOT artifacts if present (three-layer path); otherwise the
    // pure-Rust reference backend keeps the example runnable everywhere.
    let backend = match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => {
            println!("using PJRT backend ({} artifacts)", rt.manifest().artifacts.len());
            Backend::Pjrt(Arc::new(rt))
        }
        Err(_) => {
            println!("artifacts not found — using the pure-Rust reference backend");
            Backend::Reference
        }
    };

    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 60;
    cfg.docs_per_domain = 80;
    cfg.queries_per_slot = 400;
    cfg.slo_s = 15.0;
    let slots = 8;

    let phases = PhaseBreakdown::new();
    let mut co = CoordinatorBuilder::new(cfg)
        .backend(backend)
        .observer(Box::new(phases.clone()))
        .build()?;
    println!("\ncluster:");
    for (n, cap) in co.nodes.iter().zip(&co.capacities) {
        println!(
            "  {:<8} {} GPU(s), {} chunks, capacity ≈ {:.0} q @ 15s",
            n.name,
            n.gpus.len(),
            n.corpus_size(),
            cap.eval(15.0)
        );
    }

    let mut table = Table::new(&["slot", "R-L", "BERTScore", "drop%", "makespan(s)"]);
    for t in 0..slots {
        let qids = co.sample_queries(co.cfg.queries_per_slot).unwrap();
        let r = co.run_slot(&qids)?;
        table.row(vec![
            t.to_string(),
            format!("{:.3}", r.mean_scores.rouge_l),
            format!("{:.3}", r.mean_scores.bert_score),
            format!("{:.2}", r.drop_rate * 100.0),
            format!("{:.2}", r.latency_s),
        ]);
    }
    println!();
    table.print();
    println!();
    phases.print();
    println!("\nThe R-L/BERT columns should trend upward as the PPO identifier");
    println!("learns the corpus distribution across nodes (paper Fig. 4 loop).");
    Ok(())
}
