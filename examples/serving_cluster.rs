//! END-TO-END SERVING DRIVER (the repo's headline validation run).
//!
//! Boots the full three-layer stack: AOT policy artifacts through PJRT
//! (when built), the 4-node paper cluster, and the TCP serving front-end
//! with dynamic batching — then drives it with concurrent clients
//! replaying a skewed query trace, and reports wall-clock
//! latency/throughput plus generation quality. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example serving_cluster

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use coedge_rag::config::{DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::policy::ppo::Backend;
use coedge_rag::runtime::PolicyRuntime;
use coedge_rag::server::{serve, Client, ServerConfig};
use coedge_rag::util::stats::{mean, percentile};

const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 150;

fn main() -> anyhow::Result<()> {
    let backend = match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => {
            println!("backend: PJRT (AOT artifacts)");
            Backend::Pjrt(Arc::new(rt))
        }
        Err(_) => {
            println!("backend: pure-Rust reference (run `make artifacts` for the PJRT path)");
            Backend::Reference
        }
    };
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 80;
    cfg.docs_per_domain = 100;
    cfg.slo_s = 15.0;
    let n_qa = cfg.qa_per_domain * 6;
    let co = CoordinatorBuilder::new(cfg).backend(backend).build()?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let (addr_tx, addr_rx) = channel();
    let server = std::thread::spawn(move || {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        addr_tx.send(addr).unwrap();
        serve(
            co,
            ServerConfig {
                addr: addr.to_string(),
                batch_window_ms: 15,
                max_batch: 128,
                ..Default::default()
            },
            sd,
        )
        .unwrap();
    });
    let addr = addr_rx.recv()?.to_string();
    std::thread::sleep(std::time::Duration::from_millis(200));
    println!("server up at {addr}; {CLIENTS} clients × {REQS_PER_CLIENT} requests");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || -> anyhow::Result<(Vec<f64>, Vec<f64>, usize)> {
                let mut client = Client::connect(&addr)?;
                let mut lat = Vec::new();
                let mut rl = Vec::new();
                let mut dropped = 0usize;
                // skewed replay: client c favours domain c % 6
                for i in 0..REQS_PER_CLIENT {
                    let dom = if i % 10 < 7 { c % 6 } else { (c + i) % 6 };
                    let qa_id = (dom * (n_qa / 6) + (i * 13) % (n_qa / 6)) % n_qa;
                    let t = Instant::now();
                    let resp = client.request(i as u64, qa_id)?;
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    if resp.get("dropped").and_then(|v| v.as_bool()).unwrap_or(false) {
                        dropped += 1;
                    } else if let Some(r) = resp.get("rouge_l").and_then(|v| v.as_f64()) {
                        rl.push(r);
                    }
                }
                Ok((lat, rl, dropped))
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut all_rl = Vec::new();
    let mut all_drop = 0usize;
    for h in handles {
        let (lat, rl, dropped) = h.join().unwrap()?;
        all_lat.extend(lat);
        all_rl.extend(rl);
        all_drop += dropped;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * REQS_PER_CLIENT;

    println!("\n== end-to-end serving results ==");
    println!("requests          : {total}");
    println!("wall time         : {wall:.2} s");
    println!("throughput        : {:.1} req/s", total as f64 / wall);
    println!("latency mean      : {:.1} ms", mean(&all_lat));
    println!("latency p50 / p95 : {:.1} / {:.1} ms", percentile(&all_lat, 50.0), percentile(&all_lat, 95.0));
    println!("drop rate         : {:.2}%", all_drop as f64 / total as f64 * 100.0);
    println!("mean Rouge-L      : {:.3}", mean(&all_rl));

    shutdown.store(true, Ordering::Relaxed);
    server.join().unwrap();
    Ok(())
}
