//! End-to-end integration: the full coordinator loop on a small cluster.
//! Uses the Reference policy backend (no artifacts needed) so it runs in
//! any environment; the PJRT path is covered by runtime_bridge.rs.

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};

fn small_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 40;
    cfg.docs_per_domain = 60;
    cfg.queries_per_slot = 200;
    cfg.slots = 3;
    cfg.slo_s = 20.0;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 120;
    }
    cfg
}

#[test]
fn coordinator_runs_and_conserves_queries() {
    let mut co = CoordinatorBuilder::new(small_cfg(AllocatorKind::Ppo)).build().unwrap();
    let reports = co.run(3).unwrap();
    assert_eq!(reports.len(), 3);
    for r in &reports {
        assert_eq!(r.queries, 200);
        assert_eq!(r.outcomes.len(), 200);
        let psum: f64 = r.proportions.iter().sum();
        assert!((psum - 1.0).abs() < 1e-9, "proportions {:?}", r.proportions);
        assert!(r.drop_rate >= 0.0 && r.drop_rate <= 1.0);
        assert!(r.mean_scores.rouge_l >= 0.0 && r.mean_scores.rouge_l <= 1.0);
        // generous SLO: low drops
        assert!(r.drop_rate < 0.2, "drop_rate={}", r.drop_rate);
    }
}

#[test]
fn oracle_beats_random_quality() {
    let mut co_o =
        CoordinatorBuilder::new(small_cfg(AllocatorKind::Oracle)).build().unwrap();
    let mut co_r =
        CoordinatorBuilder::new(small_cfg(AllocatorKind::Random)).build().unwrap();
    let ro = co_o.run(3).unwrap();
    let rr = co_r.run(3).unwrap();
    let qo = Coordinator::tail_mean(&ro, 3);
    let qr = Coordinator::tail_mean(&rr, 3);
    assert!(
        qo.rouge_l > qr.rouge_l + 0.03,
        "oracle R-L {} vs random {}",
        qo.rouge_l,
        qr.rouge_l
    );
    assert!(qo.bert_score > qr.bert_score, "bert {} vs {}", qo.bert_score, qr.bert_score);
}

#[test]
fn ppo_improves_over_time_and_beats_random() {
    let mut cfg = small_cfg(AllocatorKind::Ppo);
    cfg.slots = 14;
    cfg.ppo_buffer = 128;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let reports = co.run(14).unwrap();
    let early: f64 = reports[..3].iter().map(|r| r.mean_scores.rouge_l).sum::<f64>() / 3.0;
    let late: f64 =
        reports[reports.len() - 3..].iter().map(|r| r.mean_scores.rouge_l).sum::<f64>() / 3.0;
    assert!(
        late > early - 0.02,
        "PPO should not regress: early={early:.3} late={late:.3}"
    );
    // against a fresh random allocator over the same horizon
    let mut co_r =
        CoordinatorBuilder::new(small_cfg(AllocatorKind::Random)).build().unwrap();
    let rr = co_r.run(6).unwrap();
    let qr = Coordinator::tail_mean(&rr, 3).rouge_l;
    assert!(late > qr, "ppo late {late:.3} vs random {qr:.3}");
}

#[test]
fn approximate_index_cluster_serves_with_sane_quality() {
    use coedge_rag::config::IndexSpec;
    // heterogeneous retrieval tier: hnsw + ivf nodes next to flat ones
    let mut cfg = small_cfg(AllocatorKind::Oracle);
    cfg.nodes[0].index = IndexSpec::of_kind("hnsw");
    cfg.nodes[1].index = IndexSpec::of_kind("ivf");
    cfg.nodes[1].index.nlist = 16;
    cfg.nodes[1].index.nprobe = 8;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let reports = co.run(2).unwrap();
    for r in &reports {
        assert_eq!(r.outcomes.len(), 200);
        assert!(r.drop_rate < 0.2, "drop_rate={}", r.drop_rate);
        // approximate retrieval still finds most gold docs under Oracle routing
        let mean_rel: f64 =
            r.outcomes.iter().map(|o| o.rel).sum::<f64>() / r.outcomes.len() as f64;
        assert!(mean_rel > 0.5, "mean_rel={mean_rel}");
    }
}

#[test]
fn tight_slo_increases_drops() {
    let mut cfg = small_cfg(AllocatorKind::Oracle);
    cfg.queries_per_slot = 600;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    co.set_slo(20.0);
    let relaxed = co.run(2).unwrap();
    co.set_slo(1.0);
    let strict = co.run(2).unwrap();
    let d_rel: f64 = relaxed.iter().map(|r| r.drop_rate).sum::<f64>() / 2.0;
    let d_str: f64 = strict.iter().map(|r| r.drop_rate).sum::<f64>() / 2.0;
    assert!(d_str > d_rel, "strict {d_str} vs relaxed {d_rel}");
}
