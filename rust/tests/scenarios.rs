//! Golden-trace replay harness for the scenario engine.
//!
//! Every committed scenario fixture (`scenarios/*.toml`) is replayed
//! twice from independently built coordinators and the two transcripts
//! must be byte-identical — catching any nondeterminism in the parallel
//! serve path, the sharded-index merge, or the schedulers. The transcript
//! is then compared byte-for-byte against the committed golden file in
//! `tests/golden/`; drift is a failure.
//!
//! Regenerating goldens intentionally (after a deliberate behavior
//! change):
//!
//!     UPDATE_GOLDEN=1 cargo test --test scenarios
//!
//! A missing golden file is blessed on first run (this is how the
//! fixtures bootstrap on a machine with a toolchain); CI then fails on
//! any uncommitted drift via `git diff --exit-code -- tests/golden`.

use std::path::{Path, PathBuf};

use coedge_rag::config::{AllocatorKind, CacheSpec, DatasetKind, ExperimentConfig, IndexSpec};
use coedge_rag::coordinator::{CoordinatorBuilder, PipelineConfig};
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::scenario::{Scenario, ScenarioRun, ScenarioRunner};
use coedge_rag::vecdb::{FlatIndex, ShardedIndex};

/// The fixed harness cluster every fixture replays against: the paper's
/// 4-node testbed shrunk for test speed, with stubbed capacity models so
/// profiling noise can't leak into goldens.
fn harness_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.queries_per_slot = 60;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

fn stub_caps() -> Vec<CapacityModel> {
    // 6 q per SLO-second per node: 360 total at the 15 s default — the
    // fixtures' 240/300-query bursts genuinely overload the cluster
    vec![CapacityModel { k: 6.0, b: 0.0 }; 4]
}

fn scenario_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios").join(format!("{name}.toml"))
}

fn golden_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(format!("{name}.jsonl"))
}

fn load_scenario(name: &str) -> Scenario {
    let text = std::fs::read_to_string(scenario_path(name)).expect("read scenario fixture");
    Scenario::from_toml(&text).expect("parse scenario fixture")
}

fn run_fixture_cfg(name: &str, cfg: ExperimentConfig) -> ScenarioRun {
    let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps()).build().unwrap();
    ScenarioRunner::new(load_scenario(name)).run(&mut co).expect("scenario run")
}

fn run_fixture(name: &str, allocator: AllocatorKind) -> ScenarioRun {
    run_fixture_cfg(name, harness_cfg(allocator))
}

/// Byte-compare two transcripts, reporting the first differing line.
fn assert_same_transcript(name: &str, got: &str, want: &str, what: &str) {
    if got == want {
        return;
    }
    for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
        assert_eq!(
            g, w,
            "{name}: {what} differs first at line {i}\n  \
             (intentional change? regenerate: UPDATE_GOLDEN=1 cargo test --test scenarios)"
        );
    }
    panic!(
        "{name}: {what} differs in line count ({} vs {})",
        got.lines().count(),
        want.lines().count()
    );
}

/// Replay fixture `name` twice from `cfg` (independent coordinators, same
/// seed) asserting byte-identical transcripts, then compare against — or
/// bless — the committed golden file `golden_name`.
fn replay_golden_cfg(name: &str, golden_name: &str, cfg: &ExperimentConfig) -> ScenarioRun {
    let run = run_fixture_cfg(name, cfg.clone());
    let rerun = run_fixture_cfg(name, cfg.clone());
    let got = run.transcript.to_jsonl();
    assert_same_transcript(name, &got, &rerun.transcript.to_jsonl(), "replay (run-to-run)");

    let gp = golden_path(golden_name);
    let bless = std::env::var("UPDATE_GOLDEN").is_ok();
    if gp.exists() && !bless {
        let golden = std::fs::read_to_string(&gp).expect("read golden");
        assert_same_transcript(name, &got, &golden, "committed golden");
    } else {
        run.transcript.write_to(&gp).expect("bless golden");
        eprintln!(
            "[golden] blessed {} ({} slot records)",
            gp.display(),
            run.transcript.num_slots()
        );
    }
    run
}

/// Replay `name` twice (independent coordinators, same seed) asserting
/// byte-identical transcripts, then compare against — or bless — the
/// committed golden file.
fn replay_golden(name: &str, allocator: AllocatorKind) -> ScenarioRun {
    replay_golden_cfg(name, name, &harness_cfg(allocator))
}

#[test]
fn burst_storm_replays_byte_identical() {
    let run = replay_golden("burst_storm", AllocatorKind::Mab);
    assert_eq!(run.reports.len(), 8);
    // BurstOverride events replace the trace load exactly
    assert_eq!(run.reports[2].queries, 240);
    assert_eq!(run.reports[5].queries, 300);
    // the arrival trace actually fluctuates (Coordinator::run never did)
    let loads: Vec<usize> = run.reports.iter().map(|r| r.queries).collect();
    assert!(loads.iter().any(|&q| q != loads[0]), "static loads: {loads:?}");
    // the SLO change lands on its slot and sticks
    assert_eq!(run.reports[4].slo_s, 15.0);
    assert_eq!(run.reports[5].slo_s, 8.0);
    assert_eq!(run.reports[7].slo_s, 8.0);
    // overloaded slots shed load but never lose queries
    for r in &run.reports {
        assert_eq!(r.outcomes.len(), r.queries);
    }
}

#[test]
fn node_churn_replays_and_routes_around_the_down_node() {
    let run = replay_golden("node_churn", AllocatorKind::Oracle);
    // slots 2..5: node 2 is down — zero queries routed to it, ever
    for t in 2..5 {
        let r = &run.reports[t];
        assert!(!r.active[2], "slot {t}");
        assert_eq!(r.proportions[2], 0.0, "slot {t}: {:?}", r.proportions);
        assert!(
            r.outcomes.iter().all(|o| o.node != 2),
            "slot {t}: a query was routed to the down node"
        );
        let psum: f64 = r.proportions.iter().sum();
        assert!((psum - 1.0).abs() < 1e-9, "slot {t}: {psum}");
    }
    // before and after the outage the node participates again
    assert!(run.reports[1].active[2]);
    assert!(run.reports[5].active[2]);
    assert!(
        run.reports[5..].iter().any(|r| r.proportions[2] > 0.0),
        "node 2 never recovered: {:?}",
        run.reports.iter().map(|r| r.proportions[2]).collect::<Vec<_>>()
    );
}

#[test]
fn corpus_drift_replays_with_live_ingest() {
    let run = replay_golden("corpus_drift", AllocatorKind::Domain);
    assert_eq!(run.reports.len(), 8);
    let text = run.transcript.to_jsonl();
    assert!(text.contains("skew-shift(primary:d1@0.8)"), "{text}");
    assert!(text.contains("corpus-ingest(0,20@d1)"), "{text}");
    assert!(text.contains("corpus-ingest(3,20@d1)"), "{text}");
    for r in &run.reports {
        assert_eq!(r.outcomes.len(), r.queries);
    }
}

/// Live reindex migration fixture: node 0 migrates flat →
/// quantized-flat while serving, with a mid-migration ingest and a
/// post-swap skew shift. The golden transcript pins the modeled swap
/// boundary byte-for-byte: the 69-row corpus (60 docs × 1.15 overlap)
/// is a 2-slot quantized build, so slots 2–3 serve the old index with a
/// counting-down migration label and slot 4 is the first slot the
/// target kind serves.
#[test]
fn reindex_drift_replays_byte_identical_with_visible_swap() {
    let run = replay_golden("reindex_drift", AllocatorKind::Domain);
    assert_eq!(run.reports.len(), 8);
    let text = run.transcript.to_jsonl();
    assert!(text.contains("reindex(0,quantized-flat)"), "{text}");
    assert!(text.contains("corpus-ingest(0,20@d1)"), "{text}");
    // migration columns appear only once the reindex has fired —
    // the slots before it keep the reindex-free record format
    for t in 0..2 {
        assert!(run.reports[t].index_kinds.is_none(), "slot {t}: premature index_kinds");
        assert!(run.reports[t].migrations.is_none(), "slot {t}: premature migrations");
    }
    let kind = |t: usize, n: usize| run.reports[t].index_kinds.as_ref().unwrap()[n].as_str();
    let mig = |t: usize, n: usize| run.reports[t].migrations.as_ref().unwrap()[n].as_str();
    // slots 2–3: old index serves, countdown is visible in the golden
    assert_eq!(kind(2, 0), "flat");
    assert_eq!(mig(2, 0), "flat->quantized-flat:2");
    assert_eq!(kind(3, 0), "flat");
    assert_eq!(mig(3, 0), "flat->quantized-flat:1");
    // slot 4: the atomic swap — target kind serves from here on
    for t in 4..8 {
        assert_eq!(kind(t, 0), "quantized-flat", "slot {t}");
        assert_eq!(mig(t, 0), "-", "slot {t}");
    }
    // the other nodes never migrate
    for t in 2..8 {
        for n in 1..4 {
            assert_eq!(kind(t, n), "flat", "slot {t} node {n}");
            assert_eq!(mig(t, n), "-", "slot {t} node {n}");
        }
    }
    // no query is ever lost across the migration
    for r in &run.reports {
        assert_eq!(r.outcomes.len(), r.queries);
    }
}

/// PR 2 claimed the sharded fan-out merge is ordering-deterministic; pin
/// it: the same seed + scenario under parallel shard fan-out vs a
/// single-threaded fan-out must produce byte-identical transcripts. The
/// corpus is sized so the batched searches clear the parallel-path work
/// threshold (vectors × queries ≥ 2^15).
#[test]
fn transcripts_stable_across_shard_fanout_thread_counts() {
    let sc = load_scenario("burst_storm");
    let run = |single_threaded: bool| {
        let mut cfg = harness_cfg(AllocatorKind::Oracle);
        cfg.docs_per_domain = 60;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = 300;
            n.index = IndexSpec::of_kind(if single_threaded {
                "sharded-flat-st"
            } else {
                "sharded-flat"
            });
        }
        let mut builder = CoordinatorBuilder::new(cfg).capacities(stub_caps());
        if single_threaded {
            builder = builder.register_index("sharded-flat-st", |ctx| {
                let dim = ctx.dim;
                Ok(Box::new(
                    ShardedIndex::from_fn(ctx.spec.shards, |_| FlatIndex::new(dim))
                        .with_threads(1),
                ))
            });
        }
        let mut co = builder.build().unwrap();
        ScenarioRunner::new(sc.clone()).run(&mut co).unwrap().transcript.to_jsonl()
    };
    let parallel = run(false);
    let single = run(true);
    assert_same_transcript("burst_storm[sharded]", &parallel, &single, "threads=N vs threads=1");
}

/// Cache-off parity: with `[cache] kind = "none"` (the default), the
/// cache tier must be invisible — every committed fixture replays
/// byte-identical whether the spec is the implicit default or an
/// explicitly spelled-out `none` cache, and no report carries cache
/// stats. Together with the golden comparison in the replay tests above,
/// this pins "adding the cache tier changed nothing by default".
#[test]
fn cache_off_fixtures_are_byte_identical_to_default() {
    for (name, allocator) in [
        ("burst_storm", AllocatorKind::Mab),
        ("node_churn", AllocatorKind::Oracle),
        ("corpus_drift", AllocatorKind::Domain),
    ] {
        let default_run = run_fixture(name, allocator);
        let mut cfg = harness_cfg(allocator);
        cfg.cache = CacheSpec { kind: "none".into(), capacity_mb: 999, ..CacheSpec::default() };
        for n in cfg.nodes.iter_mut() {
            n.cache = CacheSpec::of_kind("none");
        }
        let explicit_run = run_fixture_cfg(name, cfg);
        assert_same_transcript(
            name,
            &explicit_run.transcript.to_jsonl(),
            &default_run.transcript.to_jsonl(),
            "explicit none-cache vs default",
        );
        for r in &default_run.reports {
            assert!(r.cache.is_none(), "{name}: default run grew cache stats");
        }
        assert!(
            !default_run.transcript.to_jsonl().contains("cache"),
            "{name}: cache fields leaked into a cache-off transcript"
        );
    }
}

/// The repeated-query fixture under LRU caches: nonzero hit rates on both
/// cache levels, invalidation on the mid-run corpus ingest, and — at
/// `threshold = 1.0` — every cache-served answer carries scores bitwise
/// equal to the answer originally generated for that query.
#[test]
fn repeat_storm_replays_with_lru_hits() {
    let mut cfg = harness_cfg(AllocatorKind::Mab);
    cfg.cache = CacheSpec { kind: "lru".into(), capacity_mb: 8, ..CacheSpec::default() };
    for n in cfg.nodes.iter_mut() {
        n.cache = cfg.cache.clone();
    }
    let run = replay_golden_cfg("repeat_storm", "repeat_storm_lru", &cfg);
    assert_eq!(run.reports.len(), 8);

    // NOTE: answer-cache hits never reach a node, so under a healthy
    // answer cache the per-node retrieval hits can legitimately be rare —
    // retrieval-level hit coverage lives in tests/cache_api.rs with the
    // answer cache disabled.
    let mut total = coedge_rag::cache::CacheSlotStats::default();
    let mut last_written: std::collections::HashMap<usize, coedge_rag::metrics::QualityScores> =
        std::collections::HashMap::new();
    for r in &run.reports {
        let c = r.cache.expect("cache stats must be reported when LRU is on");
        total.retrieval_hits += c.retrieval_hits;
        total.answer_hits += c.answer_hits;
        total.invalidations += c.invalidations;
        assert_eq!(r.outcomes.len(), r.queries, "no query lost to the cache tier");
        for o in &r.outcomes {
            if o.cached {
                let want = last_written.get(&o.qa_id).expect("hit before any serve");
                assert_eq!(
                    o.scores, *want,
                    "qa {}: cached quality must be bitwise equal to the stored serve",
                    o.qa_id
                );
                assert!(!o.dropped);
            } else if !o.dropped {
                last_written.insert(o.qa_id, o.scores);
            }
        }
    }
    assert!(total.answer_hits > 0, "repeat storm must hit the answer cache");
    assert!(
        total.invalidations > 0,
        "the slot-5 corpus ingest must invalidate warmed entries"
    );
    let text = run.transcript.to_jsonl();
    assert!(text.contains("\"cache_hits\":"), "{text}");
    // at least one slot records a nonzero combined hit count
    assert!(
        run.reports.iter().any(|r| r.cache.unwrap().hits() > 0),
        "golden must record nonzero hit rates"
    );
}

/// Fuzz-minimized regression fixture: boundary skew fractions
/// (`frac = 1.0` concentrates the whole mix on one domain, `frac = 0.0`
/// excludes it). Lives under `scenarios/fuzz/` so the training
/// curriculum and eval grids (which load the parent directory,
/// non-recursively) never pick it up.
#[test]
fn fuzz_boundary_frac_replays_byte_identical() {
    let run = replay_golden_cfg(
        "fuzz/boundary_frac",
        "fuzz_boundary_frac",
        &harness_cfg(AllocatorKind::Mab),
    );
    assert_eq!(run.reports.len(), 6);
    let text = run.transcript.to_jsonl();
    assert!(text.contains("skew-shift(primary:d2@1)"), "{text}");
    assert!(text.contains("skew-shift(primary:d2@0)"), "{text}");
    assert!(text.contains("skew-shift(balanced)"), "{text}");
    for (t, r) in run.reports.iter().enumerate() {
        assert_eq!(r.outcomes.len(), r.queries, "slot {t}");
        assert!(r.drop_rate.is_finite() && r.mean_scores.rouge_l.is_finite(), "slot {t}");
    }
}

/// Fuzz-minimized regression fixture: the empty live slot. Zero-query
/// bursts must leave `run_slot(&[])` finite — all-zero proportions, no
/// outcomes, no NaN from a division by the query count.
#[test]
fn fuzz_zero_burst_replays_byte_identical() {
    let run = replay_golden_cfg(
        "fuzz/zero_burst",
        "fuzz_zero_burst",
        &harness_cfg(AllocatorKind::Oracle),
    );
    assert_eq!(run.reports.len(), 5);
    for t in [2, 3] {
        let r = &run.reports[t];
        assert_eq!(r.queries, 0, "slot {t}: burst override must zero the load");
        assert!(r.outcomes.is_empty(), "slot {t}");
        assert_eq!(r.proportions.iter().sum::<f64>(), 0.0, "slot {t}: {:?}", r.proportions);
        assert!(r.drop_rate.is_finite(), "slot {t}: drop_rate={}", r.drop_rate);
        assert!(r.latency_s.is_finite(), "slot {t}: latency={}", r.latency_s);
        assert!(r.mean_scores.rouge_l.is_finite(), "slot {t}");
    }
    // the non-empty slots around the gap still serve
    assert!(run.reports[0].queries > 0);
    assert!(run.reports[4].queries > 0);
    assert!(run.transcript.to_jsonl().contains("capacity-scale(1,x0.25)"));
}

/// The pipelined executor must be invisible in every committed byte: all
/// golden fixtures — timeline events, arrival traces, bursts, skew
/// shifts, node churn, live ingest, LRU caches, empty slots — replay
/// through `ScenarioRunner::run_pipelined` with transcripts identical to
/// the synchronous path, at encode_threads 1 and 4 (prefetch alone, and
/// prefetch + parallel embedding). This is the ADR-001 gate for the
/// serving engine's encode/serve overlap.
#[test]
fn fixtures_replay_byte_identical_under_pipelined_executor() {
    let lru_cfg = || {
        let mut cfg = harness_cfg(AllocatorKind::Mab);
        cfg.cache = CacheSpec { kind: "lru".into(), capacity_mb: 8, ..CacheSpec::default() };
        for n in cfg.nodes.iter_mut() {
            n.cache = cfg.cache.clone();
        }
        cfg
    };
    let fixtures: Vec<(&str, ExperimentConfig)> = vec![
        ("burst_storm", harness_cfg(AllocatorKind::Mab)),
        ("node_churn", harness_cfg(AllocatorKind::Oracle)),
        ("corpus_drift", harness_cfg(AllocatorKind::Domain)),
        // reindex_drift pins the migration tick under the pipelined
        // executor: the atomic swap must land on the same modeled slot
        // boundary (and the write-log drain in the same order) whether
        // slots are encoded ahead or synchronously
        ("reindex_drift", harness_cfg(AllocatorKind::Domain)),
        ("repeat_storm", lru_cfg()),
        // fuzz/boundary_frac pins the pre-sampling skew walk: its
        // skew-shift events must steer sampling exactly as apply_event
        // would, without perturbing the cache-invalidation counters
        ("fuzz/boundary_frac", harness_cfg(AllocatorKind::Mab)),
        ("fuzz/zero_burst", harness_cfg(AllocatorKind::Oracle)),
    ];
    for (name, cfg) in fixtures {
        let sync = run_fixture_cfg(name, cfg.clone()).transcript.to_jsonl();
        for encode_threads in [1, 4] {
            let mut co =
                CoordinatorBuilder::new(cfg.clone()).capacities(stub_caps()).build().unwrap();
            let pcfg = PipelineConfig { depth: 2, encode_threads };
            let run = ScenarioRunner::new(load_scenario(name))
                .run_pipelined(&mut co, &pcfg)
                .expect("pipelined scenario run");
            assert_same_transcript(
                name,
                &run.transcript.to_jsonl(),
                &sync,
                &format!("pipelined (encode_threads={encode_threads}) vs synchronous"),
            );
        }
    }
}

/// Scenario files with out-of-range targets fail fast with clear errors —
/// before any slot runs.
#[test]
fn invalid_scenarios_fail_before_running() {
    let mut co = CoordinatorBuilder::new(harness_cfg(AllocatorKind::Random))
        .capacities(stub_caps())
        .build()
        .unwrap();
    let sc = Scenario::from_toml(
        "[[scenario.events]]\nslot = 0\nkind = \"node-down\"\nnode = 9\n",
    )
    .unwrap();
    let err = ScenarioRunner::new(sc).run(&mut co).unwrap_err().to_string();
    assert!(err.contains("node 9") && err.contains("4 nodes"), "{err}");

    let sc = Scenario::from_toml(
        "[[scenario.events]]\nslot = 1\nkind = \"corpus-ingest\"\nnode = 0\ndocs = 5\ndomain = 11\n",
    )
    .unwrap();
    let err = ScenarioRunner::new(sc).run(&mut co).unwrap_err().to_string();
    assert!(err.contains("domain 11"), "{err}");

    // an event scheduled beyond the run's slot count would silently never
    // fire — it must be rejected up front
    let sc = Scenario::from_toml(
        "[scenario]\nslots = 4\n\n[[scenario.events]]\nslot = 50\nkind = \"node-down\"\nnode = 0\n",
    )
    .unwrap();
    let err = ScenarioRunner::new(sc).run(&mut co).unwrap_err().to_string();
    assert!(err.contains("slot 50") && err.contains("4 slots"), "{err}");
}
