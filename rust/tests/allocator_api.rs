//! Contract tests for the unified `Allocator` trait, the registry, and
//! the `CoordinatorBuilder` pipeline: call order, stage injection,
//! inter-node edge cases, and custom-allocator registration.

use std::sync::{Arc, Mutex};

use coedge_rag::cluster::node::QueryOutcome;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::allocator::{
    Allocator, Assignment, FeedbackStats, SlotContext,
};
use coedge_rag::coordinator::observer::{FnObserver, SlotEvent};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::router::capacity::CapacityModel;

/// Small cluster config; pair with `stub_caps` to skip capacity profiling.
fn tiny_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 10;
    cfg.docs_per_domain = 15;
    cfg.queries_per_slot = 24;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 20;
    }
    cfg
}

fn stub_caps(n: usize) -> Vec<CapacityModel> {
    vec![CapacityModel { k: 50.0, b: 0.0 }; n]
}

/// Records every trait call; routes round-robin.
struct MockAllocator {
    calls: Arc<Mutex<Vec<String>>>,
}

impl Allocator for MockAllocator {
    fn name(&self) -> &str {
        "mock"
    }

    fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
        assert_eq!(ctx.embs.len(), ctx.batch(), "one embedding per query");
        self.calls.lock().unwrap().push(format!("assign:{}", ctx.batch()));
        let n = ctx.n_nodes();
        Ok(Assignment::from_nodes((0..ctx.batch()).map(|i| i % n).collect()))
    }

    fn observe(
        &mut self,
        ctx: &SlotContext,
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> coedge_rag::Result<FeedbackStats> {
        assert_eq!(assignment.node_of.len(), outcomes.len());
        assert_eq!(ctx.batch(), outcomes.len());
        self.calls.lock().unwrap().push(format!("observe:{}", outcomes.len()));
        Ok(FeedbackStats { observed: outcomes.len(), updates: 0 })
    }
}

#[test]
fn mock_allocator_sees_assign_then_observe_once_per_slot() {
    let calls: Arc<Mutex<Vec<String>>> = Arc::default();
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .allocator(Box::new(MockAllocator { calls: Arc::clone(&calls) }))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    for _ in 0..3 {
        let qids = co.sample_queries(8).unwrap();
        let r = co.run_slot(&qids).unwrap();
        assert_eq!(r.queries, 8);
        assert_eq!(r.feedback.observed, 8);
    }
    let log = calls.lock().unwrap().clone();
    assert_eq!(
        log,
        vec!["assign:8", "observe:8", "assign:8", "observe:8", "assign:8", "observe:8"],
        "exactly one assign then one observe per slot"
    );
    assert_eq!(co.allocator().name(), "mock");
}

#[test]
fn slot_events_fire_in_phase_order_with_probs_for_ppo() {
    let seen: Arc<Mutex<Vec<String>>> = Arc::default();
    let handle = Arc::clone(&seen);
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo))
        .capacities(stub_caps(4))
        .observer(Box::new(FnObserver(move |ev: &SlotEvent| {
            let tag = match ev {
                SlotEvent::Encoded { .. } => "encoded".into(),
                SlotEvent::Routed { assignment, .. } => {
                    format!("routed(probs={})", !assignment.probs.is_empty())
                }
                SlotEvent::Served { .. } => "served".into(),
                SlotEvent::Feedback { .. } => "feedback".into(),
                SlotEvent::SlotEnd { .. } => "end".into(),
            };
            handle.lock().unwrap().push(tag);
        })))
        .build()
        .unwrap();
    let qids = co.sample_queries(12).unwrap();
    co.run_slot(&qids).unwrap();
    assert_eq!(
        seen.lock().unwrap().clone(),
        vec!["encoded", "routed(probs=true)", "served", "feedback", "end"],
        "the four phases + SlotEnd, with s_i^t surfaced to observers"
    );
}

#[test]
fn all_capacities_zero_still_serves_every_query() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.slo_s = 30.0;
    let mut co = CoordinatorBuilder::new(cfg)
        .capacities(vec![CapacityModel { k: 0.0, b: 0.0 }; 4])
        .build()
        .unwrap();
    let qids = co.sample_queries(40).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 40);
    let psum: f64 = r.proportions.iter().sum();
    assert!((psum - 1.0).abs() < 1e-9, "{:?}", r.proportions);
}

#[test]
fn single_node_cluster_takes_the_whole_slot() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes.truncate(1);
    cfg.nodes[0].primary_domains = vec![0, 1, 2, 3, 4, 5];
    let mut co =
        CoordinatorBuilder::new(cfg).capacities(stub_caps(1)).build().unwrap();
    let qids = co.sample_queries(20).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 20);
    assert!(r.outcomes.iter().all(|o| o.node == 0));
    assert_eq!(r.proportions, vec![1.0]);
}

#[test]
fn inter_disabled_ppo_assigns_by_pure_sampling() {
    let mut cfg = tiny_cfg(AllocatorKind::Ppo);
    cfg.inter_enabled = false;
    let mut co =
        CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
    let qids = co.sample_queries(30).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 30);
    assert!(r.outcomes.iter().all(|o| o.node < 4));
    assert_eq!(r.feedback.observed, 30);
}

#[test]
fn freeze_learning_stops_observation_for_learning_allocators() {
    for kind in [AllocatorKind::Ppo, AllocatorKind::Mab] {
        let mut co = CoordinatorBuilder::new(tiny_cfg(kind))
            .capacities(stub_caps(4))
            .build()
            .unwrap();
        let qids = co.sample_queries(10).unwrap();
        let r = co.run_slot(&qids).unwrap();
        assert_eq!(r.feedback.observed, 10, "{kind}: learns while unfrozen");
        co.freeze_learning();
        let qids = co.sample_queries(10).unwrap();
        let r = co.run_slot(&qids).unwrap();
        assert_eq!(r.feedback.observed, 0, "{kind}: frozen must not learn");
        assert_eq!(r.feedback.updates, 0);
    }
}

#[test]
fn coordinator_never_calls_observe_on_a_frozen_allocator() {
    /// `is_frozen` from construction; any `observe` call is a bug.
    struct FrozenPanics;
    impl Allocator for FrozenPanics {
        fn name(&self) -> &str {
            "frozen-panics"
        }
        fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
            let n = ctx.n_nodes();
            Ok(Assignment::from_nodes((0..ctx.batch()).map(|i| i % n).collect()))
        }
        fn observe(
            &mut self,
            _ctx: &SlotContext,
            _assignment: &Assignment,
            _outcomes: &[QueryOutcome],
        ) -> coedge_rag::Result<FeedbackStats> {
            panic!("feedback phase must be skipped for frozen allocators");
        }
        fn is_frozen(&self) -> bool {
            true
        }
    }
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .allocator(Box::new(FrozenPanics))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    for _ in 0..2 {
        let qids = co.sample_queries(12).unwrap();
        let r = co.run_slot(&qids).unwrap();
        assert_eq!(r.feedback, FeedbackStats::default(), "no FeedbackStats drift");
    }
}

#[test]
fn custom_allocator_registers_without_touching_the_coordinator() {
    struct AlwaysZero;
    impl Allocator for AlwaysZero {
        fn name(&self) -> &str {
            "always-zero"
        }
        fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
            Ok(Assignment::all_to(ctx.batch(), 0))
        }
    }
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .register_allocator("always-zero", |_| Ok(Box::new(AlwaysZero)))
        .allocator_kind("always-zero")
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    assert_eq!(co.allocator().name(), "always-zero");
    let qids = co.sample_queries(10).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert!(r.outcomes.iter().all(|o| o.node == 0));
}

#[test]
fn unknown_allocator_kind_error_lists_valid_kinds() {
    let err = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .allocator_kind("nope")
        .capacities(stub_caps(4))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("valid kinds"), "{err}");
    for k in AllocatorKind::ALL {
        assert!(err.contains(k.as_str()), "{err} should list {k}");
    }
}

#[test]
fn misbehaving_allocator_is_rejected_not_panicking() {
    struct OutOfRange;
    impl Allocator for OutOfRange {
        fn name(&self) -> &str {
            "out-of-range"
        }
        fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
            Ok(Assignment::all_to(ctx.batch(), 99))
        }
    }
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .allocator(Box::new(OutOfRange))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co.sample_queries(5).unwrap();
    let err = co.run_slot(&qids).unwrap_err().to_string();
    assert!(err.contains("out-of-range"), "{err}");
}
