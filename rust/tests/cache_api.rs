//! Integration tests for the pluggable cache tier: CacheKind registry
//! wiring through config/builder, eviction-policy behavior at the API
//! level, memory-budget competition with the intra-node solver, and
//! custom-cache registration (the AllocatorRegistry pattern, third
//! instance).

use coedge_rag::cache::{
    entry_bytes, quantize_embedding, CacheEntry, CachePayload, CachedAnswer, EvictPolicy,
    PolicyCache, QueryCache,
};
use coedge_rag::config::{AllocatorKind, CacheSpec, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{CacheInvalidate, CoordinatorBuilder};
use coedge_rag::metrics::QualityScores;
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::vecdb::Hit;

fn tiny_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.queries_per_slot = 60;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

fn lru_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = tiny_cfg(allocator);
    cfg.cache = CacheSpec { kind: "lru".into(), capacity_mb: 8, ..CacheSpec::default() };
    for n in cfg.nodes.iter_mut() {
        n.cache = cfg.cache.clone();
    }
    cfg
}

fn stub_caps(n: usize) -> Vec<CapacityModel> {
    vec![CapacityModel { k: 50.0, b: 0.0 }; n]
}

fn hits_entry(node: usize, domain: usize) -> CacheEntry {
    CacheEntry {
        tag: coedge_rag::cache::EntryTag { node, domain },
        guard: 0,
        payload: CachePayload::Hits(vec![Hit { id: 1, score: 0.9 }; 5]),
    }
}

/// Eviction order across both policies through the trait object (the unit
/// tests in `src/cache` cover the concrete type; this pins the dyn path
/// the cluster actually uses).
#[test]
fn eviction_order_lru_vs_lfu_through_trait_object() {
    let cap = 3 * entry_bytes(&[0i8; 4], &hits_entry(0, 0));
    let mk = |p| -> Box<dyn QueryCache> { Box::new(PolicyCache::new(p, cap)) };
    // access pattern: 1 is hot but touched longest ago; 2 and 3 are cold
    // (tied at freq 2) with 2 older — LRU must evict 1, LFU must evict 2
    for (policy, expect_evicted) in [(EvictPolicy::Lru, 1u8), (EvictPolicy::Lfu, 2u8)] {
        let mut c = mk(policy);
        for k in 1..=3u8 {
            assert_eq!(c.insert(vec![k as i8; 4], hits_entry(0, 0)), 0);
        }
        for _ in 0..3 {
            assert!(c.get(&[1; 4]).is_some());
        }
        assert!(c.get(&[2; 4]).is_some());
        assert!(c.get(&[3; 4]).is_some());
        assert_eq!(c.insert(vec![9; 4], hits_entry(0, 0)), 1, "{policy:?}");
        assert!(
            c.get(&[expect_evicted as i8; 4]).is_none(),
            "{policy:?} must evict key {expect_evicted}"
        );
        assert_eq!(c.len(), 3);
    }
}

/// Answer payloads roundtrip with bitwise-identical scores.
#[test]
fn answer_payload_roundtrips_bitwise() {
    let mut c = PolicyCache::new(EvictPolicy::Lru, 1 << 20);
    let scores = QualityScores {
        rouge1: 0.123456789,
        rouge2: 0.2,
        rouge_l: 0.987654321,
        bleu4: 0.4,
        meteor: 0.5,
        bert_score: 0.690123,
    };
    let key = quantize_embedding(&[0.5, -0.5, 0.25, 0.0]);
    c.insert(
        key.clone(),
        CacheEntry {
            tag: coedge_rag::cache::EntryTag { node: 2, domain: 3 },
            guard: coedge_rag::cache::embedding_guard(&[0.5, -0.5, 0.25, 0.0]),
            payload: CachePayload::Answer(CachedAnswer {
                node: 2,
                model_idx: Some(1),
                rel: 0.75,
                scores,
                feedback: 0.61,
            }),
        },
    );
    match c.get_similar(&key, 1.0).expect("exact hit").payload {
        CachePayload::Answer(a) => {
            assert_eq!(a.scores, scores);
            assert_eq!(a.node, 2);
            assert_eq!(a.model_idx, Some(1));
            assert_eq!(a.rel, 0.75);
        }
        other => panic!("wrong payload: {other:?}"),
    }
}

/// A custom cache registered on the builder is selectable by kind, with
/// no cluster or coordinator changes — mirroring the allocator/index
/// registration tests.
#[test]
fn custom_cache_registration() {
    // a cache that forgets everything immediately: lookups always miss,
    // inserts never store (still "enabled", so stats are reported)
    struct Amnesia;
    impl QueryCache for Amnesia {
        fn name(&self) -> &str {
            "amnesia"
        }
        fn get(&mut self, _key: &[i8]) -> Option<CacheEntry> {
            None
        }
        fn insert(&mut self, _key: Vec<i8>, _entry: CacheEntry) -> usize {
            0
        }
        fn clear(&mut self) -> usize {
            0
        }
        fn len(&self) -> usize {
            0
        }
        fn bytes(&self) -> usize {
            0
        }
        fn capacity_bytes(&self) -> usize {
            0
        }
    }
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    cfg.cache = CacheSpec::of_kind("amnesia");
    for n in cfg.nodes.iter_mut() {
        n.cache = CacheSpec::of_kind("amnesia");
    }
    let mut co = CoordinatorBuilder::new(cfg)
        .register_cache("amnesia", |_| Ok(Box::new(Amnesia)))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    assert!(co.nodes.iter().all(|n| n.cache_kind == "amnesia"));
    let qids = co.sample_queries(30).unwrap();
    let r1 = co.run_slot(&qids).unwrap();
    let r2 = co.run_slot(&qids).unwrap();
    // an enabled cache reports stats; amnesia never hits, even on repeats
    for r in [&r1, &r2] {
        let c = r.cache.expect("enabled cache must report stats");
        assert_eq!(c.hits(), 0, "amnesia must never hit");
        assert_eq!(c.misses(), 2 * r.queries, "every lookup misses on both levels");
        assert_eq!(c.bytes, 0);
    }
    assert!(r2.outcomes.iter().all(|o| !o.cached));
}

#[test]
fn unknown_cache_kind_errors_with_registered_list() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes[1].cache = CacheSpec::of_kind("memcached");
    let err = CoordinatorBuilder::new(cfg)
        .capacities(stub_caps(4))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("memcached"), "{err}");
    for k in ["lru", "lfu", "none"] {
        assert!(err.contains(k), "{err} should list {k}");
    }
    // the cluster-level answer cache goes through the same registry
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.cache = CacheSpec::of_kind("redis");
    let err = CoordinatorBuilder::new(cfg)
        .capacities(stub_caps(4))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("redis"), "{err}");
}

/// With the cluster answer cache off but per-node LRU retrieval caches
/// on, re-running the same queries hits the retrieval level: searches are
/// skipped, results (and therefore relevance and quality inputs) are the
/// cached top-k.
#[test]
fn retrieval_cache_hits_when_answer_cache_off() {
    let mut cfg = tiny_cfg(AllocatorKind::Domain); // deterministic routing
    for n in cfg.nodes.iter_mut() {
        n.cache = CacheSpec { kind: "lru".into(), capacity_mb: 8, ..CacheSpec::default() };
    }
    assert!(!cfg.cache.enabled(), "cluster answer cache stays off");
    let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
    let qids = co.sample_queries(40).unwrap();
    let r1 = co.run_slot(&qids).unwrap();
    let c1 = r1.cache.expect("node caches alone must still report stats");
    assert_eq!(c1.retrieval_hits, 0);
    assert_eq!(c1.retrieval_misses, 40);
    assert_eq!(c1.answer_hits + c1.answer_misses, 0, "answer cache is off");
    let r2 = co.run_slot(&qids).unwrap();
    let c2 = r2.cache.expect("stats");
    assert_eq!(
        c2.retrieval_hits, 40,
        "domain routing repeats node choices, so every repeat hits: {c2:?}"
    );
    assert!(r2.outcomes.iter().all(|o| !o.cached), "retrieval hits still serve at nodes");
    // identical retrieval results ⇒ identical relevance per query
    for (a, b) in r1.outcomes.iter().zip(&r2.outcomes) {
        if !a.dropped && !b.dropped {
            assert_eq!(a.rel, b.rel, "qa {}", a.qa_id);
        }
    }
}

/// Repeated slots hit the answer cache; re-running the same queries
/// serves answers from the coordinator without routing them.
#[test]
fn repeated_slots_hit_the_answer_cache() {
    let mut co = CoordinatorBuilder::new(lru_cfg(AllocatorKind::Oracle))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co.sample_queries(40).unwrap();
    let r1 = co.run_slot(&qids).unwrap();
    let c1 = r1.cache.expect("stats");
    assert_eq!(c1.hits(), 0, "cold caches cannot hit");
    assert!(c1.bytes > 0, "serving must warm the caches");
    let r2 = co.run_slot(&qids).unwrap();
    let c2 = r2.cache.expect("stats");
    let served1: usize = r1.outcomes.iter().filter(|o| !o.dropped).count();
    assert!(c2.answer_hits > 0, "exact repeats must hit the answer cache: {c2:?}");
    assert_eq!(
        c2.answer_hits, served1,
        "every answer served in slot 1 must be a hit in slot 2 (none evicted at 8 MiB)"
    );
    // answer hits never reach a node, so proportions cover only routed
    // queries and cached outcomes replay the stored serve bitwise (the
    // cache keeps the LAST serve of a qa — duplicates within a slot
    // overwrite, so compare against the last occurrence, not positions)
    let mut stored: std::collections::HashMap<usize, &coedge_rag::cluster::node::QueryOutcome> =
        std::collections::HashMap::new();
    for o in r1.outcomes.iter().filter(|o| !o.dropped) {
        stored.insert(o.qa_id, o);
    }
    for b in r2.outcomes.iter().filter(|o| o.cached) {
        let a = stored[&b.qa_id];
        assert_eq!(a.scores, b.scores, "qa {}", b.qa_id);
        assert_eq!(a.node, b.node);
        assert_eq!(a.rel, b.rel);
    }
    let psum: f64 = r2.proportions.iter().sum();
    assert!(psum <= 1.0 + 1e-9);
}

/// `CacheInvalidate` scopes: corpus invalidation is per node, query-mix
/// invalidation flushes the answer cache, `All` empties everything.
#[test]
fn invalidate_scopes() {
    let mut co = CoordinatorBuilder::new(lru_cfg(AllocatorKind::Oracle))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co.sample_queries(40).unwrap();
    co.run_slot(&qids).unwrap();
    // node 0's retrieval cache warmed? (routing spreads load, so check sum)
    let warmed: usize = co.nodes.iter().map(|n| n.cache.len()).sum();
    assert!(warmed > 0);
    let dropped = co.invalidate_caches(CacheInvalidate::QueryMix);
    assert!(dropped > 0, "answer cache must have been warm");
    let dropped_all = co.invalidate_caches(CacheInvalidate::All);
    assert_eq!(dropped_all, warmed, "All must flush every remaining retrieval entry");
    assert!(co.nodes.iter().all(|n| n.cache.is_empty()));
    // with everything cold again, the next identical slot misses cleanly
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.cache.unwrap().hits(), 0);
}

/// The memory governor: a filling retrieval cache shrinks the node's
/// generation-memory cap; an empty or disabled cache leaves it at 1.0.
#[test]
fn cache_bytes_charge_the_node_memory_budget() {
    let mut cfg = lru_cfg(AllocatorKind::Oracle);
    // tiny node memory so the warmed cache is a visible fraction of it
    for n in cfg.nodes.iter_mut() {
        n.cache.node_mem_mb = 1;
    }
    let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
    for n in &co.nodes {
        assert_eq!(n.gen_mem_cap(), 1.0, "cold cache must not charge memory");
    }
    let qids = co.sample_queries(60).unwrap();
    co.run_slot(&qids).unwrap();
    let caps: Vec<f64> = co.nodes.iter().map(|n| n.gen_mem_cap()).collect();
    assert!(
        caps.iter().any(|&c| c < 1.0),
        "warmed caches must eat into generation memory: {caps:?}"
    );
    assert!(caps.iter().all(|&c| (0.0..=1.0).contains(&c)));
    // cache-off nodes never charge anything, however much they serve
    let mut co_off = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co_off.sample_queries(60).unwrap();
    co_off.run_slot(&qids).unwrap();
    assert!(co_off.nodes.iter().all(|n| n.gen_mem_cap() == 1.0));
}
