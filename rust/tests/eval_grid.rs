//! Integration coverage for the evaluation & reporting tier (`coedge
//! eval`): the grid fan-out must be byte-deterministic (two runs of the
//! same grid produce identical `BENCH_eval.json` text and identical
//! `docs/RESULTS.md` markdown), the paper grid must cover the full
//! acceptance matrix (all five allocators × both datasets × the four
//! committed scenario fixtures), and the rendered artifacts must carry
//! the per-baseline %-gain columns.

use std::path::{Path, PathBuf};

use coedge_rag::bench_harness::bench_json;
use coedge_rag::config::AllocatorKind;
use coedge_rag::experiments::{EvalGrid, EvalReport};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn artifacts(report: &EvalReport) -> (String, String) {
    (bench_json("eval", &report.to_bench_cases()), report.render_markdown())
}

/// Two independent smoke-grid runs — different thread counts, fresh
/// coordinators — must serialize byte-identically, the same contract the
/// golden-trace harness pins for transcripts. This is what lets CI diff
/// `coedge eval` output across double runs and commits.
#[test]
fn smoke_grid_is_byte_deterministic_across_runs_and_thread_counts() {
    let grid = EvalGrid::smoke();
    let a = grid.run(&scenarios_dir(), 4).expect("smoke grid run");
    let b = grid.run(&scenarios_dir(), 1).expect("smoke grid rerun");
    let (json_a, md_a) = artifacts(&a);
    let (json_b, md_b) = artifacts(&b);
    for (ga, gb) in json_a.lines().zip(json_b.lines()) {
        assert_eq!(ga, gb, "BENCH_eval.json drifted between identical runs");
    }
    assert_eq!(json_a, json_b);
    assert_eq!(md_a, md_b, "RESULTS.md drifted between identical runs");
}

/// The smoke grid's cells carry sane paper metrics, and the LRU-cached
/// repeat-storm cells report a cache hit rate while the plain cells do
/// not (the cache column only appears when the tier is on).
#[test]
fn smoke_grid_metrics_are_sane() {
    let report = EvalGrid::smoke().run(&scenarios_dir(), 0).expect("smoke grid run");
    assert_eq!(report.cells.len(), EvalGrid::smoke().num_cells());
    for c in &report.cells {
        let m = &c.metrics;
        assert!(m.slots > 0 && m.queries > 0, "{}: empty cell", c.name());
        assert!((0.0..=1.0).contains(&m.drop_rate), "{}: drop {}", c.name(), m.drop_rate);
        assert!((0.0..=1.0).contains(&m.slo_attainment), "{}", c.name());
        assert!(m.p95_latency_s >= 0.0 && m.mean_latency_s >= 0.0, "{}", c.name());
        assert!(m.rouge_l >= 0.0 && m.bert_score >= 0.0, "{}", c.name());
        if c.cached {
            let h = m.cache_hit_rate.expect("cached cell must report a hit rate");
            assert!((0.0..=1.0).contains(&h), "{}: hit rate {h}", c.name());
        } else {
            assert!(m.cache_hit_rate.is_none(), "{}: cache-off cell grew a hit rate", c.name());
        }
    }
    // at least one cached cell actually hit: repeat_storm is built for it
    assert!(
        report.cells.iter().any(|c| c.cached && c.metrics.cache_hit_rate.unwrap_or(0.0) > 0.0),
        "repeat_storm under LRU should produce nonzero hits"
    );
}

/// The paper grid covers the acceptance matrix — all five allocators
/// across at least four scenario fixtures and both datasets — and every
/// fixture it names is actually committed.
#[test]
fn paper_grid_covers_the_acceptance_matrix() {
    let grid = EvalGrid::paper();
    assert_eq!(grid.allocators, AllocatorKind::ALL.to_vec());
    assert!(grid.scenarios.len() >= 4);
    assert_eq!(grid.datasets.len(), 2);
    for sc in &grid.scenarios {
        let p = scenarios_dir().join(format!("{}.toml", sc.name));
        assert!(p.is_file(), "fixture missing: {}", p.display());
    }
}

/// The rendered markdown carries the paper-layout tables: one block per
/// (dataset, scenario) with every allocator as a row, plus the PPO-gain
/// summary with one column per baseline.
#[test]
fn rendered_markdown_has_baseline_and_gain_tables() {
    let report = EvalGrid::smoke().run(&scenarios_dir(), 0).expect("smoke grid run");
    let md = report.render_markdown();
    assert!(md.contains("Auto-generated"), "{md}");
    for al in AllocatorKind::ALL {
        assert!(md.contains(&format!("| {} |", al.as_str())), "missing row {al}\n{md}");
    }
    for col in ["vs random", "vs domain", "vs oracle", "vs mab"] {
        assert!(md.contains(col), "missing gain column {col}\n{md}");
    }
    assert!(md.contains("`domainqa` / `burst_storm`"), "{md}");
    assert!(md.contains("LRU caches on"), "{md}");
    // the JSON twin carries the same gains as machine-readable fields
    let json = bench_json("eval", &report.to_bench_cases());
    for key in ["gain_vs_random", "gain_vs_domain", "gain_vs_oracle", "gain_vs_mab"] {
        assert!(json.contains(key), "missing {key} in BENCH_eval.json\n{json}");
    }
}
