//! Failure-injection tests: the coordinator and serving front-end must
//! degrade gracefully, never panic, and account every query.

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, IntraStrategy};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::llmsim::model::ModelSize;
use coedge_rag::policy::ppo::Backend;

fn tiny_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.queries_per_slot = 120;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

#[test]
fn impossible_slo_drops_everything_gracefully() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle)).build().unwrap();
    co.set_slo(0.001); // below even the vector-search time
    let qids = co.sample_queries(100);
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 100);
    assert!(r.drop_rate > 0.95, "drop={}", r.drop_rate);
    // scores of dropped queries are zeros ("invalid")
    assert!(r.mean_scores.rouge_l < 0.05);
}

#[test]
fn failing_index_factory_surfaces_as_build_error() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.index = coedge_rag::config::IndexSpec::of_kind("degraded");
    }
    let err = CoordinatorBuilder::new(cfg)
        .register_index("degraded", |_| anyhow::bail!("index backend unavailable"))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("index backend unavailable"), "{err}");
}

#[test]
fn empty_slot_is_fine() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
    let r = co.run_slot(&[]).unwrap();
    assert_eq!(r.queries, 0);
    assert_eq!(r.outcomes.len(), 0);
    assert_eq!(r.drop_rate, 0.0);
}

#[test]
fn node_with_empty_corpus_still_serves() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes[0].corpus_docs = 0; // data-less node: retrieval returns nothing
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(120);
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 120);
    // queries landing on the empty node get rel=0 generations, not panics
    let on_empty: Vec<_> = r.outcomes.iter().filter(|o| o.node == 0 && !o.dropped).collect();
    for o in &on_empty {
        assert!(o.rel == 0.0);
        assert!(o.scores.rouge_l < 0.9);
    }
}

#[test]
fn pool_without_small_models_survives_tight_slo() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.pool = vec![ModelSize::Large];
    }
    cfg.slo_s = 3.0;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(200);
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 200);
    assert!(r.drop_rate > 0.2, "large-only at 3s must shed load");
}

#[test]
fn fixed_strategy_referencing_missing_size_degrades() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.pool = vec![ModelSize::Small]; // pool lacks Mid
    }
    cfg.intra = IntraStrategy::mid_param(2); // asks for Mid everywhere
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(60);
    let r = co.run_slot(&qids).unwrap();
    // nothing deployable -> every query dropped, no panic
    assert_eq!(r.outcomes.len(), 60);
    assert!(r.drop_rate > 0.99);
}

#[test]
fn zero_embedding_queries_get_valid_probabilities() {
    use coedge_rag::policy::ppo::{OnlinePolicy, PpoConfig};
    let pol = OnlinePolicy::new(4, PpoConfig::default(), Backend::Reference);
    let x = vec![0f32; coedge_rag::policy::params::EMBED_DIM];
    let probs = pol.probs(&x, 1).unwrap();
    let s: f32 = probs.iter().sum();
    assert!((s - 1.0).abs() < 1e-4);
    assert!(probs.iter().all(|p| p.is_finite() && *p >= 0.0));
}

#[test]
fn server_survives_malformed_requests() {
    use coedge_rag::server::{serve, Client, ServerConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle)).build().unwrap();
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        tx.send(addr).unwrap();
        serve(
            co,
            ServerConfig { addr: addr.to_string(), batch_window_ms: 5, max_batch: 4 },
            sd,
        )
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    std::thread::sleep(std::time::Duration::from_millis(100));

    // garbage line -> error response, connection stays alive
    // (scoped so both socket handles close before server shutdown —
    // the handler thread blocks on the connection until EOF)
    {
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        stream.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        // missing qa_id -> structured error
        stream.write_all(b"{\"id\": 3}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("missing qa_id"), "{line}");
    }

    // a well-formed client still works afterwards
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.request(9, 1).unwrap();
    assert!(resp.get("rouge_l").is_some());

    shutdown.store(true, Ordering::Relaxed);
    drop(client);
    handle.join().unwrap();
}

#[test]
fn coordinator_deterministic_given_seed() {
    let r1 = {
        let mut co =
            CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
        let qids = co.sample_queries(100);
        co.run_slot(&qids).unwrap().mean_scores
    };
    let r2 = {
        let mut co =
            CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
        let qids = co.sample_queries(100);
        co.run_slot(&qids).unwrap().mean_scores
    };
    assert_eq!(r1, r2);
}
