//! Failure-injection tests: the coordinator and serving front-end must
//! degrade gracefully, never panic, and account every query.

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, IntraStrategy};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::llmsim::model::ModelSize;
use coedge_rag::policy::ppo::Backend;
use coedge_rag::scenario::{Scenario, ScenarioEvent, ScenarioRunner, TimedEvent};

fn tiny_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.queries_per_slot = 120;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

#[test]
fn impossible_slo_drops_everything_gracefully() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle)).build().unwrap();
    co.set_slo(0.001); // below even the vector-search time
    let qids = co.sample_queries(100).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 100);
    assert!(r.drop_rate > 0.95, "drop={}", r.drop_rate);
    // scores of dropped queries are zeros ("invalid")
    assert!(r.mean_scores.rouge_l < 0.05);
}

#[test]
fn failing_index_factory_surfaces_as_build_error() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.index = coedge_rag::config::IndexSpec::of_kind("degraded");
    }
    let err = CoordinatorBuilder::new(cfg)
        .register_index("degraded", |_| anyhow::bail!("index backend unavailable"))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("index backend unavailable"), "{err}");
}

#[test]
fn empty_slot_is_fine() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
    let r = co.run_slot(&[]).unwrap();
    assert_eq!(r.queries, 0);
    assert_eq!(r.outcomes.len(), 0);
    assert_eq!(r.drop_rate, 0.0);
}

#[test]
fn node_with_empty_corpus_still_serves() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes[0].corpus_docs = 0; // data-less node: retrieval returns nothing
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(120).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 120);
    // queries landing on the empty node get rel=0 generations, not panics
    let on_empty: Vec<_> = r.outcomes.iter().filter(|o| o.node == 0 && !o.dropped).collect();
    for o in &on_empty {
        assert!(o.rel == 0.0);
        assert!(o.scores.rouge_l < 0.9);
    }
}

#[test]
fn pool_without_small_models_survives_tight_slo() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.pool = vec![ModelSize::Large];
    }
    cfg.slo_s = 3.0;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(200).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 200);
    assert!(r.drop_rate > 0.2, "large-only at 3s must shed load");
}

#[test]
fn fixed_strategy_referencing_missing_size_degrades() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.pool = vec![ModelSize::Small]; // pool lacks Mid
    }
    cfg.intra = IntraStrategy::mid_param(2); // asks for Mid everywhere
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let qids = co.sample_queries(60).unwrap();
    let r = co.run_slot(&qids).unwrap();
    // nothing deployable -> every query dropped, no panic
    assert_eq!(r.outcomes.len(), 60);
    assert!(r.drop_rate > 0.99);
}

#[test]
fn zero_embedding_queries_get_valid_probabilities() {
    use coedge_rag::policy::ppo::{OnlinePolicy, PpoConfig};
    let pol = OnlinePolicy::new(4, PpoConfig::default(), Backend::Reference);
    let x = vec![0f32; coedge_rag::policy::params::EMBED_DIM];
    let probs = pol.probs(&x, 1).unwrap();
    let s: f32 = probs.iter().sum();
    assert!((s - 1.0).abs() < 1e-4);
    assert!(probs.iter().all(|p| p.is_finite() && *p >= 0.0));
}

#[test]
fn server_survives_malformed_requests() {
    use coedge_rag::server::{serve, Client, ServerConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle)).build().unwrap();
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        tx.send(addr).unwrap();
        serve(
            co,
            ServerConfig {
                addr: addr.to_string(),
                batch_window_ms: 5,
                max_batch: 4,
                ..Default::default()
            },
            sd,
        )
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    std::thread::sleep(std::time::Duration::from_millis(100));

    // garbage line -> error response, connection stays alive
    // (scoped so both socket handles close before server shutdown —
    // the handler thread blocks on the connection until EOF)
    {
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        stream.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        // missing qa_id -> structured error
        stream.write_all(b"{\"id\": 3}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("missing qa_id"), "{line}");
    }

    // a well-formed client still works afterwards
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.request(9, 1).unwrap();
    assert!(resp.get("rouge_l").is_some());

    shutdown.store(true, Ordering::Relaxed);
    drop(client);
    handle.join().unwrap();
}

/// Every node down: the slot is shed at the coordinator — 100% drops, no
/// panic, proportions all zero — and service resumes the moment any node
/// returns.
#[test]
fn all_nodes_down_slot_degrades_gracefully_then_recovers() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle)).build().unwrap();
    for n in 0..4 {
        co.set_node_active(n, false).unwrap();
    }
    let qids = co.sample_queries(50).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 50);
    assert_eq!(r.drop_rate, 1.0);
    assert!(r.outcomes.iter().all(|o| o.dropped && o.node == usize::MAX));
    assert_eq!(r.proportions, vec![0.0; 4]);
    assert!(r.active.iter().all(|&a| !a));

    co.set_node_active(1, true).unwrap();
    let qids = co.sample_queries(50).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 50);
    assert!(r.outcomes.iter().all(|o| o.node == 1), "only the live node may serve");
    assert!(r.drop_rate < 1.0, "drop={}", r.drop_rate);
}

/// A node that fails mid-run and comes back: while down it receives
/// nothing; once up it serves again — driven through the scenario engine.
#[test]
fn node_down_mid_run_comes_back_and_recovers() {
    let sc = Scenario {
        name: "churn".into(),
        slots: Some(4),
        trace: None,
        events: vec![
            TimedEvent { slot: 1, event: ScenarioEvent::NodeDown { node: 0 } },
            TimedEvent { slot: 3, event: ScenarioEvent::NodeUp { node: 0 } },
        ],
    };
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.queries_per_slot = 120;
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let run = ScenarioRunner::new(sc).run(&mut co).unwrap();
    assert_eq!(run.reports.len(), 4);
    assert!(run.reports[0].outcomes.iter().any(|o| o.node == 0), "warmup uses node 0");
    for t in 1..3 {
        assert!(!run.reports[t].active[0]);
        assert!(
            run.reports[t].outcomes.iter().all(|o| o.node != 0),
            "slot {t}: query on down node 0"
        );
        assert_eq!(run.reports[t].proportions[0], 0.0);
    }
    assert!(run.reports[3].active[0]);
    assert!(
        run.reports[3].outcomes.iter().any(|o| o.node == 0),
        "node 0 must rejoin after NodeUp: {:?}",
        run.reports[3].proportions
    );
}

/// Live corpus ingest into finalized IVF and HNSW indexes: vectors route
/// online (IVF) / build incrementally (HNSW) — no re-finalize, no panic,
/// and the next slot serves normally.
#[test]
fn corpus_ingest_into_ivf_and_hnsw_serves_without_refinalize() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes[0].index = coedge_rag::config::IndexSpec::of_kind("ivf");
    cfg.nodes[0].index.nlist = 8;
    cfg.nodes[0].index.nprobe = 4;
    cfg.nodes[1].index = coedge_rag::config::IndexSpec::of_kind("hnsw");
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    let before: Vec<usize> = (0..2).map(|n| co.nodes[n].corpus_size()).collect();
    let added_ivf = co.ingest_corpus(0, 4, 12).unwrap();
    let added_hnsw = co.ingest_corpus(1, 0, 12).unwrap();
    assert!(added_ivf > 0 && added_hnsw > 0, "{added_ivf} {added_hnsw}");
    assert_eq!(co.nodes[0].corpus_size(), before[0] + added_ivf);
    assert_eq!(co.nodes[1].corpus_size(), before[1] + added_hnsw);
    // the running indexes grew with the corpus — no rebuild happened
    assert_eq!(co.nodes[0].index.len(), co.nodes[0].corpus_size());
    assert_eq!(co.nodes[1].index.len(), co.nodes[1].corpus_size());
    // ingest is idempotent once the domain is exhausted on that node
    let rest = co.ingest_corpus(0, 4, 1000).unwrap();
    assert_eq!(co.nodes[0].corpus_size(), before[0] + added_ivf + rest);
    assert_eq!(co.ingest_corpus(0, 4, 1000).unwrap(), 0, "domain already fully replicated");
    let qids = co.sample_queries(80).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 80);
}

#[test]
fn coordinator_deterministic_given_seed() {
    let r1 = {
        let mut co =
            CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
        let qids = co.sample_queries(100).unwrap();
        co.run_slot(&qids).unwrap().mean_scores
    };
    let r2 = {
        let mut co =
            CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Ppo)).build().unwrap();
        let qids = co.sample_queries(100).unwrap();
        co.run_slot(&qids).unwrap().mean_scores
    };
    assert_eq!(r1, r2);
}
