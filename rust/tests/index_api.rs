//! Integration tests for the pluggable retrieval tier: IndexKind registry
//! wiring through config/builder, sharded-vs-flat exactness (property
//! test), batch/loop parity across kinds, and end-to-end retrieval parity
//! when swapping `flat` for `sharded-flat` on a live cluster.

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, IndexSpec};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::text::embed::l2_normalize;
use coedge_rag::util::rng::Rng;
use coedge_rag::vecdb::{
    FlatIndex, Hit, HnswIndex, IndexBuildCtx, IndexKind, IndexMigration, IndexRegistry, IvfIndex,
    QuantizedFlatIndex, ShardedIndex, VectorIndex,
};

fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn tiny_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.queries_per_slot = 80;
    cfg.allocator = allocator;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

fn stub_caps(n: usize) -> Vec<CapacityModel> {
    vec![CapacityModel { k: 50.0, b: 0.0 }; n]
}

/// Property: `ShardedIndex<FlatIndex>` returns identical top-k to an
/// unsharded `FlatIndex` across random corpus sizes, dims, shard counts,
/// and k (exact recall parity — sharding must not change results).
#[test]
fn prop_sharded_flat_equals_flat() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..30 {
        let dim = 4 + rng.below(24);
        let n = 20 + rng.below(400);
        let shards = 1 + rng.below(8);
        let k = 1 + rng.below(10);
        let mut flat = FlatIndex::new(dim);
        let mut sharded = ShardedIndex::from_fn(shards, |_| FlatIndex::new(dim));
        for i in 0..n {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            sharded.add(i, &v);
        }
        let queries: Vec<Vec<f32>> = (0..8).map(|_| random_unit(&mut rng, dim)).collect();
        let expect: Vec<Vec<Hit>> = queries.iter().map(|q| flat.search(q, k)).collect();
        let batched = sharded.search_batch(&queries, k);
        assert_eq!(
            batched, expect,
            "case {case}: dim={dim} n={n} shards={shards} k={k}"
        );
        for (q, e) in queries.iter().zip(&expect) {
            assert_eq!(sharded.search(q, k), *e, "case {case} (single-query path)");
        }
    }
}

/// The default `search_batch` and any override must match the per-query
/// loop for every built-in kind.
#[test]
fn batch_matches_loop_across_kinds() {
    let mut rng = Rng::new(71);
    let dim = 16;
    let vecs: Vec<Vec<f32>> = (0..500).map(|_| random_unit(&mut rng, dim)).collect();
    let mut flat = FlatIndex::new(dim);
    let mut ivf = IvfIndex::new(dim, 12, 4);
    let mut hnsw = HnswIndex::new(dim, 8, 48, 32, 9);
    let mut sharded = ShardedIndex::from_fn(4, |_| FlatIndex::new(dim));
    for (i, v) in vecs.iter().enumerate() {
        flat.add(i, v);
        ivf.add(i, v);
        hnsw.add(i, v);
        sharded.add(i, v);
    }
    ivf.finalize(5);
    let queries: Vec<Vec<f32>> = (0..24).map(|_| random_unit(&mut rng, dim)).collect();
    let indexes: [&dyn VectorIndex; 4] = [&flat, &ivf, &hnsw, &sharded];
    for (name, idx) in ["flat", "ivf", "hnsw", "sharded-flat"].iter().zip(indexes) {
        let batched = idx.search_batch(&queries, 5);
        for (q, hits) in queries.iter().zip(&batched) {
            assert_eq!(*hits, idx.search(q, 5), "{name}");
        }
    }
}

/// Selecting a built-in kind per node through the config reaches the node.
#[test]
fn node_index_kind_is_config_selectable() {
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    cfg.nodes[0].index = IndexSpec::of_kind("sharded-flat");
    cfg.nodes[0].index.shards = 2;
    cfg.nodes[1].index = IndexSpec::of_kind("ivf");
    cfg.nodes[1].index.nlist = 8;
    cfg.nodes[1].index.nprobe = 8;
    cfg.nodes[2].index = IndexSpec::of_kind("hnsw");
    let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
    let kinds: Vec<&str> = co.nodes.iter().map(|n| n.index_kind.as_str()).collect();
    assert_eq!(kinds, vec!["sharded-flat", "ivf", "hnsw", "flat"]);
    for n in &co.nodes {
        assert_eq!(n.index.len(), n.corpus_size(), "{}", n.name);
    }
    let qids = co.sample_queries(40).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.outcomes.len(), 40);
}

/// A custom index registered on the builder is selectable by kind, with no
/// cluster-layer changes (the AllocatorRegistry pattern, retrieval tier).
#[test]
fn custom_index_registration() {
    // degenerate index that "retrieves" nothing
    struct Amnesia;
    impl VectorIndex for Amnesia {
        fn add(&mut self, _id: usize, _v: &[f32]) {}
        fn search(&self, _q: &[f32], _k: usize) -> Vec<Hit> {
            Vec::new()
        }
        fn len(&self) -> usize {
            0
        }
    }
    let mut cfg = tiny_cfg(AllocatorKind::Oracle);
    for n in cfg.nodes.iter_mut() {
        n.index = IndexSpec::of_kind("amnesia");
    }
    let mut co = CoordinatorBuilder::new(cfg)
        .register_index("amnesia", |_| Ok(Box::new(Amnesia)))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co.sample_queries(30).unwrap();
    let r = co.run_slot(&qids).unwrap();
    // nothing retrieved → zero relevance everywhere, but serving still works
    assert!(r.outcomes.iter().all(|o| o.rel == 0.0));
}

#[test]
fn unknown_index_kind_errors_with_registered_list() {
    let mut cfg = tiny_cfg(AllocatorKind::Random);
    cfg.nodes[2].index = IndexSpec::of_kind("faiss-gpu");
    let err = CoordinatorBuilder::new(cfg)
        .capacities(stub_caps(4))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("faiss-gpu"), "{err}");
    for k in ["flat", "ivf", "hnsw", "sharded-flat", "sharded-ivf"] {
        assert!(err.contains(k), "{err} should list {k}");
    }
}

/// End-to-end retrieval parity: swapping every node's `flat` index for
/// `sharded-flat` must leave each query's retrieval relevance byte-for-byte
/// identical (exactness survives the whole serve path).
#[test]
fn e2e_sharded_flat_matches_flat_outcomes() {
    let run = |kind: &str| {
        let mut cfg = tiny_cfg(AllocatorKind::Oracle);
        for n in cfg.nodes.iter_mut() {
            n.index = IndexSpec::of_kind(kind);
            n.index.shards = 3;
        }
        let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
        let qids = co.sample_queries(60).unwrap();
        (qids.clone(), co.run_slot(&qids).unwrap())
    };
    let (q_flat, r_flat) = run("flat");
    let (q_shard, r_shard) = run("sharded-flat");
    assert_eq!(q_flat, q_shard, "same seed → same sampled queries");
    for (a, b) in r_flat.outcomes.iter().zip(&r_shard.outcomes) {
        assert_eq!(a.qa_id, b.qa_id);
        assert_eq!(a.rel, b.rel, "qa {}", a.qa_id);
        assert_eq!(a.dropped, b.dropped);
    }
}

/// The slot report exposes measured wall-clock search time alongside the
/// modeled TS_n^t, per node.
#[test]
fn measured_search_time_is_reported() {
    let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Random))
        .capacities(stub_caps(4))
        .build()
        .unwrap();
    let qids = co.sample_queries(80).unwrap();
    let r = co.run_slot(&qids).unwrap();
    assert_eq!(r.node_search_s.len(), co.nodes.len());
    // with a random allocator over 80 queries every node serves some
    for (nid, &(modeled, measured)) in r.node_search_s.iter().enumerate() {
        assert!(modeled > 0.0, "node {nid}: modeled TS must be positive");
        assert!(measured > 0.0, "node {nid}: measured wall-clock must be recorded");
    }
}

/// Property: `quantized-flat` at the default `rescore_factor` returns hit
/// lists *byte-identical* to `flat` over random dims / corpus sizes / k —
/// and the sharded composition keeps the parity across thread counts.
#[test]
fn prop_quantized_flat_equals_flat_bitwise() {
    let mut rng = Rng::new(0x0DDB17);
    for case in 0..25 {
        let dim = 4 + rng.below(28);
        let n = 20 + rng.below(400);
        let k = 1 + rng.below(10);
        let shards = 1 + rng.below(6);
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, 4);
        let mut sharded_1 = ShardedIndex::from_fn(shards, |_| QuantizedFlatIndex::new(dim, 4))
            .with_threads(1);
        let mut sharded_4 = ShardedIndex::from_fn(shards, |_| QuantizedFlatIndex::new(dim, 4))
            .with_threads(4);
        for i in 0..n {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            quant.add(i, &v);
            sharded_1.add(i, &v);
            sharded_4.add(i, &v);
        }
        let queries: Vec<Vec<f32>> = (0..8).map(|_| random_unit(&mut rng, dim)).collect();
        let expect: Vec<Vec<Hit>> = queries.iter().map(|q| flat.search(q, k)).collect();
        let ctx = format!("case {case}: dim={dim} n={n} k={k} shards={shards}");
        assert_eq!(quant.search_batch(&queries, k), expect, "{ctx}");
        for (q, e) in queries.iter().zip(&expect) {
            assert_eq!(quant.search(q, k), *e, "{ctx} (single-query)");
        }
        assert_eq!(sharded_1.search_batch(&queries, k), expect, "{ctx} (threads=1)");
        assert_eq!(sharded_4.search_batch(&queries, k), expect, "{ctx} (threads=4)");
    }
}

/// Property: at `rescore_factor = 1` (approximate integer-top-k mode)
/// recall@5 vs the exact flat scan stays ≥ 0.9 in aggregate — for both the
/// unsharded index and the sharded composition at 1 and 4 threads.
#[test]
fn prop_quantized_rescore_one_recall() {
    let mut rng = Rng::new(0x5EED);
    let (mut hit, mut total) = ([0usize; 3], 0usize);
    for _ in 0..12 {
        let dim = 8 + rng.below(32);
        let n = 50 + rng.below(300);
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, 1);
        let mut sharded_1 =
            ShardedIndex::from_fn(3, |_| QuantizedFlatIndex::new(dim, 1)).with_threads(1);
        let mut sharded_4 =
            ShardedIndex::from_fn(3, |_| QuantizedFlatIndex::new(dim, 1)).with_threads(4);
        for i in 0..n {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            quant.add(i, &v);
            sharded_1.add(i, &v);
            sharded_4.add(i, &v);
        }
        for _ in 0..10 {
            let q = random_unit(&mut rng, dim);
            let k = 5.min(n);
            let exact: Vec<usize> = flat.search(&q, k).iter().map(|h| h.id).collect();
            let indexes: [&dyn VectorIndex; 3] = [&quant, &sharded_1, &sharded_4];
            for (slot, idx) in indexes.into_iter().enumerate() {
                let approx = idx.search(&q, k);
                assert_eq!(approx.len(), exact.len());
                hit[slot] += approx.iter().filter(|h| exact.contains(&h.id)).count();
            }
            total += exact.len();
        }
    }
    for (slot, name) in ["quantized-flat", "sharded(t=1)", "sharded(t=4)"].iter().enumerate() {
        let recall = hit[slot] as f64 / total as f64;
        assert!(recall >= 0.9, "{name}: recall@5 = {recall:.3}");
    }
}

/// The registry builds both quantized kinds, honors `rescore_factor`, and
/// the built index round-trips an end-to-end search.
#[test]
fn quantized_kinds_build_through_registry() {
    use coedge_rag::vecdb::{IndexBuildCtx, IndexRegistry};
    let reg = IndexRegistry::with_builtins();
    let mut spec = IndexSpec::of_kind("quantized-flat");
    spec.rescore_factor = 2;
    let mut rng = Rng::new(3);
    for kind in ["quantized-flat", "sharded-quantized"] {
        spec.kind = kind.into();
        let mut idx = reg.build(kind, &IndexBuildCtx { dim: 16, seed: 1, spec: &spec }).unwrap();
        let mut flat = FlatIndex::new(16);
        for i in 0..120 {
            let v = random_unit(&mut rng, 16);
            idx.add(i, &v);
            flat.add(i, &v);
        }
        idx.finalize(1);
        let q = random_unit(&mut rng, 16);
        assert_eq!(idx.search(&q, 5), flat.search(&q, 5), "{kind}");
    }
}

/// Property: a reindex-migrated index is bitwise identical to a
/// fresh-built target index over random dim / n / k and random
/// mid-migration ingests, across every pair of exact kinds
/// (flat ↔ quantized-flat rf=4 ↔ sharded-flat). The write-log drain must
/// replay snapshot rows inside the finalized build and ingested rows
/// after it, in ingestion order — any reorder or drop breaks tie
/// resolution and shows up as a hit-list mismatch.
#[test]
fn prop_migrated_index_matches_fresh_build_bitwise() {
    use std::sync::Arc;
    let pairs = [
        ("flat", "quantized-flat"),
        ("quantized-flat", "sharded-flat"),
        ("sharded-flat", "flat"),
        ("quantized-flat", "flat"),
        ("flat", "sharded-flat"),
        ("sharded-flat", "quantized-flat"),
    ];
    let registry = Arc::new(IndexRegistry::with_builtins());
    let mut rng = Rng::new(0x9E11DE);
    for (case, &(from, to)) in pairs.iter().cycle().take(18).enumerate() {
        let dim = 4 + rng.below(24);
        let n = 20 + rng.below(200);
        let extra = rng.below(30);
        let k = 1 + rng.below(8);
        let seed = rng.below(1 << 20) as u64;
        let embs: Arc<Vec<Vec<f32>>> =
            Arc::new((0..n + extra).map(|_| random_unit(&mut rng, dim)).collect());
        let mut spec = IndexSpec::of_kind(to);
        spec.rescore_factor = 4;
        let to_kind: IndexKind = to.parse().unwrap();
        let mut mig = IndexMigration::start(
            Arc::clone(&registry),
            spec.clone(),
            to_kind,
            from,
            dim,
            seed,
            (0..n).collect(),
            Arc::clone(&embs),
            1,
        );
        let ingested: Vec<usize> = (n..n + extra).collect();
        mig.log_ingest(&ingested);
        assert!(mig.tick(), "a 1-slot countdown swaps on the first tick");
        let migrated = mig.finish(&embs).unwrap();
        // fresh-built target over the same rows, matching the live
        // corpus-ingest semantics: snapshot rows inside the finalized
        // build, ingested rows appended afterwards, same order
        let mut fresh = registry.build(to, &IndexBuildCtx { dim, seed, spec: &spec }).unwrap();
        for i in 0..n {
            fresh.add(i, &embs[i]);
        }
        fresh.finalize(seed);
        for &i in &ingested {
            fresh.add(i, &embs[i]);
        }
        assert_eq!(migrated.len(), fresh.len());
        let ctx = format!("case {case}: {from}->{to} dim={dim} n={n} extra={extra} k={k}");
        for q in (0..6).map(|_| random_unit(&mut rng, dim)) {
            assert_eq!(migrated.search(&q, k), fresh.search(&q, k), "{ctx}");
        }
    }
}

/// End-to-end migration parity, plus the block-edge ingest regression:
/// a run that live-migrates node 0 flat → quantized-flat (exact at
/// rf=4) and then ingests past the 96-row SoA block edge produces
/// per-query outcomes bitwise identical to a run that never migrates —
/// before the swap (the in-flight build must not perturb the serving
/// old index), across the swap (exact target kind), and through the
/// post-swap incremental `add` that opens a fresh i8 code block.
#[test]
fn e2e_migration_and_block_edge_ingest_match_unmigrated_run() {
    use coedge_rag::scenario::ScenarioEvent;
    let run = |reindex: bool| {
        let mut co = CoordinatorBuilder::new(tiny_cfg(AllocatorKind::Oracle))
            .capacities(stub_caps(4))
            .build()
            .unwrap();
        if reindex {
            co.apply_event(&ScenarioEvent::Reindex {
                node: 0,
                to: "quantized-flat".into(),
                shards: None,
                rescore_factor: Some(4),
            })
            .unwrap();
        }
        let mut outs = Vec::new();
        for slot in 0..5 {
            if slot == 3 {
                // node 0 holds 69 rows (60 × 1.15 overlap): ingesting 30
                // docs from non-primary domain 3 (38 un-held available)
                // takes the live index 69 → 99, crossing the 96-row SoA
                // block edge with incremental adds (post-swap: the
                // 69-row build is a 2-slot modeled migration, so the
                // quantized index is serving by now)
                assert_eq!(co.ingest_corpus(0, 3, 30).unwrap(), 30);
            }
            let qids = co.sample_queries(40).unwrap();
            let r = co.run_slot(&qids).unwrap();
            outs.push((qids, r));
        }
        (co.nodes[0].index_kind.clone(), co.nodes[0].corpus_size(), outs)
    };
    let (kind_mig, size_mig, migrated) = run(true);
    let (kind_ctl, size_ctl, control) = run(false);
    assert_eq!(kind_mig, "quantized-flat", "the swap must have landed");
    assert_eq!(kind_ctl, "flat");
    assert_eq!(size_mig, size_ctl);
    assert!(size_mig > 96, "ingest must cross the 96-row block edge (corpus = {size_mig})");
    for (t, ((qa, ra), (qb, rb))) in migrated.iter().zip(&control).enumerate() {
        assert_eq!(qa, qb, "slot {t}: same seed → same sampled queries");
        for (a, b) in ra.outcomes.iter().zip(&rb.outcomes) {
            assert_eq!(a.qa_id, b.qa_id, "slot {t}");
            assert_eq!(a.rel, b.rel, "slot {t} qa {}", a.qa_id);
            assert_eq!(a.dropped, b.dropped, "slot {t} qa {}", a.qa_id);
        }
    }
}

/// End-to-end parity: swapping every node's index for `quantized-flat` (or
/// `sharded-quantized`) leaves each query's retrieval relevance
/// byte-for-byte identical to `flat` across the whole serve path.
#[test]
fn e2e_quantized_matches_flat_outcomes() {
    let run = |kind: &str| {
        let mut cfg = tiny_cfg(AllocatorKind::Oracle);
        for n in cfg.nodes.iter_mut() {
            n.index = IndexSpec::of_kind(kind);
            n.index.shards = 3;
        }
        let mut co = CoordinatorBuilder::new(cfg).capacities(stub_caps(4)).build().unwrap();
        let qids = co.sample_queries(60).unwrap();
        (qids.clone(), co.run_slot(&qids).unwrap())
    };
    let (q_flat, r_flat) = run("flat");
    for kind in ["quantized-flat", "sharded-quantized"] {
        let (q_kind, r_kind) = run(kind);
        assert_eq!(q_flat, q_kind, "same seed → same sampled queries");
        for (a, b) in r_flat.outcomes.iter().zip(&r_kind.outcomes) {
            assert_eq!(a.qa_id, b.qa_id, "{kind}");
            assert_eq!(a.rel, b.rel, "{kind} qa {}", a.qa_id);
            assert_eq!(a.dropped, b.dropped, "{kind}");
        }
    }
}
