//! Integration tests for the TCP serving engine: shutdown with idle
//! connections, shed-slot wire encoding, concurrent multi-connection
//! request pipelining, batching boundaries, and sync-vs-pipelined
//! response parity.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::server::{serve, Client, ServerConfig};
use coedge_rag::util::json::Json;

/// The shrunk paper cluster the server tests run against (stubbed
/// capacities: no profiling noise, no drops at these loads).
fn test_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 20;
    cfg.docs_per_domain = 40;
    cfg.allocator = AllocatorKind::Oracle;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 60;
    }
    cfg
}

fn build_coordinator() -> Coordinator {
    CoordinatorBuilder::new(test_cfg())
        .capacities(vec![CapacityModel { k: 6.0, b: 0.0 }; 4])
        .build()
        .unwrap()
}

/// Start `serve` on an ephemeral port in a background thread. Returns the
/// address, the shutdown flag, and the server's join handle.
fn start_server(
    co: Coordinator,
    scfg: ServerConfig,
) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let (addr_tx, addr_rx) = channel();
    let handle = std::thread::spawn(move || {
        // probe an ephemeral port, then serve on it
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        addr_tx.send(addr).unwrap();
        let cfg = ServerConfig { addr: addr.to_string(), ..scfg };
        serve(co, cfg, sd).unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();
    std::thread::sleep(Duration::from_millis(100));
    (addr, shutdown, handle)
}

/// Join a server handle under a watchdog: a hung shutdown fails the test
/// instead of hanging the suite forever.
fn join_within(handle: std::thread::JoinHandle<()>, timeout: Duration, what: &str) {
    let (done_tx, done_rx) = channel();
    std::thread::spawn(move || {
        let r = handle.join();
        let _ = done_tx.send(r);
    });
    match done_rx.recv_timeout(timeout) {
        Ok(r) => r.unwrap(),
        Err(_) => panic!("{what}: server did not shut down within {timeout:?}"),
    }
}

/// Regression (shutdown hang): `serve` must terminate even with a client
/// connected that never sends a byte. The old handler blocked forever in
/// `reader.lines()` and the final join never returned.
#[test]
fn shutdown_terminates_with_idle_client_attached() {
    let (addr, shutdown, handle) = start_server(
        build_coordinator(),
        ServerConfig { batch_window_ms: 5, read_timeout_ms: 20, ..Default::default() },
    );
    // connect and stay silent; keep the connection open across shutdown
    let idle = Client::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(150));
    shutdown.store(true, Ordering::Relaxed);
    join_within(handle, Duration::from_secs(10), "idle-client shutdown");
    drop(idle);
}

/// Regression (shed-query wire encoding): with every node down the slot
/// is shed at the coordinator and the response must carry `node: null`
/// (not usize::MAX cast to a float) alongside `dropped: true`.
#[test]
fn all_down_slot_responds_with_null_node() {
    let mut co = build_coordinator();
    for n in 0..4 {
        co.set_node_active(n, false).unwrap();
    }
    let (addr, shutdown, handle) = start_server(
        co,
        ServerConfig { batch_window_ms: 5, ..Default::default() },
    );
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.request(1, 0).unwrap();
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(1.0), "{resp:?}");
    assert!(
        matches!(resp.get("node"), Some(Json::Null)),
        "shed query must put node:null on the wire: {resp:?}"
    );
    assert_eq!(resp.get("dropped").unwrap().as_bool(), Some(true), "{resp:?}");
    shutdown.store(true, Ordering::Relaxed);
    drop(client);
    join_within(handle, Duration::from_secs(10), "all-down shutdown");
}

/// N concurrent connections, each pipelining M requests without waiting:
/// every request is answered exactly once with its own id, none are lost
/// to batching across connections. Runs with the pipelined engine on.
#[test]
fn concurrent_clients_pipelining_each_answered_exactly_once() {
    const CLIENTS: usize = 4;
    const REQS: u64 = 8;
    let (addr, shutdown, handle) = start_server(
        build_coordinator(),
        ServerConfig {
            batch_window_ms: 10,
            max_batch: 16,
            pipeline: true,
            ..Default::default()
        },
    );
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                // fire all requests first (pipelining), then collect
                for i in 0..REQS {
                    let id = c as u64 * 100 + i;
                    client.send(id, (c + i as usize) % 20).unwrap();
                }
                let mut ids: Vec<u64> = (0..REQS)
                    .map(|_| {
                        let resp = client.recv().unwrap();
                        assert!(
                            resp.get("error").is_none(),
                            "client {c}: unexpected error: {resp:?}"
                        );
                        assert!(resp.get("rouge_l").is_some(), "client {c}: {resp:?}");
                        resp.get("id").unwrap().as_f64().unwrap() as u64
                    })
                    .collect();
                ids.sort_unstable();
                let want: Vec<u64> = (0..REQS).map(|i| c as u64 * 100 + i).collect();
                assert_eq!(ids, want, "client {c}: every id exactly once");
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    shutdown.store(true, Ordering::Relaxed);
    join_within(handle, Duration::from_secs(10), "concurrent shutdown");
}

/// Batching boundary: with a batch window far longer than the test,
/// exactly `max_batch` pending requests must dispatch immediately — the
/// responses arrive long before the window could have expired.
#[test]
fn max_batch_pending_dispatches_without_waiting_for_window() {
    const MAX_BATCH: usize = 6;
    let (addr, shutdown, handle) = start_server(
        build_coordinator(),
        ServerConfig {
            batch_window_ms: 30_000, // would time the test out if waited on
            max_batch: MAX_BATCH,
            ..Default::default()
        },
    );
    let mut client = Client::connect(&addr).unwrap();
    let t0 = Instant::now();
    for i in 0..MAX_BATCH as u64 {
        client.send(i, i as usize).unwrap();
    }
    for _ in 0..MAX_BATCH {
        let resp = client.recv().unwrap();
        assert!(resp.get("rouge_l").is_some(), "{resp:?}");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "max_batch-full batch waited on the window: {:?}",
        t0.elapsed()
    );
    shutdown.store(true, Ordering::Relaxed);
    drop(client);
    join_within(handle, Duration::from_secs(10), "max-batch shutdown");
}

/// Pipelining is wall-clock-only: the same request sequence served with
/// `pipeline: false` and `pipeline: true` produces identical responses
/// (modeled fields; `wall_s` is machine noise and excluded).
#[test]
fn pipelined_server_matches_synchronous_responses() {
    let run = |pipeline: bool| -> Vec<String> {
        let (addr, shutdown, handle) = start_server(
            build_coordinator(),
            ServerConfig { batch_window_ms: 5, pipeline, ..Default::default() },
        );
        let mut client = Client::connect(&addr).unwrap();
        let out: Vec<String> = (0..6u64)
            .map(|i| {
                // serial requests → one single-query batch each, so the
                // slot sequence is identical across both engines
                let resp = client.request(i, (3 * i as usize) % 20).unwrap();
                let modeled: Vec<String> = ["id", "node", "dropped", "rouge_l", "sim_latency_s"]
                    .iter()
                    .map(|&k| format!("{k}={:?}", resp.get(k)))
                    .collect();
                modeled.join(",")
            })
            .collect();
        shutdown.store(true, Ordering::Relaxed);
        drop(client);
        join_within(handle, Duration::from_secs(10), "parity shutdown");
        out
    };
    assert_eq!(run(false), run(true), "pipelining changed a response");
}
