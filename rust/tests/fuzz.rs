//! Integration tests for the scenario fuzzing engine (`coedge fuzz`):
//! generator validity, sweep determinism, the injected-bug
//! find-and-shrink loop, and regressions for the fuzz-reachable bugs the
//! engine fixes pinned (zero-query bursts, capacity factor 0, NaN
//! leakage into transcripts).

use coedge_rag::config::AllocatorKind;
use coedge_rag::fuzz::oracle::{self, check_transcript_finite, OracleConfig};
use coedge_rag::fuzz::{
    case_allocator, case_cached, case_seed, generate_scenario, run_case, run_fuzz, shrink,
    FuzzConfig, GenConfig,
};
use coedge_rag::scenario::{Scenario, ScenarioEvent, TimedEvent};
use coedge_rag::workload::SkewPattern;

/// Every generated timeline is valid against the fuzz cluster shape —
/// a failing replay therefore always indicts the engine, not the input.
#[test]
fn generated_scenarios_are_valid_over_many_seeds() {
    let gc = GenConfig::default();
    for seed in 0..300 {
        let sc = generate_scenario(seed, &gc);
        sc.validate(gc.n_nodes, gc.n_domains)
            .unwrap_or_else(|e| panic!("seed {seed} generated an invalid scenario: {e:#}"));
        let slots = sc.slots.expect("generator always pins slots");
        assert!(slots >= 2, "seed {seed}: degenerate slot count {slots}");
        for te in &sc.events {
            assert!(te.slot < slots, "seed {seed}: event beyond the timeline");
        }
        // events arrive sorted by slot (parser same-slot file-order semantics)
        assert!(
            sc.events.windows(2).all(|w| w[0].slot <= w[1].slot),
            "seed {seed}: events out of slot order"
        );
    }
}

/// Same seed → same timeline, byte-for-byte; different seeds diverge.
#[test]
fn generator_is_seed_deterministic() {
    let gc = GenConfig::default();
    let a = generate_scenario(42, &gc).to_toml();
    let b = generate_scenario(42, &gc).to_toml();
    assert_eq!(a, b, "same seed must generate identical timelines");
    let distinct: std::collections::HashSet<String> =
        (0..20).map(|s| generate_scenario(s, &gc).to_toml()).collect();
    assert!(distinct.len() > 15, "20 seeds produced only {} distinct timelines", distinct.len());
}

/// Generated timelines survive the TOML round trip byte-identically —
/// what the shrinker emits as a fixture is exactly what replays.
#[test]
fn generated_scenarios_round_trip_through_toml() {
    let gc = GenConfig::default();
    for seed in 0..50 {
        let sc = generate_scenario(seed, &gc);
        let toml = sc.to_toml();
        let reparsed = Scenario::from_toml(&toml)
            .unwrap_or_else(|e| panic!("seed {seed}: emitted TOML does not reparse: {e:#}\n{toml}"));
        assert_eq!(toml, reparsed.to_toml(), "seed {seed}: round trip not a fixpoint");
    }
}

/// A production sweep is clean (zero violations) and byte-deterministic:
/// two runs write identical artifacts, and thread count never changes
/// output bytes (index-ordered collection per ADR-001).
#[test]
fn small_sweep_is_clean_and_byte_deterministic() {
    let cfg = FuzzConfig { count: 12, seed: 1, threads: 4, ..FuzzConfig::default() };
    let report = run_fuzz(&cfg);
    assert!(
        report.failures().is_empty(),
        "production sweep found violations:\n{}",
        report.failure_report()
    );
    assert_eq!(report.failure_report(), "", "clean sweep must render an empty report");

    let rerun = run_fuzz(&cfg);
    let single = run_fuzz(&FuzzConfig { threads: 1, ..cfg.clone() });
    let dir_a = temp_dir("fuzz_det_a");
    let dir_b = temp_dir("fuzz_det_b");
    let dir_c = temp_dir("fuzz_det_c");
    report.write_artifacts(&dir_a).unwrap();
    rerun.write_artifacts(&dir_b).unwrap();
    single.write_artifacts(&dir_c).unwrap();
    for name in ["BENCH_fuzz.json", "FUZZ_failures.txt"] {
        let a = std::fs::read_to_string(dir_a.join(name)).unwrap();
        let b = std::fs::read_to_string(dir_b.join(name)).unwrap();
        let c = std::fs::read_to_string(dir_c.join(name)).unwrap();
        assert_eq!(a, b, "{name}: two identical sweeps diverged");
        assert_eq!(a, c, "{name}: thread count changed output bytes");
    }
    assert!(std::fs::read_to_string(dir_a.join("FUZZ_failures.txt")).unwrap().is_empty());
}

/// A case flagged by a sweep replays identically as a single-case sweep
/// seeded with the flagged case's seed — the repro command in the
/// failure report is faithful because allocator and cache flag derive
/// from the case seed, not the sweep index.
#[test]
fn single_case_repro_matches_the_sweep() {
    let sweep = FuzzConfig { count: 6, seed: 40, threads: 1, ..FuzzConfig::default() };
    for index in 0..sweep.count {
        let from_sweep = run_case(&sweep, index);
        let seed = case_seed(sweep.seed, index);
        let repro_cfg = FuzzConfig { count: 1, seed, threads: 1, ..FuzzConfig::default() };
        let repro = run_case(&repro_cfg, 0);
        assert_eq!(from_sweep.seed, repro.seed);
        assert_eq!(from_sweep.allocator, repro.allocator, "seed {seed}");
        assert_eq!(from_sweep.cached, repro.cached, "seed {seed}");
        assert_eq!(from_sweep.allocator, case_allocator(seed));
        assert_eq!(from_sweep.cached, case_cached(seed));
        assert_eq!(from_sweep.slots, repro.slots, "seed {seed}");
        assert_eq!(from_sweep.events, repro.events, "seed {seed}");
        assert_eq!(from_sweep.queries, repro.queries, "seed {seed}");
        assert_eq!(from_sweep.violations.len(), repro.violations.len(), "seed {seed}");
    }
}

/// The injected-bug hook, end to end: raise `bug_rate` so skew-shifts
/// carry the out-of-range `frac` the validation fixes now reject, skip
/// up-front validation so the timeline reaches the engine, and prove the
/// oracle flags it and the shrinker minimizes it to a ≤3-event repro
/// whose emitted TOML is itself rejected at parse time by the fix.
#[test]
fn injected_bug_is_found_and_shrunk_to_a_tiny_repro() {
    let gc = GenConfig { bug_rate: 1.0, ..GenConfig::default() };
    let (seed, sc) = (0..500)
        .map(|s| (s, generate_scenario(s, &gc)))
        .find(|(_, sc)| {
            sc.events.iter().any(|te| {
                matches!(
                    &te.event,
                    ScenarioEvent::SkewShift { pattern: SkewPattern::Primary { frac, .. } }
                        if *frac > 1.0
                )
            })
        })
        .expect("bug_rate 1.0 must produce an out-of-range skew-shift within 500 seeds");
    let oc = OracleConfig {
        seed,
        allocator: case_allocator(seed),
        cached: case_cached(seed),
        skip_validation: true,
        swap_skew: 0,
    };
    let checked = oracle::check_scenario(&sc, &gc, &oc);
    assert!(
        !checked.violations.is_empty(),
        "seed {seed}: the oracle missed the injected out-of-range frac"
    );
    assert!(
        checked.violations.iter().any(|v| v.invariant == "run-error"),
        "seed {seed}: expected a run-error violation, got {:?}",
        checked.violations
    );

    let outcome = shrink(&sc, |cand| {
        !oracle::check_scenario(cand, &gc, &oc).violations.is_empty()
    });
    assert!(
        outcome.scenario.events.len() <= 3,
        "seed {seed}: shrink left {} events (steps {})\n{}",
        outcome.scenario.events.len(),
        outcome.steps,
        outcome.toml
    );
    // the minimal repro still fails, and its TOML is exactly the class of
    // input the frac validation fix now rejects at parse time
    assert!(!oracle::check_scenario(&outcome.scenario, &gc, &oc).violations.is_empty());
    let err = Scenario::from_toml(&outcome.toml).unwrap_err().to_string();
    assert!(err.contains("frac"), "parse error should indict frac: {err}");
}

/// The reindex grammar's injected-bug hook, end to end: plant a
/// swap-ordering bug in the engine (`swap_skew = -1` shifts the atomic
/// swap one slot early) and prove the oracle's `migration` invariant —
/// whose expected swap slot is recomputed from `modeled_build_slots`
/// independently of the engine — catches it, and the shrinker minimizes
/// the failing timeline to a tiny repro that still contains the reindex.
/// The same timeline replays clean with the bug unplanted.
#[test]
fn injected_swap_ordering_bug_is_found_and_shrunk() {
    let gc = GenConfig::default();
    // a one-slot skew only bites targets whose modeled build is ≥ 2
    // slots (for the 16-row fuzz corpus: ivf, hnsw, sharded-ivf) — scan
    // generated timelines for one where the planted bug actually fires
    let heavy = |sc: &Scenario| {
        sc.events.iter().any(|te| {
            matches!(&te.event, ScenarioEvent::Reindex { to, .. }
                if matches!(to.as_str(), "ivf" | "hnsw" | "sharded-ivf"))
        })
    };
    let found = (0..500).map(|s| (s, generate_scenario(s, &gc))).filter(|(_, sc)| heavy(sc)).find_map(
        |(seed, sc)| {
            let oc = OracleConfig {
                seed,
                allocator: case_allocator(seed),
                cached: case_cached(seed),
                skip_validation: false,
                swap_skew: -1,
            };
            let checked = oracle::check_scenario(&sc, &gc, &oc);
            checked.violations.iter().any(|v| v.invariant == "migration").then_some((sc, oc))
        },
    );
    let (sc, oc) = found.expect("500 seeds must yield a timeline where the planted swap bug fires");

    let outcome = shrink(&sc, |cand| {
        oracle::check_scenario(cand, &gc, &oc)
            .violations
            .iter()
            .any(|v| v.invariant == "migration")
    });
    assert!(
        outcome.scenario.events.len() <= 2,
        "seed {}: shrink left {} events (steps {})\n{}",
        oc.seed,
        outcome.scenario.events.len(),
        outcome.steps,
        outcome.toml
    );
    assert!(
        outcome.scenario.events.iter().any(|te| matches!(&te.event, ScenarioEvent::Reindex { .. })),
        "the minimal repro must keep the reindex:\n{}",
        outcome.toml
    );
    // unplant the bug: the exact same minimal timeline replays clean,
    // so the violation indicts the planted skew, not the grammar
    let clean = oracle::check_scenario(&outcome.scenario, &gc, &OracleConfig { swap_skew: 0, ..oc });
    assert!(
        clean.violations.is_empty(),
        "skew-0 replay of the minimal repro must pass: {:?}",
        clean.violations
    );
}

/// Regression: a `burst queries = 0` slot (an empty live slot) replays
/// with every invariant intact — finite report, valid transcript, no
/// violations. Before the fix class this PR pins, empty slots were never
/// exercised by any fixture.
#[test]
fn zero_query_burst_slot_replays_clean() {
    let gc = GenConfig::default();
    let sc = Scenario {
        name: "zero-burst".into(),
        slots: Some(3),
        trace: None,
        events: vec![
            TimedEvent { slot: 1, event: ScenarioEvent::BurstOverride { queries: 0 } },
        ],
    };
    sc.validate(gc.n_nodes, gc.n_domains).unwrap();
    for (allocator, cached) in
        [(AllocatorKind::Mab, false), (AllocatorKind::Oracle, true), (AllocatorKind::Ppo, false)]
    {
        let oc = OracleConfig { seed: 7, allocator, cached, skip_validation: false, swap_skew: 0 };
        let checked = oracle::check_scenario(&sc, &gc, &oc);
        assert!(
            checked.violations.is_empty(),
            "{allocator}: zero-query burst violated invariants: {:?}",
            checked.violations
        );
        assert_eq!(checked.slots, 3);
        assert!(!checked.transcript.is_empty());
    }
}

/// Regression: `capacity-scale` with factor 0 (or a non-finite factor)
/// is rejected — it would brick the node permanently, since `node-up`
/// cannot undo a zeroed multiplicative scale.
#[test]
fn capacity_factor_zero_is_rejected_by_a_live_coordinator() {
    use coedge_rag::coordinator::CoordinatorBuilder;
    use coedge_rag::router::capacity::CapacityModel;
    let gc = GenConfig::default();
    let cfg = coedge_rag::fuzz::generator::fuzz_experiment_config(
        &gc,
        3,
        AllocatorKind::Domain,
        false,
    );
    let caps = vec![CapacityModel { k: 6.0, b: 0.0 }; cfg.nodes.len()];
    let mut co = CoordinatorBuilder::new(cfg).capacities(caps).build().unwrap();
    let err = co.scale_capacity(0, 0.0).unwrap_err().to_string();
    assert!(err.contains("node-down"), "error should suggest node-down: {err}");
    assert!(co.scale_capacity(0, f64::NAN).is_err());
    assert!(co.scale_capacity(0, f64::INFINITY).is_err());
    co.scale_capacity(0, 0.5).unwrap();
}

/// The transcript finiteness check actually catches what it claims to:
/// the JSON writer serializes an f64 NaN as a literal `NaN`, which is
/// not JSON — crafted lines with non-finite numbers must be flagged.
#[test]
fn transcript_finiteness_check_catches_crafted_nan() {
    assert!(check_transcript_finite("{\"drop_rate\": 0.5}\n{\"lat\": [1.0, 2.0]}").is_empty());
    let bad = check_transcript_finite("{\"drop_rate\": NaN}");
    assert_eq!(bad.len(), 1, "literal NaN must fail to parse: {bad:?}");
    assert_eq!(bad[0].invariant, "finiteness");
}

/// Scratch directory for artifact byte-comparisons; unique per call so
/// parallel tests never collide.
fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("coedge_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}
