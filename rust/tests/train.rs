//! Integration tests for the training tier: farm determinism across
//! thread counts, learning-curve sanity, checkpoint persistence, the
//! `ppo-pretrained` eval-grid column, and frozen-deploy replays.

use std::path::{Path, PathBuf};

use coedge_rag::bench_harness::bench_json;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, PPO_PRETRAINED_KEY};
use coedge_rag::coordinator::allocator::FeedbackStats;
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::experiments::{eval_capacities, EvalGrid, EvalProfile};
use coedge_rag::policy::PolicyParams;
use coedge_rag::scenario::{load_fixtures, NamedScenario, ScenarioRunner};
use coedge_rag::train::{
    checkpoint, CheckpointMeta, PretrainedPpoAllocator, TrainConfig, TrainFarm,
};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

/// Unique temp path per test process so parallel test runs never collide.
fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("coedge-train-test-{}-{name}", std::process::id()))
}

/// A hand-picked curriculum out of the committed fixture set.
fn curriculum(names: &[&str]) -> Vec<NamedScenario> {
    let all = load_fixtures(&scenarios_dir()).unwrap();
    names
        .iter()
        .map(|n| {
            all.iter()
                .find(|f| &f.name == n)
                .unwrap_or_else(|| panic!("no committed fixture named {n}"))
                .clone()
        })
        .collect()
}

#[test]
fn train_is_byte_deterministic_across_thread_counts() {
    let fixtures = curriculum(&["burst_storm", "node_churn"]);
    let cfg =
        |threads| TrainConfig { replicas: 2, epochs: 2, threads, ..TrainConfig::default() };
    let a = TrainFarm::new(cfg(4), fixtures.clone()).unwrap().run().unwrap();
    let b = TrainFarm::new(cfg(1), fixtures).unwrap().run().unwrap();
    assert_eq!(
        bench_json("train", &a.to_bench_cases()),
        bench_json("train", &b.to_bench_cases()),
        "BENCH_train.json must be byte-identical at --threads 4 vs --threads 1"
    );
    assert_eq!(
        checkpoint::to_bytes(&a.params, &a.meta),
        checkpoint::to_bytes(&b.params, &b.meta),
        "the trained checkpoint must be byte-identical at --threads 4 vs --threads 1"
    );
}

#[test]
fn reward_does_not_regress_over_a_smoke_budget() {
    let farm = TrainFarm::from_dir(
        &scenarios_dir(),
        TrainConfig { replicas: 1, epochs: 3, ..TrainConfig::default() },
    )
    .unwrap();
    let report = farm.run().unwrap();
    assert_eq!(report.curve.len(), 3);
    assert!(
        report.curve.iter().all(|e| e.transitions > 0 && e.updates > 0),
        "every epoch must collect transitions and step the learner: {:?}",
        report.curve
    );
    let first = report.curve.first().unwrap().mean_reward;
    let last = report.curve.last().unwrap().mean_reward;
    assert!(
        last >= first - 0.02,
        "reward regressed over the smoke budget: {first:.4} -> {last:.4}"
    );
}

#[test]
fn smoke_checkpoint_grows_the_eval_grid_and_beats_random() {
    let farm = TrainFarm::from_dir(
        &scenarios_dir(),
        TrainConfig { replicas: 1, epochs: 3, ..TrainConfig::default() },
    )
    .unwrap();
    let ckpt = tmp_path("grid.ckpt");
    farm.run().unwrap().save_checkpoint(&ckpt).unwrap();

    let mut grid = EvalGrid::smoke();
    grid.pretrained = Some(ckpt.clone());
    let report = grid.run(&scenarios_dir(), 0).unwrap();
    std::fs::remove_file(&ckpt).ok();

    assert_eq!(report.cells.len(), grid.num_cells(), "pretrained column adds one allocator");
    let mean_rouge = |key: &str| {
        let rows: Vec<f64> = report
            .cells
            .iter()
            .filter(|c| c.allocator == key)
            .map(|c| c.metrics.rouge_l)
            .collect();
        assert_eq!(
            rows.len(),
            grid.datasets.len() * grid.scenarios.len(),
            "one {key} cell per (dataset, scenario) row"
        );
        rows.iter().sum::<f64>() / rows.len() as f64
    };
    let pretrained = mean_rouge(PPO_PRETRAINED_KEY);
    let random = mean_rouge(AllocatorKind::Random.as_str());
    assert!(
        pretrained >= random,
        "pretrained policy (R-L {pretrained:.4}) must beat random routing (R-L {random:.4})"
    );
}

#[test]
fn checkpoints_round_trip_bitwise_through_files() {
    let mut params = PolicyParams::init(4, 7);
    params.step = 5;
    params.adam_m[0][0] = 0.25;
    params.adam_v[3][1] = 1.5;
    let meta = CheckpointMeta { dataset: "domainqa".into(), num_domains: 6 };
    let p1 = tmp_path("rt1.ckpt");
    let p2 = tmp_path("rt2.ckpt");
    checkpoint::save(&p1, &params, &meta).unwrap();
    let ck = checkpoint::load(&p1).unwrap();
    assert_eq!(ck.meta, meta);
    checkpoint::save(&p2, &ck.params, &ck.meta).unwrap();
    let (a, b) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(a, b, "save → load → save must reproduce the file bitwise");
}

#[test]
fn corrupt_and_mismatched_checkpoints_error_descriptively() {
    let params = PolicyParams::init(3, 9);
    let meta = CheckpointMeta { dataset: "domainqa".into(), num_domains: 6 };
    let path = tmp_path("bad.ckpt");
    checkpoint::save(&path, &params, &meta).unwrap();
    let good = std::fs::read(&path).unwrap();

    // truncation names the file and the field being read
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    let err = checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
    assert!(err.contains("bad.ckpt"), "{err}");

    // a flipped payload byte trips the checksum
    let mut corrupt = good.clone();
    *corrupt.last_mut().unwrap() ^= 0xFF;
    std::fs::write(&path, &corrupt).unwrap();
    let err = checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    // a foreign file is rejected at the magic, not parsed as garbage
    let mut wrong = good.clone();
    wrong[0] ^= 0xFF;
    std::fs::write(&path, &wrong).unwrap();
    let err = checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // dimension pinning: a 3-action policy cannot drive a 4-node cluster,
    // and a 6-domain policy cannot serve an 8-domain dataset
    std::fs::write(&path, &good).unwrap();
    let err = PretrainedPpoAllocator::load(&path, 4, 6, 1).unwrap_err().to_string();
    assert!(err.contains("n_actions"), "{err}");
    let err = PretrainedPpoAllocator::load(&path, 3, 8, 1).unwrap_err().to_string();
    assert!(err.contains("num_domains"), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn frozen_pretrained_allocator_replays_byte_identically() {
    let fixtures = curriculum(&["node_churn"]);
    let farm = TrainFarm::new(
        TrainConfig { replicas: 1, epochs: 1, ..TrainConfig::default() },
        fixtures.clone(),
    )
    .unwrap();
    let ckpt = tmp_path("frozen.ckpt");
    farm.run().unwrap().save_checkpoint(&ckpt).unwrap();

    let replay = || {
        let p = EvalProfile::smoke();
        let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        cfg.qa_per_domain = p.qa_per_domain;
        cfg.docs_per_domain = p.docs_per_domain;
        cfg.queries_per_slot = p.queries_per_slot;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = p.corpus_docs;
        }
        cfg.allocator_override = Some(PPO_PRETRAINED_KEY.to_string());
        cfg.checkpoint = Some(ckpt.clone());
        let caps = eval_capacities(&cfg);
        let mut co = CoordinatorBuilder::new(cfg).capacities(caps).build().unwrap();
        ScenarioRunner::new(fixtures[0].scenario.clone()).run(&mut co).unwrap()
    };
    let a = replay();
    let b = replay();
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(
        a.transcript.to_jsonl(),
        b.transcript.to_jsonl(),
        "a frozen policy must replay a fixture byte-identically"
    );
    assert!(
        a.reports.iter().all(|r| r.feedback == FeedbackStats::default()),
        "the coordinator must skip the feedback phase for a frozen allocator"
    );
}
