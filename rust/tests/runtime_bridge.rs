//! Integration: the AOT HLO artifacts (JAX/Pallas-authored, PJRT-executed)
//! must match the pure-Rust mirror numerically.
//!
//! Requires `make artifacts`. These tests validate the whole three-layer
//! bridge: Pallas kernel → JAX graph → HLO text → PJRT execute ≡ Rust ref.

use coedge_rag::policy::grad;
use coedge_rag::policy::mlp;
use coedge_rag::policy::params::{PolicyParams, EMBED_DIM};
use coedge_rag::runtime::{PolicyRuntime, UpdateBatch};
use coedge_rag::util::rng::Rng;

fn runtime() -> Option<PolicyRuntime> {
    let dir = PolicyRuntime::default_dir();
    match PolicyRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (no artifacts: {e}); run `make artifacts`");
            None
        }
    }
}

fn rand_x(rng: &mut Rng, rows: usize) -> Vec<f32> {
    (0..rows * EMBED_DIM).map(|_| rng.normal() as f32 * 0.4).collect()
}

#[test]
fn hlo_forward_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    for &n in &[3usize, 4, 6] {
        let params = PolicyParams::init(n, 1234 + n as u64);
        let mut rng = Rng::new(55 + n as u64);
        for &rows in &[1usize, 5, 64, 100] {
            let x = rand_x(&mut rng, rows);
            let hlo = rt.forward(&params, &x, rows).expect("hlo fwd");
            let refr = mlp::forward(&params, &x, rows);
            assert_eq!(hlo.len(), refr.len());
            for (i, (a, b)) in hlo.iter().zip(&refr).enumerate() {
                assert!(
                    (a - b).abs() < 2e-4,
                    "n={n} rows={rows} idx={i}: hlo={a} rust={b}"
                );
            }
            // rows are valid simplexes
            for r in 0..rows {
                let s: f32 = hlo[r * n..(r + 1) * n].iter().sum();
                assert!((s - 1.0).abs() < 1e-4);
            }
        }
    }
}

#[test]
fn hlo_update_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let n = 4usize;
    let mut rng = Rng::new(77);
    let rows = 256; // exactly the compiled update batch
    let x = rand_x(&mut rng, rows);

    let mut p_hlo = PolicyParams::init(n, 999);
    let mut p_ref = p_hlo.clone();

    let probs = mlp::forward(&p_ref, &x, rows);
    let mut batch = UpdateBatch::default();
    batch.x = x.clone();
    for r in 0..rows {
        let row: Vec<f64> = probs[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let a = rng.sample_weighted(&row);
        batch.actions.push(a);
        batch.old_logp.push(probs[r * n + a].max(1e-12).ln());
        batch.rewards.push(rng.normal() as f32);
    }

    let s_hlo = rt.update(&mut p_hlo, &batch).expect("hlo update");
    let s_ref = grad::update_host(&mut p_ref, &batch);

    assert!(
        (s_hlo.loss - s_ref.loss).abs() < 5e-4,
        "loss hlo={} ref={}",
        s_hlo.loss,
        s_ref.loss
    );
    assert!(
        (s_hlo.entropy - s_ref.entropy).abs() < 5e-4,
        "entropy hlo={} ref={}",
        s_hlo.entropy,
        s_ref.entropy
    );
    // parameters after one Adam step must agree elementwise
    for ti in 0..p_hlo.tensors.len() {
        for (j, (a, b)) in p_hlo.tensors[ti].iter().zip(&p_ref.tensors[ti]).enumerate() {
            assert!(
                (a - b).abs() < 5e-4,
                "tensor {ti} idx {j}: hlo={a} ref={b}"
            );
        }
    }
}

#[test]
fn hlo_update_with_padding_matches_reference() {
    let Some(rt) = runtime() else { return };
    let n = 3usize;
    let mut rng = Rng::new(88);
    let rows = 100; // < compiled batch 256 -> exercises masking
    let x = rand_x(&mut rng, rows);
    let mut p_hlo = PolicyParams::init(n, 31);
    let mut p_ref = p_hlo.clone();
    let probs = mlp::forward(&p_ref, &x, rows);
    let mut batch = UpdateBatch::default();
    batch.x = x;
    for r in 0..rows {
        let a = r % n;
        batch.actions.push(a);
        batch.old_logp.push(probs[r * n + a].max(1e-12).ln());
        batch.rewards.push(if a == 0 { 1.0 } else { -0.5 });
    }
    let s_hlo = rt.update(&mut p_hlo, &batch).expect("hlo update");
    let s_ref = grad::update_host(&mut p_ref, &batch);
    assert!(
        (s_hlo.loss - s_ref.loss).abs() < 1e-3,
        "loss hlo={} ref={}",
        s_hlo.loss,
        s_ref.loss
    );
    for ti in 0..p_hlo.tensors.len() {
        for (a, b) in p_hlo.tensors[ti].iter().zip(&p_ref.tensors[ti]) {
            assert!((a - b).abs() < 1e-3, "tensor {ti}: hlo={a} ref={b}");
        }
    }
}

#[test]
fn pjrt_policy_learns_online() {
    // End-to-end sanity: PPO through the PJRT backend learns a separable
    // cluster→node mapping (the same task the Reference backend passes).
    use coedge_rag::policy::ppo::{Backend, OnlinePolicy, PpoConfig};
    let Some(rt) = runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let n = 3;
    let cfg = PpoConfig { buffer_threshold: 64, epochs: 6, explore_eps: 0.1, ..Default::default() };
    let mut pol = OnlinePolicy::new(n, cfg, Backend::Pjrt(rt));
    let mut rng = Rng::new(7);
    let span = EMBED_DIM / n;
    let mut correct = 0usize;
    let mut total = 0usize;
    for step in 0..1200 {
        let c = rng.below(n);
        let mut x = vec![0f32; EMBED_DIM];
        for i in 0..span {
            x[c * span + i] = 1.0 + 0.1 * rng.normal() as f32;
        }
        coedge_rag::text::embed::l2_normalize(&mut x);
        let probs = pol.probs(&x, 1).unwrap();
        let (a, logp) = pol.sample_action(&probs);
        let fb = if a == c { 1.0 } else { -1.0 };
        pol.record(&x, a, logp, fb).unwrap();
        if step >= 900 {
            total += 1;
            if a == c {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(acc > 0.55, "pjrt online accuracy={acc:.3}");
}
