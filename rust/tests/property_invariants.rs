//! Randomized property tests over scheduler + metric invariants.
//!
//! proptest is not available offline; these tests implement the same
//! discipline with the crate's own deterministic RNG: hundreds of random
//! cases per property, with the failing seed printed on assertion failure.

use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;
use coedge_rag::corpus::partition::{partition_corpus, NodeCorpusSpec};
use coedge_rag::corpus::{build_dataset, domainqa_spec};
use coedge_rag::fuzz::oracle;
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::scenario::ScenarioEvent;
use coedge_rag::workload::SkewPattern;
use coedge_rag::intranode::latfit::LatencyProfiler;
use coedge_rag::intranode::solver::{solve_node, SolverInput};
use coedge_rag::llmsim::gpu::GpuState;
use coedge_rag::llmsim::latency::LatencyGroundTruth;
use coedge_rag::llmsim::model::standard_pool;
use coedge_rag::metrics::Evaluator;
use coedge_rag::router::inter::inter_node_schedule;
use coedge_rag::text::tokenizer::tokenize;
use coedge_rag::util::rng::Rng;

/// Random probability rows (each sums to 1).
fn random_probs(rng: &mut Rng, b: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(b * n);
    for _ in 0..b {
        let row = rng.dirichlet(&vec![0.5; n]);
        out.extend(row.iter().map(|&x| x as f32));
    }
    out
}

#[test]
fn prop_inter_node_conservation_and_capacity() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..300 {
        let n = 2 + rng.below(5);
        let b = rng.below(400);
        let probs = random_probs(&mut rng, b, n);
        let caps: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 200.0)).collect();
        let res = inter_node_schedule(&probs, n, &caps, &mut rng);

        // conservation
        assert_eq!(res.assignment.len(), b, "case {case}");
        assert_eq!(res.counts.iter().sum::<usize>(), b, "case {case}");
        // proportions form a distribution (when b > 0)
        if b > 0 {
            let s: f64 = res.proportions.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "case {case}: sum={s}");
        }
        // assignments in range
        assert!(res.assignment.iter().all(|&a| a < n), "case {case}");
        // per-node counts never exceed the (scaled) capacity by more
        // than 1 (the final sample when all nodes saturate)
        for (j, &c) in res.counts.iter().enumerate() {
            assert!(
                (c as f64) <= res.capacities[j] + 1.0,
                "case {case}: node {j} count {c} > cap {}",
                res.capacities[j]
            );
        }
        // scaled capacities preserve ratios under overload
        let total: f64 = caps.iter().sum();
        if b as f64 > total && total > 0.0 {
            for j in 0..n {
                for k in 0..n {
                    if caps[k] > 1e-9 && res.capacities[k] > 1e-9 {
                        let r1 = caps[j] / caps[k];
                        let r2 = res.capacities[j] / res.capacities[k];
                        assert!((r1 - r2).abs() < 1e-6, "case {case}");
                    }
                }
            }
        }
    }
}

/// Scheduling conservation under random scenario churn: across random
/// seeds, allocators and random mid-run events (node down/up, capacity
/// scaling, skew shifts, live reindex migrations), every slot must
/// (a) account every sampled query exactly once and in slot order,
/// (b) emit proportions that sum to 1 whenever any node is live and the
/// slot is nonempty (all-zero otherwise), and (c) never route a query to
/// a down node. Exactly-once conservation must hold *mid-migration* too
/// — a node with a background rebuild in flight keeps serving — and the
/// migration swap contract is checked per slot by the oracle's
/// `MigrationTracker` (a reindex on a down node must be rejected naming
/// `node-up`).
///
/// The checks themselves live in `coedge_rag::fuzz::oracle` — this test
/// and the fuzzer consume the same functions, so the two suites cannot
/// drift apart.
#[test]
fn prop_scheduling_conservation_under_random_churn() {
    let kinds = [
        AllocatorKind::Random,
        AllocatorKind::Mab,
        AllocatorKind::Oracle,
        AllocatorKind::Ppo,
    ];
    for (case, &allocator) in kinds.iter().enumerate() {
        let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        cfg.seed = 9000 + case as u64;
        cfg.qa_per_domain = 10;
        cfg.docs_per_domain = 15;
        cfg.allocator = allocator;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = 20;
        }
        let mut co = CoordinatorBuilder::new(cfg)
            .capacities(vec![CapacityModel { k: 3.0, b: 0.0 }; 4])
            .build()
            .unwrap();
        let mut rng = Rng::new(0x5CE0 ^ case as u64);
        let mut mig = oracle::MigrationTracker::new();
        for slot in 0..8 {
            if rng.chance(0.6) {
                let node = rng.below(4);
                let event = match rng.below(5) {
                    0 => ScenarioEvent::NodeDown { node },
                    1 => ScenarioEvent::NodeUp { node },
                    2 => ScenarioEvent::CapacityScale {
                        node,
                        factor: rng.range_f64(0.2, 2.0),
                    },
                    3 => ScenarioEvent::SkewShift {
                        pattern: SkewPattern::Primary {
                            domain: rng.below(6),
                            frac: rng.range_f64(0.3, 0.9),
                        },
                    },
                    _ => {
                        let all = coedge_rag::vecdb::IndexKind::ALL;
                        ScenarioEvent::Reindex {
                            node,
                            to: all[rng.below(all.len())].as_str().to_string(),
                            shards: None,
                            rescore_factor: None,
                        }
                    }
                };
                if let ScenarioEvent::Reindex { node, to, .. } = &event {
                    let from = co.nodes[*node].index_kind.clone();
                    let rows = co.nodes[*node].corpus_size();
                    let down = !co.active[*node];
                    match co.apply_event(&event) {
                        Ok(()) => {
                            assert!(!down, "{allocator} slot {slot}: down-node reindex accepted");
                            mig.note_begin(*node, &from, to.parse().unwrap(), slot, rows);
                        }
                        Err(e) => {
                            assert!(down, "{allocator} slot {slot}: live reindex failed: {e:#}");
                            assert!(
                                format!("{e:#}").contains("node-up"),
                                "{allocator} slot {slot}: rejection must name node-up: {e:#}"
                            );
                        }
                    }
                } else {
                    co.apply_event(&event).unwrap();
                }
            }
            let b = rng.below(80);
            let qids = co.sample_queries(b).unwrap();
            let r = co.run_slot(&qids).unwrap();
            let tag = format!("{allocator} slot {slot}");

            // (a) conservation + order, (b) proportions distribution,
            // (c) routing — plus finiteness of every reported number and
            // the modeled migration-swap contract
            let mut violations = oracle::check_conservation(slot, &qids, &r);
            violations.extend(oracle::check_proportions(slot, &r));
            violations.extend(oracle::check_routing(slot, &r));
            violations.extend(oracle::check_report_finite(slot, &r));
            violations.extend(mig.check_slot(slot, &r));
            assert!(violations.is_empty(), "{tag}: {violations:?}");
        }
    }
}

/// A `reindex` targeting a down node is rejected up front with an error
/// naming the `node-up` recovery path, starts no migration, and the same
/// event succeeds immediately once the node is brought back.
#[test]
fn reindex_on_down_node_is_rejected_naming_node_up() {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.seed = 77;
    cfg.qa_per_domain = 10;
    cfg.docs_per_domain = 15;
    cfg.allocator = AllocatorKind::Oracle;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 20;
    }
    let mut co = CoordinatorBuilder::new(cfg)
        .capacities(vec![CapacityModel { k: 3.0, b: 0.0 }; 4])
        .build()
        .unwrap();
    let reindex = ScenarioEvent::Reindex {
        node: 1,
        to: "quantized-flat".into(),
        shards: None,
        rescore_factor: None,
    };
    co.apply_event(&ScenarioEvent::NodeDown { node: 1 }).unwrap();
    let err = co.apply_event(&reindex).unwrap_err().to_string();
    assert!(err.contains("node-up"), "rejection must name the recovery path: {err}");
    assert!(err.contains("node 1"), "{err}");
    assert!(!co.nodes[1].migrating(), "a rejected reindex must not start a migration");
    co.apply_event(&ScenarioEvent::NodeUp { node: 1 }).unwrap();
    co.apply_event(&reindex).unwrap();
    assert!(co.nodes[1].migrating());
    assert_eq!(co.nodes[1].migration_label().unwrap(), "flat->quantized-flat:1");
}

/// Cache-safety property: under random interleavings of queries and
/// corpus-ingest / skew-shift events, (a) a cached answer is never served
/// for a (node, domain) whose corpus changed after the entry was written,
/// (b) every cached answer's quality is bitwise equal to the serve that
/// wrote the entry (threshold = 1.0 ⇒ exact duplicates only), and (c) no
/// entry written before a skew-shift survives its flush.
///
/// The bookkeeping and checks live in `fuzz::oracle::StaleTracker` — the
/// fuzzer replays the same logic against generated timelines.
#[test]
fn prop_cache_never_serves_stale_answers() {
    use coedge_rag::config::CacheSpec;

    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.seed = 0xCACE;
    cfg.qa_per_domain = 10;
    cfg.docs_per_domain = 20;
    cfg.allocator = AllocatorKind::Oracle;
    cfg.cache = CacheSpec { kind: "lru".into(), capacity_mb: 4, ..CacheSpec::default() };
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 25;
        n.cache = cfg.cache.clone();
    }
    let mut co = CoordinatorBuilder::new(cfg)
        .capacities(vec![CapacityModel { k: 30.0, b: 0.0 }; 4])
        .build()
        .unwrap();
    let mut rng = Rng::new(0x57A1E);
    let mut tracker = oracle::StaleTracker::new();
    let mut hits = 0usize;
    for slot in 0..24 {
        if rng.chance(0.35) {
            if rng.chance(0.5) {
                let (node, domain) = (rng.below(4), rng.below(6));
                let added = co.ingest_corpus(node, domain, 1 + rng.below(6)).unwrap();
                tracker.note_ingest(node, domain, slot, added);
            } else {
                co.apply_event(&ScenarioEvent::SkewShift {
                    pattern: SkewPattern::Primary {
                        domain: rng.below(6),
                        frac: rng.range_f64(0.4, 0.9),
                    },
                })
                .unwrap();
                tracker.note_skew_flush(slot);
            }
        }
        let qids = co.sample_queries(20 + rng.below(30)).unwrap();
        let r = co.run_slot(&qids).unwrap();
        let mut violations = oracle::check_conservation(slot, &qids, &r);
        violations.extend(tracker.check_slot(slot, &r, &co.ds));
        assert!(violations.is_empty(), "slot {slot}: {violations:?}");
        hits += r.outcomes.iter().filter(|o| o.cached).count();
    }
    assert!(hits > 0, "property vacuous: the run never hit the answer cache");
}

#[test]
fn prop_solver_feasibility() {
    let pool = standard_pool();
    let prof = LatencyProfiler::default();
    let mut rng = Rng::new(0x50CCE5);
    // fits are expensive; build once per gpu-speed class
    let gt1 = LatencyGroundTruth::new(1.0);
    let gt2 = LatencyGroundTruth::new(1.3);
    let fits: Vec<Vec<_>> = pool
        .iter()
        .map(|m| vec![prof.fit_production(&gt1, m, 1), prof.fit_production(&gt2, m, 2)])
        .collect();
    for case in 0..60 {
        let gpus: Vec<GpuState> = (0..1 + rng.below(2)).map(|_| GpuState::new(1.0)).collect();
        let queries = rng.below(3000);
        let budget = rng.range_f64(0.5, 40.0);
        let quality: Vec<f64> = (0..3).map(|_| rng.range_f64(0.5, 1.5)).collect();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &quality,
            queries,
            budget_s: budget,
            mem_cap: 1.0,
        });
        // every query accounted for
        assert_eq!(plan.total_assigned() + plan.overflow, queries, "case {case}");
        for (k, g) in plan.gpus.iter().enumerate() {
            // memory feasible
            let mem: f64 = g.assignments.iter().map(|a| a.mem).sum();
            assert!(mem <= 1.0 + 1e-9, "case {case} gpu {k}: mem {mem}");
            for a in &g.assignments {
                assert!(
                    a.mem >= pool[a.model_idx].min_mem - 1e-9,
                    "case {case}: below min mem"
                );
            }
            // reload time consistent with the GPU's (empty) prior state:
            // every deployed model is a fresh load
            assert!(g.reload_s >= 0.0);
        }
    }
}

#[test]
fn prop_partition_no_dups_and_domain_bias() {
    let ds = build_dataset(&domainqa_spec(10, 50), 9);
    let mut rng = Rng::new(0xBADD);
    for case in 0..40 {
        let n_nodes = 2 + rng.below(3);
        let specs: Vec<NodeCorpusSpec> = (0..n_nodes)
            .map(|i| {
                let primaries: Vec<usize> = vec![i % 6, (i + 1) % 6, (i + 2) % 6];
                NodeCorpusSpec::dual(80 + rng.below(120), 6, &primaries, rng.range_f64(0.05, 0.6))
            })
            .collect();
        let overlap = rng.range_f64(0.0, 0.8);
        let parts = partition_corpus(&ds, &specs, overlap, case as u64);
        for (ni, docs) in parts.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for &d in docs {
                assert!(d < ds.documents.len());
                assert!(seen.insert(d), "case {case} node {ni}: dup doc");
            }
            // primaries hold more docs than non-primaries on average
            let primaries = &specs[ni];
            let in_primary = docs
                .iter()
                .filter(|&&d| {
                    let dom = ds.documents[d].domain;
                    primaries.domain_weights[dom] > primaries.domain_weights.iter().sum::<f64>() / 8.0
                })
                .count();
            assert!(in_primary * 2 >= docs.len(), "case {case} node {ni}");
        }
    }
}

#[test]
fn prop_metric_ranges_and_identity() {
    let ev = Evaluator::default();
    let mut rng = Rng::new(0x3E7);
    let vocab: Vec<String> = (0..40).map(|i| format!("tok{i}")).collect();
    for case in 0..200 {
        let len_a = 1 + rng.below(40);
        let len_b = 1 + rng.below(40);
        let a: Vec<String> = (0..len_a).map(|_| vocab[rng.below(vocab.len())].clone()).collect();
        let b: Vec<String> = (0..len_b).map(|_| vocab[rng.below(vocab.len())].clone()).collect();
        let s = ev.score_tokens(&a, &b);
        for (name, v) in [
            ("rouge1", s.rouge1),
            ("rouge2", s.rouge2),
            ("rougeL", s.rouge_l),
            ("bleu4", s.bleu4),
            ("meteor", s.meteor),
            ("bert", s.bert_score),
        ] {
            assert!((0.0..=1.0 + 1e-9).contains(&v), "case {case} {name}={v}");
        }
        // identity scores dominate
        let id = ev.score_tokens(&a, &a);
        assert!(id.rouge_l >= s.rouge_l - 1e-9, "case {case}");
        assert!(id.rouge_l > 0.999);
        // rouge-L bounded by rouge-1 (LCS is a common subsequence)
        assert!(s.rouge_l <= s.rouge1 + 1e-9, "case {case}");
        // feedback is monotone in its weights
        let f1 = ev.feedback(&a, &b, 1.0, 0.0);
        let f2 = ev.feedback(&a, &b, 1.0, 0.5);
        assert!(f2 >= f1 - 1e-9, "case {case}");
    }
}

#[test]
fn prop_tokenize_idempotent_on_own_output() {
    let mut rng = Rng::new(0x70CE);
    let corpus = build_dataset(&domainqa_spec(5, 10), 4);
    for _ in 0..50 {
        let doc = &corpus.documents[rng.below(corpus.documents.len())];
        let text = doc.text();
        let t1 = tokenize(&text);
        let t2 = tokenize(&t1.join(" "));
        assert_eq!(t1, t2);
    }
}

#[test]
fn prop_gpu_reconfig_properties() {
    let mut rng = Rng::new(0x96);
    let names = ["a", "b", "c"];
    let lt = |n: &str| match n {
        "a" => 1.0,
        "b" => 2.0,
        _ => 3.0,
    };
    for case in 0..200 {
        let mut gpu = GpuState::new(1.0);
        let mut config = std::collections::BTreeMap::new();
        for &n in &names {
            if rng.chance(0.6) {
                config.insert(n.to_string(), rng.range_f64(0.1, 0.5));
            }
        }
        gpu.apply(config.clone());
        // same config -> zero reconfig time
        assert_eq!(gpu.reconfig_time(&config, &lt), 0.0, "case {case}");
        // a pure unload is free
        let mut smaller = config.clone();
        let removed = smaller.keys().next().cloned();
        if let Some(k) = removed {
            smaller.remove(&k);
            assert_eq!(gpu.reconfig_time(&smaller, &lt), 0.0, "case {case}");
        }
        // cost is bounded by total load time of the target set
        let mut target = std::collections::BTreeMap::new();
        for &n in &names {
            if rng.chance(0.5) {
                target.insert(n.to_string(), rng.range_f64(0.1, 0.9));
            }
        }
        let cost = gpu.reconfig_time(&target, &lt);
        let bound: f64 = target.keys().map(|k| lt(k)).sum();
        assert!(cost <= bound + 1e-9, "case {case}");
        assert!(cost >= 0.0);
    }
}
