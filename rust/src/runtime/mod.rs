//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`). The artifacts are produced once by
//! `make artifacts` (python/compile/aot.py); this module is the only place
//! where the Layer-3 coordinator touches XLA.
//!
//! Executables are compiled lazily per (kind, n_actions, batch) and cached.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::policy::params::{param_shapes, PolicyParams, EMBED_DIM, NUM_TENSORS};
use crate::util::json::Json;

/// Parsed `artifacts/manifest.json` entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: String,
    pub n_actions: usize,
    pub batch: usize,
    pub file: String,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub embed_dim: usize,
    pub artifacts: Vec<ArtifactInfo>,
    pub learning_rate: f64,
    pub clip_eps: f64,
    pub entropy_beta: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let hp = v.get("hyperparams").ok_or_else(|| anyhow!("no hyperparams"))?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("no artifacts[]"))?
            .iter()
            .map(|a| {
                Ok(ArtifactInfo {
                    name: a.get("name").and_then(|x| x.as_str()).unwrap_or_default().into(),
                    kind: a.get("kind").and_then(|x| x.as_str()).unwrap_or_default().into(),
                    n_actions: a.get("n_actions").and_then(|x| x.as_usize()).unwrap_or(0),
                    batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                    file: a.get("file").and_then(|x| x.as_str()).unwrap_or_default().into(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            embed_dim: v.get("embed_dim").and_then(|x| x.as_usize()).unwrap_or(0),
            artifacts: arts,
            learning_rate: hp.get("learning_rate").and_then(|x| x.as_f64()).unwrap_or(3e-4),
            clip_eps: hp.get("clip_eps").and_then(|x| x.as_f64()).unwrap_or(0.02),
            entropy_beta: hp.get("entropy_beta").and_then(|x| x.as_f64()).unwrap_or(0.01),
        })
    }
}

/// The PPO update batch the runtime executes (padded to the artifact's
/// compiled batch size internally).
#[derive(Clone, Debug, Default)]
pub struct UpdateBatch {
    /// Row-major [rows × EMBED_DIM] embeddings.
    pub x: Vec<f32>,
    /// Chosen node per row.
    pub actions: Vec<usize>,
    /// Batch-standardized rewards (Eq. 10).
    pub rewards: Vec<f32>,
    /// log π_old(a|s) recorded at decision time.
    pub old_logp: Vec<f32>,
}

impl UpdateBatch {
    pub fn rows(&self) -> usize {
        self.actions.len()
    }
}

/// Result of one update execution.
#[derive(Clone, Copy, Debug)]
pub struct UpdateStats {
    pub loss: f32,
    pub entropy: f32,
}

/// PJRT-backed policy runtime.
///
/// All PJRT objects (client, executables, buffers) are touched only while
/// holding `pjrt` — the `xla` crate wraps them in non-atomic `Rc`s, so the
/// mutex guarantees no concurrent refcount mutation. Host-side state
/// (`manifest`, `dir`) is immutable after construction. Under that
/// invariant the type is safe to share across threads:
pub struct PolicyRuntime {
    dir: PathBuf,
    manifest: Manifest,
    pjrt: Mutex<PjrtState>,
}

struct PjrtState {
    client: xla::PjRtClient,
    // (kind, n, batch) -> compiled executable
    cache: HashMap<(String, usize, usize), xla::PjRtLoadedExecutable>,
}

// SAFETY: see the struct docs — every access to the Rc-backed PJRT
// wrappers goes through the `pjrt` mutex and no Rc handle escapes a
// locked section (outputs are converted to host `Vec<f32>` before the
// guard drops). The underlying PJRT CPU client is itself thread-safe.
unsafe impl Send for PolicyRuntime {}
unsafe impl Sync for PolicyRuntime {}

impl PolicyRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<PolicyRuntime> {
        let manifest = Manifest::load(dir)?;
        if manifest.embed_dim != EMBED_DIM {
            bail!(
                "artifact embed_dim {} != runtime EMBED_DIM {}",
                manifest.embed_dim,
                EMBED_DIM
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PolicyRuntime {
            dir: dir.to_path_buf(),
            manifest,
            pjrt: Mutex::new(PjrtState { client, cache: HashMap::new() }),
        })
    }

    /// Default artifact directory (`$COEDGE_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var("COEDGE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pick the best-fitting compiled forward batch size for `rows`.
    fn pick_fwd_batch(&self, n: usize, rows: usize) -> Result<usize> {
        let mut batches: Vec<usize> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "policy_fwd" && a.n_actions == n)
            .map(|a| a.batch)
            .collect();
        if batches.is_empty() {
            bail!("no policy_fwd artifact for n_actions={n} (have: {:?})",
                  self.manifest.artifacts.iter().map(|a| a.n_actions).collect::<Vec<_>>());
        }
        batches.sort_unstable();
        // smallest batch >= rows, else the largest available
        Ok(*batches.iter().find(|&&b| b >= rows).unwrap_or(batches.last().unwrap()))
    }

    /// Look up (or lazily compile) an executable. Must be called with the
    /// `pjrt` guard held; the returned reference lives inside the guard.
    fn executable<'a>(
        state: &'a mut PjrtState,
        manifest: &Manifest,
        dir: &Path,
        kind: &str,
        n: usize,
        batch: usize,
    ) -> Result<&'a xla::PjRtLoadedExecutable> {
        let key = (kind.to_string(), n, batch);
        if !state.cache.contains_key(&key) {
            let info = manifest
                .artifacts
                .iter()
                .find(|a| a.kind == kind && a.n_actions == n && a.batch == batch)
                .ok_or_else(|| anyhow!("no artifact {kind} n={n} b={batch}"))?;
            let path = dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("load {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = state
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", info.name))?;
            state.cache.insert(key.clone(), exe);
        }
        Ok(state.cache.get(&key).unwrap())
    }

    /// Convert host parameters to literals in artifact input order.
    fn param_literals(params: &PolicyParams, tensors: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
        let shapes = param_shapes(params.n_actions);
        tensors
            .iter()
            .zip(shapes.iter())
            .map(|(t, &(r, c))| {
                let lit = xla::Literal::vec1(t);
                let dims: Vec<i64> = if r == 1 {
                    vec![c as i64] // rank-1 tensors (biases, ln params)
                } else {
                    vec![r as i64, c as i64]
                };
                lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect()
    }

    /// Forward pass: returns row-major `[rows × n_actions]` probabilities.
    /// Pads to the compiled batch and slices the result; for large inputs
    /// runs multiple executions.
    pub fn forward(&self, params: &PolicyParams, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        assert_eq!(x.len(), rows * EMBED_DIM);
        let n = params.n_actions;
        if rows == 0 {
            return Ok(Vec::new());
        }
        let batch = self.pick_fwd_batch(n, rows)?;
        let mut guard = self.pjrt.lock().unwrap();
        let exe = Self::executable(&mut guard, &self.manifest, &self.dir, "policy_fwd", n, batch)?;
        let plits = Self::param_literals(params, &params.tensors)?;

        let mut out = Vec::with_capacity(rows * n);
        let mut done = 0;
        while done < rows {
            let take = (rows - done).min(batch);
            let mut chunk = vec![0f32; batch * EMBED_DIM];
            chunk[..take * EMBED_DIM]
                .copy_from_slice(&x[done * EMBED_DIM..(done + take) * EMBED_DIM]);
            let xlit = xla::Literal::vec1(&chunk)
                .reshape(&[batch as i64, EMBED_DIM as i64])
                .map_err(|e| anyhow!("reshape x: {e:?}"))?;
            let mut inputs: Vec<&xla::Literal> = plits.iter().collect();
            inputs.push(&xlit);
            let result = exe
                .execute::<&xla::Literal>(&inputs)
                .map_err(|e| anyhow!("execute fwd: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let probs_lit = lit.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            let probs: Vec<f32> = probs_lit.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            out.extend_from_slice(&probs[..take * n]);
            done += take;
        }
        Ok(out)
    }

    /// Execute one PPO update (paper Eq. 10–11) in place on `params`.
    ///
    /// Batches larger than the compiled size are split into chained
    /// updates; smaller ones are zero-padded with mask=0.
    pub fn update(&self, params: &mut PolicyParams, batch: &UpdateBatch) -> Result<UpdateStats> {
        let n = params.n_actions;
        let rows = batch.rows();
        assert_eq!(batch.x.len(), rows * EMBED_DIM);
        let info_batch = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.kind == "ppo_update" && a.n_actions == n)
            .map(|a| a.batch)
            .ok_or_else(|| anyhow!("no ppo_update artifact for n={n}"))?;
        let mut guard = self.pjrt.lock().unwrap();
        let exe =
            Self::executable(&mut guard, &self.manifest, &self.dir, "ppo_update", n, info_batch)?;

        let mut stats = UpdateStats { loss: 0.0, entropy: 0.0 };
        let mut done = 0;
        let mut chunks = 0;
        while done < rows {
            let take = (rows - done).min(info_batch);
            let b = info_batch;
            let mut x = vec![0f32; b * EMBED_DIM];
            x[..take * EMBED_DIM]
                .copy_from_slice(&batch.x[done * EMBED_DIM..(done + take) * EMBED_DIM]);
            let mut onehot = vec![0f32; b * n];
            let mut reward = vec![0f32; b];
            let mut old_logp = vec![0f32; b];
            let mut mask = vec![0f32; b];
            for i in 0..take {
                onehot[i * n + batch.actions[done + i]] = 1.0;
                reward[i] = batch.rewards[done + i];
                old_logp[i] = batch.old_logp[done + i];
                mask[i] = 1.0;
            }
            params.step += 1;

            let plits = Self::param_literals(params, &params.tensors)?;
            let mlits = Self::param_literals(params, &params.adam_m)?;
            let vlits = Self::param_literals(params, &params.adam_v)?;
            let step_lit = xla::Literal::scalar(params.step as f32);
            let xlit = xla::Literal::vec1(&x)
                .reshape(&[b as i64, EMBED_DIM as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let ohlit = xla::Literal::vec1(&onehot)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let rlit = xla::Literal::vec1(&reward);
            let ollit = xla::Literal::vec1(&old_logp);
            let mklit = xla::Literal::vec1(&mask);

            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * NUM_TENSORS + 6);
            inputs.extend(plits.iter());
            inputs.extend(mlits.iter());
            inputs.extend(vlits.iter());
            inputs.push(&step_lit);
            inputs.push(&xlit);
            inputs.push(&ohlit);
            inputs.push(&rlit);
            inputs.push(&ollit);
            inputs.push(&mklit);

            let result = exe
                .execute::<&xla::Literal>(&inputs)
                .map_err(|e| anyhow!("execute update: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
            if parts.len() != 3 * NUM_TENSORS + 2 {
                bail!("update returned {} parts, expected {}", parts.len(), 3 * NUM_TENSORS + 2);
            }
            for (i, part) in parts.iter().take(NUM_TENSORS).enumerate() {
                params.tensors[i] = part.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            }
            for i in 0..NUM_TENSORS {
                params.adam_m[i] =
                    parts[NUM_TENSORS + i].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                params.adam_v[i] =
                    parts[2 * NUM_TENSORS + i].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            }
            stats.loss += parts[3 * NUM_TENSORS]
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;
            stats.entropy += parts[3 * NUM_TENSORS + 1]
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;
            chunks += 1;
            done += take;
        }
        if chunks > 0 {
            stats.loss /= chunks as f32;
            stats.entropy /= chunks as f32;
        }
        Ok(stats)
    }
}
