//! Scenario engine: deterministic cluster dynamics + golden-trace replay.
//!
//! The paper's setting is *fluctuating* assigned loads over heterogeneous,
//! churn-prone edge nodes (§III, §IV-B/C), yet a plain `Coordinator::run`
//! is static: fixed load, fixed SLO, a cluster that never changes. This
//! tier makes the dynamics first-class:
//!
//! - [`Scenario`] ([`event`]) — a slot-indexed timeline of typed events
//!   (`node-down`/`node-up`, `capacity-scale`, `slo-change`,
//!   `corpus-ingest`, `burst`, `skew-shift`), parsed from
//!   `[[scenario.events]]` TOML tables (`--scenario <file>` on the CLI);
//! - [`ScenarioRunner`] ([`runner`]) — applies events between slots and
//!   drives per-slot load from a [`TraceConfig`](crate::workload::TraceConfig)
//!   arrival trace, so load actually fluctuates;
//! - [`RunTranscript`] ([`transcript`]) — a byte-stable JSONL record of
//!   every slot (queries, proportions, drop rate, quality, active-node
//!   mask, applied events). `tests/scenarios.rs` replays committed
//!   scenario fixtures against committed transcripts and asserts exact
//!   equality — any nondeterminism or behavioral drift is a test failure.
//!
//! Node availability threads through `SlotContext::active` and
//! `Coordinator::slot_capacities` (a down node has capacity 0, every
//! built-in allocator routes around it, and `route` rejects assignments
//! that touch one).

pub mod event;
pub mod fixtures;
pub mod runner;
pub mod transcript;

pub use event::{Scenario, ScenarioEvent, TimedEvent};
pub use fixtures::{find_scenarios_dir, load_fixtures, resolve_scenarios_dir, NamedScenario};
pub use runner::{ScenarioRun, ScenarioRunner};
pub use transcript::{RunTranscript, TranscriptRecorder};
