//! Byte-stable run transcripts: the golden-trace replay format.
//!
//! A transcript is a header line plus one JSON line per slot, holding only
//! *modeled* quantities (never wall-clock measurements) so that, for a
//! given seed + scenario, two runs — on any machine, under any thread
//! count — produce byte-identical text. `tests/scenarios.rs` replays the
//! committed scenario fixtures against committed transcripts and asserts
//! exact equality, catching both nondeterminism (e.g. in the parallel
//! serve path or the sharded-index merge) and unintended behavioral drift.

use std::sync::{Arc, Mutex};

use crate::coordinator::observer::{SlotEvent, SlotObserver};
use crate::coordinator::SlotReport;
use crate::util::json::Json;
use crate::Result;

/// Structured record of one run: JSON lines, append-only.
#[derive(Clone, Debug, Default)]
pub struct RunTranscript {
    lines: Vec<String>,
}

impl RunTranscript {
    /// Start a transcript with a self-describing header line.
    pub fn new(scenario: &str, seed: u64, n_nodes: usize, allocator: &str, slots: usize) -> Self {
        let header = Json::obj(vec![
            ("scenario", Json::Str(scenario.to_string())),
            ("seed", Json::Num(seed as f64)),
            ("nodes", Json::Num(n_nodes as f64)),
            ("allocator", Json::Str(allocator.to_string())),
            ("slots", Json::Num(slots as f64)),
        ]);
        RunTranscript { lines: vec![header.to_string()] }
    }

    /// Append one slot record. `events` are the labels of the scenario
    /// events applied before the slot (empty outside the scenario engine).
    ///
    /// Deliberately excluded: `measured_search_s` and phase wall-clock
    /// times — anything a stopwatch produced would break byte stability.
    /// Cache counters appear only when a cache tier is configured
    /// (`report.cache` is `Some`), so default-configuration transcripts
    /// are byte-identical to the pre-cache format.
    pub fn record(&mut self, slot: usize, events: &[String], report: &SlotReport) {
        let mut fields = vec![
            ("slot", Json::Num(slot as f64)),
            ("queries", Json::Num(report.queries as f64)),
            ("events", Json::arr_str(events)),
            ("active", Json::Arr(report.active.iter().map(|&a| Json::Bool(a)).collect())),
            ("slo_s", Json::Num(report.slo_s)),
            ("proportions", Json::arr_f64(&report.proportions)),
            ("drop_rate", Json::Num(report.drop_rate)),
            ("latency_s", Json::Num(report.latency_s)),
            ("rouge_l", Json::Num(report.mean_scores.rouge_l)),
            ("bert_score", Json::Num(report.mean_scores.bert_score)),
            ("updates", Json::Num(report.feedback.updates as f64)),
        ];
        if let Some(c) = &report.cache {
            fields.push(("cache_hits", Json::Num(c.hits() as f64)));
            fields.push(("cache_misses", Json::Num(c.misses() as f64)));
            fields.push(("cache_evictions", Json::Num(c.evictions() as f64)));
            fields.push(("cache_invalidations", Json::Num(c.invalidations as f64)));
            fields.push(("cache_bytes", Json::Num(c.bytes as f64)));
        }
        // migration fields appear only once a reindex event has fired
        // (same gating pattern as the cache fields): per-node serving
        // index kind — the slot where an entry changes IS the swap slot —
        // and in-flight migration state
        if let Some(kinds) = &report.index_kinds {
            fields.push(("index_kinds", Json::arr_str(kinds)));
        }
        if let Some(migs) = &report.migrations {
            fields.push(("migrations", Json::arr_str(migs)));
        }
        let line = Json::obj(fields);
        self.lines.push(line.to_string());
    }

    /// All lines (header first).
    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// Slot records written so far (excludes the header).
    pub fn num_slots(&self) -> usize {
        self.lines.len().saturating_sub(1)
    }

    /// The transcript as JSON-lines text (every line newline-terminated).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }

    /// Write the JSONL text to `path` (golden-fixture blessing).
    pub fn write_to(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_jsonl())?;
        Ok(())
    }
}

/// A [`SlotObserver`] appending every `SlotEnd` to a shared
/// [`RunTranscript`] — lets long-running fronts (the TCP server) record a
/// replayable transcript without running under the scenario engine.
#[derive(Clone)]
pub struct TranscriptRecorder {
    inner: Arc<Mutex<RunTranscript>>,
}

impl TranscriptRecorder {
    /// Start a shared transcript with the given header fields.
    pub fn new(name: &str, seed: u64, n_nodes: usize, allocator: &str) -> Self {
        TranscriptRecorder {
            inner: Arc::new(Mutex::new(RunTranscript::new(name, seed, n_nodes, allocator, 0))),
        }
    }

    /// Snapshot of everything recorded so far.
    pub fn snapshot(&self) -> RunTranscript {
        self.inner.lock().unwrap().clone()
    }
}

impl SlotObserver for TranscriptRecorder {
    fn on_event(&mut self, event: &SlotEvent) {
        if let SlotEvent::SlotEnd { slot, report } = event {
            self.inner.lock().unwrap().record(*slot, &[], report);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SlotReport;

    fn demo_report() -> SlotReport {
        SlotReport {
            queries: 10,
            drop_rate: 0.1,
            latency_s: 3.25,
            proportions: vec![0.5, 0.5],
            active: vec![true, false],
            slo_s: 15.0,
            ..SlotReport::default()
        }
    }

    #[test]
    fn serialization_is_stable_and_excludes_wall_clock() {
        let mk = || {
            let mut t = RunTranscript::new("demo", 42, 2, "oracle", 1);
            let mut r = demo_report();
            // wall-clock fields must not leak into the transcript
            r.node_search_s = vec![(0.1, 123.456), (0.1, 789.0)];
            t.record(0, &["node-down(1)".to_string()], &r);
            t.to_jsonl()
        };
        let a = mk();
        assert_eq!(a, mk(), "same inputs must serialize byte-identically");
        assert_eq!(a.lines().count(), 2);
        assert!(a.contains("\"scenario\":\"demo\""), "{a}");
        assert!(a.contains("\"events\":[\"node-down(1)\"]"), "{a}");
        assert!(a.contains("\"active\":[true,false]"), "{a}");
        assert!(!a.contains("123.456"), "wall-clock leaked: {a}");
        // no cache tier configured ⇒ no cache fields (pre-cache format)
        assert!(!a.contains("cache"), "{a}");
    }

    #[test]
    fn cache_fields_appear_only_when_cache_tier_is_on() {
        let mut t = RunTranscript::new("demo", 42, 2, "oracle", 1);
        let mut r = demo_report();
        r.cache = Some(crate::cache::CacheSlotStats {
            retrieval_hits: 5,
            retrieval_misses: 3,
            answer_hits: 2,
            answer_misses: 6,
            retrieval_evictions: 1,
            answer_evictions: 0,
            invalidations: 4,
            bytes: 1024,
        });
        t.record(0, &[], &r);
        let text = t.to_jsonl();
        assert!(text.contains("\"cache_hits\":7"), "{text}");
        assert!(text.contains("\"cache_misses\":9"), "{text}");
        assert!(text.contains("\"cache_evictions\":1"), "{text}");
        assert!(text.contains("\"cache_invalidations\":4"), "{text}");
        assert!(text.contains("\"cache_bytes\":1024"), "{text}");
    }

    #[test]
    fn migration_fields_appear_only_after_reindex() {
        let mut t = RunTranscript::new("demo", 42, 2, "oracle", 1);
        let mut r = demo_report();
        r.index_kinds = Some(vec!["flat".into(), "quantized-flat".into()]);
        r.migrations = Some(vec!["flat->quantized-flat:2".into(), "-".into()]);
        t.record(0, &[], &r);
        let text = t.to_jsonl();
        assert!(text.contains("\"index_kinds\":[\"flat\",\"quantized-flat\"]"), "{text}");
        assert!(text.contains("\"migrations\":[\"flat->quantized-flat:2\",\"-\"]"), "{text}");
        // absent by default — reindex-free transcripts keep the old format
        let mut t2 = RunTranscript::new("demo", 42, 2, "oracle", 1);
        t2.record(0, &[], &demo_report());
        let text2 = t2.to_jsonl();
        assert!(!text2.contains("index_kinds") && !text2.contains("migrations"), "{text2}");
    }

    #[test]
    fn recorder_appends_on_slot_end() {
        let rec = TranscriptRecorder::new("srv", 7, 2, "random");
        let report = demo_report();
        let mut obs: Box<dyn SlotObserver> = Box::new(rec.clone());
        obs.on_event(&SlotEvent::Encoded { slot: 0, queries: 10, elapsed_s: 0.0 });
        obs.on_event(&SlotEvent::SlotEnd { slot: 0, report: &report });
        let snap = rec.snapshot();
        assert_eq!(snap.num_slots(), 1);
        assert!(snap.to_jsonl().contains("\"queries\":10"));
    }
}
