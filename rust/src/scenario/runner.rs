//! Scenario execution: apply events between slots, drive per-slot load
//! from the arrival trace, and record a byte-stable [`RunTranscript`].

use super::event::{Scenario, ScenarioEvent};
use super::transcript::RunTranscript;
use crate::coordinator::pipeline::{PipelineConfig, PipelinedExecutor};
use crate::coordinator::{Coordinator, SlotReport};
use crate::workload::{arrival_trace, TraceConfig};
use crate::Result;

/// Everything one scenario run produced.
pub struct ScenarioRun {
    /// Per-slot reports, in slot order.
    pub reports: Vec<SlotReport>,
    /// The replayable transcript (one JSON line per slot + header).
    pub transcript: RunTranscript,
}

/// Replays a [`Scenario`] against a coordinator: per slot, apply the
/// scheduled events, sample the trace-driven (fluctuating) load, run the
/// slot, and record the transcript line.
pub struct ScenarioRunner {
    scenario: Scenario,
}

impl ScenarioRunner {
    /// Wrap a parsed scenario for replay.
    pub fn new(scenario: Scenario) -> Self {
        ScenarioRunner { scenario }
    }

    /// The scenario being replayed.
    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Per-slot query counts this scenario would drive against `co`'s
    /// config: the arrival trace when one is configured, otherwise the
    /// config's fixed `queries_per_slot`. `BurstOverride` events replace
    /// individual entries at run time.
    pub fn loads(&self, co: &Coordinator) -> Vec<usize> {
        let slots = self.scenario.slots.unwrap_or(co.cfg.slots);
        match &self.scenario.trace {
            Some(tc) => arrival_trace(&TraceConfig { slots, ..tc.clone() }),
            None => vec![co.cfg.queries_per_slot; slots],
        }
    }

    /// Run the full scenario. Events apply between slots, in timeline
    /// order; the run fails fast on out-of-range nodes/domains and on
    /// events scheduled beyond the resolved slot count (a typo'd `slot`
    /// would otherwise just silently never fire).
    pub fn run(&self, co: &mut Coordinator) -> Result<ScenarioRun> {
        self.run_observed(co, |_, _, _| {})
    }

    /// [`ScenarioRunner::run`] with a per-slot observation hook: after
    /// each slot the hook sees `(slot, sampled query ids, report)`. This
    /// is how the fuzzer's invariant oracle checks outcome conservation
    /// against the exact ids the slot was asked to serve — information
    /// the transcript alone does not carry.
    pub fn run_observed(
        &self,
        co: &mut Coordinator,
        mut observe: impl FnMut(usize, &[usize], &SlotReport),
    ) -> Result<ScenarioRun> {
        self.scenario.validate(co.nodes.len(), co.ds.num_domains())?;
        let loads = self.loads(co);
        for te in &self.scenario.events {
            anyhow::ensure!(
                te.slot < loads.len(),
                "scenario event {} at slot {} is beyond the run's {} slots",
                te.event.kind(),
                te.slot,
                loads.len()
            );
        }
        let mut transcript = RunTranscript::new(
            &self.scenario.name,
            co.cfg.seed,
            co.nodes.len(),
            co.allocator().name(),
            loads.len(),
        );
        let mut reports = Vec::with_capacity(loads.len());
        for (t, &load) in loads.iter().enumerate() {
            let mut burst = None;
            let mut labels = Vec::new();
            for te in self.scenario.events_at(t) {
                labels.push(te.event.label());
                if let ScenarioEvent::BurstOverride { queries } = &te.event {
                    burst = Some(*queries); // consumed by the load below
                } else {
                    co.apply_event(&te.event)?;
                }
            }
            let qids = co.sample_queries(burst.unwrap_or(load))?;
            let report = co.run_slot(&qids)?;
            transcript.record(t, &labels, &report);
            observe(t, &qids, &report);
            reports.push(report);
        }
        Ok(ScenarioRun { reports, transcript })
    }

    /// [`ScenarioRunner::run`] through the pipelined slot executor:
    /// encode of slot `t+1` overlaps route/serve/feedback of slot `t`.
    ///
    /// Query sampling is hoisted into a pre-pass. This is sound because
    /// the coordinator's rng is consumed by sampling alone, and the only
    /// timeline inputs that influence sampling are `skew-shift` (whose
    /// schedule is statically known, so the pre-pass walks it) and
    /// `burst` (resolved against the arrival trace either way). The
    /// pre-pass draws from the rng in exactly the order the synchronous
    /// loop would, so the sampled ids — and therefore every slot's
    /// behavior, observer event, and transcript byte — are identical to
    /// [`run`](Self::run); `tests/scenarios.rs` pins this for every
    /// committed fixture at several encode-thread counts.
    pub fn run_pipelined(
        &self,
        co: &mut Coordinator,
        pcfg: &PipelineConfig,
    ) -> Result<ScenarioRun> {
        self.scenario.validate(co.nodes.len(), co.ds.num_domains())?;
        let loads = self.loads(co);
        for te in &self.scenario.events {
            anyhow::ensure!(
                te.slot < loads.len(),
                "scenario event {} at slot {} is beyond the run's {} slots",
                te.event.kind(),
                te.slot,
                loads.len()
            );
        }

        // pre-sample every slot's query ids, tracking the skew-shift
        // timeline exactly as the synchronous loop would. Crucially this
        // sets `cfg.skew` directly instead of going through
        // `apply_event`, which would also count cache invalidations and
        // perturb the transcript's cache columns; the saved skew is
        // restored before the execute pass re-applies events for real.
        let saved_skew = co.cfg.skew.clone();
        let mut slots: Vec<Vec<usize>> = Vec::with_capacity(loads.len());
        let mut sample_err = None;
        'sample: for (t, &load) in loads.iter().enumerate() {
            let mut burst = None;
            for te in self.scenario.events_at(t) {
                match &te.event {
                    ScenarioEvent::BurstOverride { queries } => burst = Some(*queries),
                    ScenarioEvent::SkewShift { pattern } => co.cfg.skew = pattern.clone(),
                    _ => {}
                }
            }
            match co.sample_queries(burst.unwrap_or(load)) {
                Ok(qids) => slots.push(qids),
                Err(e) => {
                    sample_err = Some(e);
                    break 'sample;
                }
            }
        }
        co.cfg.skew = saved_skew;
        if let Some(e) = sample_err {
            return Err(e);
        }

        // event labels are static per slot; precompute so the transcript
        // hook needs no mutable state shared with the event hook
        let labels: Vec<Vec<String>> = (0..loads.len())
            .map(|t| self.scenario.events_at(t).map(|te| te.event.label()).collect())
            .collect();

        let mut transcript = RunTranscript::new(
            &self.scenario.name,
            co.cfg.seed,
            co.nodes.len(),
            co.allocator().name(),
            loads.len(),
        );
        let scenario = &self.scenario;
        let reports = PipelinedExecutor::new(pcfg.clone()).run_with(
            co,
            &slots,
            |co, t| {
                for te in scenario.events_at(t) {
                    if !matches!(te.event, ScenarioEvent::BurstOverride { .. }) {
                        co.apply_event(&te.event)?;
                    }
                }
                Ok(())
            },
            |t, report| transcript.record(t, &labels[t], report),
        )?;
        Ok(ScenarioRun { reports, transcript })
    }
}
