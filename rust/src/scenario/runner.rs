//! Scenario execution: apply events between slots, drive per-slot load
//! from the arrival trace, and record a byte-stable [`RunTranscript`].

use super::event::{Scenario, ScenarioEvent};
use super::transcript::RunTranscript;
use crate::coordinator::{Coordinator, SlotReport};
use crate::workload::{arrival_trace, TraceConfig};
use crate::Result;

/// Everything one scenario run produced.
pub struct ScenarioRun {
    /// Per-slot reports, in slot order.
    pub reports: Vec<SlotReport>,
    /// The replayable transcript (one JSON line per slot + header).
    pub transcript: RunTranscript,
}

/// Replays a [`Scenario`] against a coordinator: per slot, apply the
/// scheduled events, sample the trace-driven (fluctuating) load, run the
/// slot, and record the transcript line.
pub struct ScenarioRunner {
    scenario: Scenario,
}

impl ScenarioRunner {
    /// Wrap a parsed scenario for replay.
    pub fn new(scenario: Scenario) -> Self {
        ScenarioRunner { scenario }
    }

    /// The scenario being replayed.
    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Per-slot query counts this scenario would drive against `co`'s
    /// config: the arrival trace when one is configured, otherwise the
    /// config's fixed `queries_per_slot`. `BurstOverride` events replace
    /// individual entries at run time.
    pub fn loads(&self, co: &Coordinator) -> Vec<usize> {
        let slots = self.scenario.slots.unwrap_or(co.cfg.slots);
        match &self.scenario.trace {
            Some(tc) => arrival_trace(&TraceConfig { slots, ..tc.clone() }),
            None => vec![co.cfg.queries_per_slot; slots],
        }
    }

    /// Run the full scenario. Events apply between slots, in timeline
    /// order; the run fails fast on out-of-range nodes/domains and on
    /// events scheduled beyond the resolved slot count (a typo'd `slot`
    /// would otherwise just silently never fire).
    pub fn run(&self, co: &mut Coordinator) -> Result<ScenarioRun> {
        self.run_observed(co, |_, _, _| {})
    }

    /// [`ScenarioRunner::run`] with a per-slot observation hook: after
    /// each slot the hook sees `(slot, sampled query ids, report)`. This
    /// is how the fuzzer's invariant oracle checks outcome conservation
    /// against the exact ids the slot was asked to serve — information
    /// the transcript alone does not carry.
    pub fn run_observed(
        &self,
        co: &mut Coordinator,
        mut observe: impl FnMut(usize, &[usize], &SlotReport),
    ) -> Result<ScenarioRun> {
        self.scenario.validate(co.nodes.len(), co.ds.num_domains())?;
        let loads = self.loads(co);
        for te in &self.scenario.events {
            anyhow::ensure!(
                te.slot < loads.len(),
                "scenario event {} at slot {} is beyond the run's {} slots",
                te.event.kind(),
                te.slot,
                loads.len()
            );
        }
        let mut transcript = RunTranscript::new(
            &self.scenario.name,
            co.cfg.seed,
            co.nodes.len(),
            co.allocator().name(),
            loads.len(),
        );
        let mut reports = Vec::with_capacity(loads.len());
        for (t, &load) in loads.iter().enumerate() {
            let mut burst = None;
            let mut labels = Vec::new();
            for te in self.scenario.events_at(t) {
                labels.push(te.event.label());
                if let ScenarioEvent::BurstOverride { queries } = &te.event {
                    burst = Some(*queries); // consumed by the load below
                } else {
                    co.apply_event(&te.event)?;
                }
            }
            let qids = co.sample_queries(burst.unwrap_or(load))?;
            let report = co.run_slot(&qids)?;
            transcript.record(t, &labels, &report);
            observe(t, &qids, &report);
            reports.push(report);
        }
        Ok(ScenarioRun { reports, transcript })
    }
}
