//! Typed cluster-dynamics events and the slot-indexed [`Scenario`]
//! timeline, parsed from `[scenario]` / `[scenario.trace]` /
//! `[[scenario.events]]` TOML tables (standalone scenario files or tables
//! embedded in an experiment config).

use crate::util::toml::{Table, TomlDoc};
use crate::workload::{SkewPattern, TraceConfig};
use crate::Result;
use anyhow::anyhow;

/// One cluster-dynamics event, applied by the coordinator between slots
/// (see [`Coordinator::apply_event`](crate::coordinator::Coordinator::apply_event)).
#[derive(Clone, Debug)]
pub enum ScenarioEvent {
    /// Take a node offline: capacity 0, no queries routed to it.
    NodeDown { node: usize },
    /// Bring a node back online.
    NodeUp { node: usize },
    /// Multiply a node's effective capacity by `factor` (<1 degradation,
    /// >1 upgrade; factors compose across events).
    CapacityScale { node: usize, factor: f64 },
    /// Change the per-slot latency SLO L^t.
    SloChange { slo_s: f64 },
    /// Live corpus update: replicate up to `docs` documents of `domain`
    /// onto `node` via `VectorIndex::add` — no rebuild, no re-finalize.
    CorpusIngest { node: usize, docs: usize, domain: usize },
    /// Override this slot's arrival load with an exact query count.
    BurstOverride { queries: usize },
    /// Switch the per-slot query domain mix.
    SkewShift { pattern: SkewPattern },
    /// Live index migration: rebuild `node`'s index as kind `to` in the
    /// background (snapshot + write-log) and atomically swap at the
    /// modeled slot boundary — the node serves every slot meanwhile.
    /// Optional `shards` / `rescore_factor` override the target spec's
    /// parameters; other parameters keep the node's configured values.
    Reindex {
        /// Node whose index migrates (must be up when the event fires).
        node: usize,
        /// Target built-in [`crate::vecdb::IndexKind`] key.
        to: String,
        /// Sharded targets: shard-count override.
        shards: Option<usize>,
        /// Quantized targets: rescore-factor override.
        rescore_factor: Option<usize>,
    },
}

impl ScenarioEvent {
    /// Valid `kind` strings for `[[scenario.events]]` tables.
    pub const KINDS: [&'static str; 8] = [
        "node-down",
        "node-up",
        "capacity-scale",
        "slo-change",
        "corpus-ingest",
        "burst",
        "skew-shift",
        "reindex",
    ];

    /// Stable kind key (the TOML `kind` value).
    pub fn kind(&self) -> &'static str {
        match self {
            ScenarioEvent::NodeDown { .. } => "node-down",
            ScenarioEvent::NodeUp { .. } => "node-up",
            ScenarioEvent::CapacityScale { .. } => "capacity-scale",
            ScenarioEvent::SloChange { .. } => "slo-change",
            ScenarioEvent::CorpusIngest { .. } => "corpus-ingest",
            ScenarioEvent::BurstOverride { .. } => "burst",
            ScenarioEvent::SkewShift { .. } => "skew-shift",
            ScenarioEvent::Reindex { .. } => "reindex",
        }
    }

    /// Compact label for transcripts and CLI tables, e.g. `node-down(2)`.
    pub fn label(&self) -> String {
        match self {
            ScenarioEvent::NodeDown { node } => format!("node-down({node})"),
            ScenarioEvent::NodeUp { node } => format!("node-up({node})"),
            ScenarioEvent::CapacityScale { node, factor } => {
                format!("capacity-scale({node},x{factor})")
            }
            ScenarioEvent::SloChange { slo_s } => format!("slo-change({slo_s})"),
            ScenarioEvent::CorpusIngest { node, docs, domain } => {
                format!("corpus-ingest({node},{docs}@d{domain})")
            }
            ScenarioEvent::BurstOverride { queries } => format!("burst({queries})"),
            ScenarioEvent::SkewShift { pattern } => {
                let p = match pattern {
                    SkewPattern::Balanced => "balanced".to_string(),
                    SkewPattern::Primary { domain, frac } => format!("primary:d{domain}@{frac}"),
                    SkewPattern::Dirichlet { alpha } => format!("dirichlet:{alpha}"),
                };
                format!("skew-shift({p})")
            }
            ScenarioEvent::Reindex { node, to, .. } => format!("reindex({node},{to})"),
        }
    }
}

/// Reject keys outside `valid` — a typo'd key (`diurnal_anp`, a stray
/// `factor` on a `burst`, …) would otherwise silently keep its default
/// with no diagnostic.
fn reject_unknown_keys(t: &Table, ctx: &str, valid: &[&str]) -> Result<()> {
    for key in t.keys() {
        anyhow::ensure!(
            valid.contains(&key.as_str()),
            "unknown key {key:?} in {ctx}; valid keys: {}",
            valid.join(", ")
        );
    }
    Ok(())
}

/// An event scheduled for a specific slot.
#[derive(Clone, Debug)]
pub struct TimedEvent {
    /// 0-based slot the event fires *before* (events apply between slots).
    pub slot: usize,
    /// The event to apply.
    pub event: ScenarioEvent,
}

impl TimedEvent {
    /// Parse one `[[scenario.events]]` table. Unknown kinds and missing
    /// required keys are clear errors naming the valid alternatives.
    pub fn from_table(t: &Table) -> Result<TimedEvent> {
        let slot = t
            .get("slot")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("scenario event missing 'slot'"))?;
        let kind = t
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("scenario event at slot {slot} missing 'kind'"))?;
        let node = || {
            t.get("node")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{kind} at slot {slot}: missing 'node'"))
        };
        let f64_key = |key: &str| {
            t.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("{kind} at slot {slot}: missing '{key}'"))
        };
        let usize_key = |key: &str| {
            t.get(key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{kind} at slot {slot}: missing '{key}'"))
        };
        let valid: &[&str] = match kind {
            "node-down" | "node-up" => &["slot", "kind", "node"],
            "capacity-scale" => &["slot", "kind", "node", "factor"],
            "slo-change" => &["slot", "kind", "slo_s"],
            "corpus-ingest" => &["slot", "kind", "node", "docs", "domain"],
            "burst" => &["slot", "kind", "queries"],
            "skew-shift" => &["slot", "kind", "skew", "domain", "frac", "alpha"],
            "reindex" => &["slot", "kind", "node", "to", "shards", "rescore_factor"],
            other => anyhow::bail!(
                "unknown scenario event kind {other:?} at slot {slot}; valid kinds: {}",
                ScenarioEvent::KINDS.join(", ")
            ),
        };
        reject_unknown_keys(t, &format!("{kind} event at slot {slot}"), valid)?;
        let event = match kind {
            "node-down" => ScenarioEvent::NodeDown { node: node()? },
            "node-up" => ScenarioEvent::NodeUp { node: node()? },
            "capacity-scale" => {
                ScenarioEvent::CapacityScale { node: node()?, factor: f64_key("factor")? }
            }
            "slo-change" => ScenarioEvent::SloChange { slo_s: f64_key("slo_s")? },
            "corpus-ingest" => ScenarioEvent::CorpusIngest {
                node: node()?,
                docs: usize_key("docs")?,
                domain: usize_key("domain")?,
            },
            "burst" => ScenarioEvent::BurstOverride { queries: usize_key("queries")? },
            "skew-shift" => ScenarioEvent::SkewShift {
                pattern: SkewPattern::from_table(t, "skew")?
                    .ok_or_else(|| anyhow!("skew-shift at slot {slot}: missing 'skew'"))?,
            },
            "reindex" => ScenarioEvent::Reindex {
                node: node()?,
                to: t
                    .get("to")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("{kind} at slot {slot}: missing 'to'"))?
                    .to_string(),
                shards: t.get("shards").and_then(|v| v.as_usize()),
                rescore_factor: t.get("rescore_factor").and_then(|v| v.as_usize()),
            },
            _ => unreachable!("kind was matched against the same set above"),
        };
        Ok(TimedEvent { slot, event })
    }
}

/// A slot-indexed timeline of cluster dynamics plus an optional arrival
/// trace — everything `Coordinator::run` holds fixed, made fluctuating.
#[derive(Clone, Debug, Default)]
pub struct Scenario {
    /// Scenario name (stamped into transcripts).
    pub name: String,
    /// Slots to run; `None` falls back to the experiment config's count.
    pub slots: Option<usize>,
    /// Arrival trace driving per-slot load; `None` keeps the config's
    /// fixed `queries_per_slot`. (`trace.slots` is overridden by the
    /// resolved slot count at run time.)
    pub trace: Option<TraceConfig>,
    /// Events sorted by slot (same-slot events keep file order).
    pub events: Vec<TimedEvent>,
}

impl Scenario {
    /// Parse a scenario from TOML text (a standalone `--scenario` file or
    /// a full experiment config embedding the `[scenario]` tables).
    pub fn from_toml(text: &str) -> Result<Scenario> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("scenario toml: {e}"))?;
        Scenario::from_doc(&doc)
    }

    /// Read the scenario tables out of a parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Scenario> {
        let mut sc = Scenario::default();
        if let Some(t) = doc.tables.get("scenario") {
            reject_unknown_keys(t, "[scenario]", &["name", "slots"])?;
            if let Some(v) = t.get("name").and_then(|v| v.as_str()) {
                sc.name = v.to_string();
            }
            if let Some(v) = t.get("slots").and_then(|v| v.as_usize()) {
                sc.slots = Some(v);
            }
        }
        if let Some(t) = doc.tables.get("scenario.trace") {
            reject_unknown_keys(
                t,
                "[scenario.trace]",
                &["base", "period", "diurnal_amp", "burst_prob", "burst_mult", "seed"],
            )?;
            let mut tc = TraceConfig::default();
            if let Some(v) = t.get("base").and_then(|v| v.as_usize()) {
                tc.base = v;
            }
            if let Some(v) = t.get("period").and_then(|v| v.as_usize()) {
                tc.period = v;
            }
            if let Some(v) = t.get("diurnal_amp").and_then(|v| v.as_f64()) {
                tc.diurnal_amp = v;
            }
            if let Some(v) = t.get("burst_prob").and_then(|v| v.as_f64()) {
                tc.burst_prob = v;
            }
            if let Some(v) = t.get("burst_mult").and_then(|v| v.as_f64()) {
                tc.burst_mult = v;
            }
            if let Some(v) = t.get("seed").and_then(|v| v.as_i64()) {
                // a negative seed used to wrap via `as u64` into a huge
                // unrelated stream — reject it instead
                anyhow::ensure!(v >= 0, "[scenario.trace] seed must be non-negative, got {v}");
                tc.seed = v as u64;
            }
            sc.trace = Some(tc);
        }
        for t in doc.array("scenario.events") {
            sc.events.push(TimedEvent::from_table(t)?);
        }
        // stable: same-slot events keep file order
        sc.events.sort_by_key(|e| e.slot);
        Ok(sc)
    }

    /// Events scheduled for `slot`, in file order.
    pub fn events_at(&self, slot: usize) -> impl Iterator<Item = &TimedEvent> {
        self.events.iter().filter(move |e| e.slot == slot)
    }

    /// Serialize back to the `[scenario]` TOML shape [`Scenario::from_toml`]
    /// parses — byte-deterministic (events in slot order, fixed key
    /// order), so `parse(s.to_toml()).to_toml() == s.to_toml()`. Used by
    /// the fuzzer's shrinker to emit a minimized failing timeline as a
    /// committable fixture.
    pub fn to_toml(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        out.push_str("[scenario]\n");
        let _ = writeln!(out, "name = {:?}", self.name);
        if let Some(slots) = self.slots {
            let _ = writeln!(out, "slots = {slots}");
        }
        if let Some(tc) = &self.trace {
            out.push_str("\n[scenario.trace]\n");
            let _ = writeln!(out, "base = {}", tc.base);
            let _ = writeln!(out, "period = {}", tc.period);
            let _ = writeln!(out, "diurnal_amp = {}", tc.diurnal_amp);
            let _ = writeln!(out, "burst_prob = {}", tc.burst_prob);
            let _ = writeln!(out, "burst_mult = {}", tc.burst_mult);
            let _ = writeln!(out, "seed = {}", tc.seed);
        }
        for te in &self.events {
            out.push_str("\n[[scenario.events]]\n");
            let _ = writeln!(out, "slot = {}", te.slot);
            let _ = writeln!(out, "kind = {:?}", te.event.kind());
            match &te.event {
                ScenarioEvent::NodeDown { node } | ScenarioEvent::NodeUp { node } => {
                    let _ = writeln!(out, "node = {node}");
                }
                ScenarioEvent::CapacityScale { node, factor } => {
                    let _ = writeln!(out, "node = {node}");
                    let _ = writeln!(out, "factor = {factor}");
                }
                ScenarioEvent::SloChange { slo_s } => {
                    let _ = writeln!(out, "slo_s = {slo_s}");
                }
                ScenarioEvent::CorpusIngest { node, docs, domain } => {
                    let _ = writeln!(out, "node = {node}");
                    let _ = writeln!(out, "docs = {docs}");
                    let _ = writeln!(out, "domain = {domain}");
                }
                ScenarioEvent::BurstOverride { queries } => {
                    let _ = writeln!(out, "queries = {queries}");
                }
                ScenarioEvent::SkewShift { pattern } => match pattern {
                    SkewPattern::Balanced => {
                        let _ = writeln!(out, "skew = \"balanced\"");
                    }
                    SkewPattern::Primary { domain, frac } => {
                        let _ = writeln!(out, "skew = \"primary\"");
                        let _ = writeln!(out, "domain = {domain}");
                        let _ = writeln!(out, "frac = {frac}");
                    }
                    SkewPattern::Dirichlet { alpha } => {
                        let _ = writeln!(out, "skew = \"dirichlet\"");
                        let _ = writeln!(out, "alpha = {alpha}");
                    }
                },
                ScenarioEvent::Reindex { node, to, shards, rescore_factor } => {
                    let _ = writeln!(out, "node = {node}");
                    let _ = writeln!(out, "to = {to:?}");
                    if let Some(s) = shards {
                        let _ = writeln!(out, "shards = {s}");
                    }
                    if let Some(rf) = rescore_factor {
                        let _ = writeln!(out, "rescore_factor = {rf}");
                    }
                }
            }
        }
        out
    }

    /// Bounds-check every event against a built cluster — typo'd node or
    /// domain indices fail before the run starts, not mid-replay.
    pub fn validate(&self, n_nodes: usize, n_domains: usize) -> Result<()> {
        let check_node = |node: usize, kind: &str, slot: usize| {
            anyhow::ensure!(
                node < n_nodes,
                "{kind} at slot {slot}: node {node} out of range (cluster has {n_nodes} nodes)"
            );
            Ok(())
        };
        for te in &self.events {
            let (kind, slot) = (te.event.kind(), te.slot);
            match &te.event {
                ScenarioEvent::NodeDown { node } | ScenarioEvent::NodeUp { node } => {
                    check_node(*node, kind, slot)?;
                }
                ScenarioEvent::CapacityScale { node, factor } => {
                    check_node(*node, kind, slot)?;
                    anyhow::ensure!(
                        factor.is_finite() && *factor > 0.0,
                        "{kind} at slot {slot}: factor must be finite and > 0 (a factor of 0 \
                         bricks the node permanently — use node-down for outages), got {factor}"
                    );
                }
                ScenarioEvent::SloChange { slo_s } => {
                    anyhow::ensure!(
                        slo_s.is_finite() && *slo_s > 0.0,
                        "{kind} at slot {slot}: slo_s must be positive, got {slo_s}"
                    );
                }
                ScenarioEvent::CorpusIngest { node, domain, .. } => {
                    check_node(*node, kind, slot)?;
                    anyhow::ensure!(
                        *domain < n_domains,
                        "{kind} at slot {slot}: domain {domain} out of range \
                         (dataset has {n_domains} domains)"
                    );
                }
                ScenarioEvent::BurstOverride { .. } => {}
                ScenarioEvent::SkewShift { pattern } => pattern.validate(n_domains)?,
                ScenarioEvent::Reindex { node, to, shards, rescore_factor } => {
                    check_node(*node, kind, slot)?;
                    // only built-in kinds are reindexable — the error
                    // lists every valid kind (custom registrations have
                    // no snapshot-build contract)
                    to.parse::<crate::vecdb::IndexKind>()
                        .map_err(|e| anyhow!("{kind} at slot {slot}: {e}"))?;
                    if let Some(s) = shards {
                        anyhow::ensure!(*s >= 1, "{kind} at slot {slot}: shards must be >= 1");
                    }
                    if let Some(rf) = rescore_factor {
                        anyhow::ensure!(
                            *rf >= 1,
                            "{kind} at slot {slot}: rescore_factor must be >= 1"
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[scenario]
name = "demo"
slots = 6

[scenario.trace]
base = 40
diurnal_amp = 0.3
period = 6
burst_prob = 0.0
seed = 9

[[scenario.events]]
slot = 4
kind = "node-up"
node = 1

[[scenario.events]]
slot = 2
kind = "node-down"
node = 1

[[scenario.events]]
slot = 2
kind = "slo-change"
slo_s = 6.5

[[scenario.events]]
slot = 3
kind = "skew-shift"
skew = "primary"
domain = 1
frac = 0.8
"#;

    #[test]
    fn parses_and_sorts_by_slot_keeping_file_order_within_a_slot() {
        let sc = Scenario::from_toml(SAMPLE).unwrap();
        assert_eq!(sc.name, "demo");
        assert_eq!(sc.slots, Some(6));
        let tc = sc.trace.as_ref().unwrap();
        assert_eq!(tc.base, 40);
        assert_eq!(tc.seed, 9);
        let kinds: Vec<(usize, &str)> =
            sc.events.iter().map(|e| (e.slot, e.event.kind())).collect();
        assert_eq!(
            kinds,
            vec![(2, "node-down"), (2, "slo-change"), (3, "skew-shift"), (4, "node-up")]
        );
        assert_eq!(sc.events_at(2).count(), 2);
        assert_eq!(sc.events_at(5).count(), 0);
    }

    #[test]
    fn unknown_kind_lists_valid_kinds() {
        let err = Scenario::from_toml("[[scenario.events]]\nslot = 0\nkind = \"meteor\"\n")
            .unwrap_err()
            .to_string();
        for k in ScenarioEvent::KINDS {
            assert!(err.contains(k), "{err} should list {k}");
        }
    }

    #[test]
    fn missing_fields_error_clearly() {
        let err = Scenario::from_toml("[[scenario.events]]\nkind = \"node-down\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("slot"), "{err}");
        let err = Scenario::from_toml("[[scenario.events]]\nslot = 1\nkind = \"node-down\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("node"), "{err}");
        let err = Scenario::from_toml("[[scenario.events]]\nslot = 1\nkind = \"skew-shift\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("skew"), "{err}");
    }

    #[test]
    fn validate_bounds_checks_nodes_domains_and_parameters() {
        let mk = |event: ScenarioEvent| Scenario {
            events: vec![TimedEvent { slot: 0, event }],
            ..Scenario::default()
        };
        assert!(mk(ScenarioEvent::NodeDown { node: 3 }).validate(4, 6).is_ok());
        let err =
            mk(ScenarioEvent::NodeDown { node: 4 }).validate(4, 6).unwrap_err().to_string();
        assert!(err.contains("node 4") && err.contains("4 nodes"), "{err}");
        let err = mk(ScenarioEvent::CorpusIngest { node: 0, docs: 5, domain: 6 })
            .validate(4, 6)
            .unwrap_err()
            .to_string();
        assert!(err.contains("domain 6"), "{err}");
        assert!(mk(ScenarioEvent::SloChange { slo_s: 0.0 }).validate(4, 6).is_err());
        assert!(mk(ScenarioEvent::CapacityScale { node: 0, factor: -1.0 })
            .validate(4, 6)
            .is_err());
        assert!(mk(ScenarioEvent::SkewShift {
            pattern: crate::workload::SkewPattern::Primary { domain: 9, frac: 0.5 }
        })
        .validate(4, 6)
        .is_err());
    }

    #[test]
    fn empty_document_is_an_empty_scenario() {
        let sc = Scenario::from_toml("").unwrap();
        assert!(sc.events.is_empty());
        assert!(sc.trace.is_none());
        assert_eq!(sc.slots, None);
    }

    /// Regression: unknown keys used to be silently ignored — a typo'd
    /// `diurnal_anp` kept the default amplitude with no diagnostic.
    #[test]
    fn unknown_keys_are_rejected_naming_the_valid_ones() {
        let err = Scenario::from_toml("[scenario.trace]\nbase = 40\ndiurnal_anp = 0.5\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("diurnal_anp") && err.contains("diurnal_amp"), "{err}");
        let err = Scenario::from_toml("[scenario]\nname = \"x\"\nslot = 6\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("slot") && err.contains("slots"), "{err}");
        // a stray `factor` on a burst event (valid only on capacity-scale)
        let err = Scenario::from_toml(
            "[[scenario.events]]\nslot = 1\nkind = \"burst\"\nqueries = 10\nfactor = 2.0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("factor") && err.contains("queries"), "{err}");
        // all documented keys on every table parse cleanly
        assert!(Scenario::from_toml(SAMPLE).is_ok());
    }

    /// Regression: a negative trace seed used to wrap via `as u64` into a
    /// huge unrelated stream.
    #[test]
    fn negative_trace_seed_is_rejected() {
        let err = Scenario::from_toml("[scenario.trace]\nseed = -5\n").unwrap_err().to_string();
        assert!(err.contains("non-negative") && err.contains("-5"), "{err}");
    }

    /// Regression: `capacity-scale` with `factor = 0` bricks a node
    /// permanently (`cap_scale` sticks at 0; `node-up` cannot recover
    /// it) — the error points at `node-down` for outages.
    #[test]
    fn capacity_scale_factor_zero_is_rejected() {
        let mk = |factor: f64| Scenario {
            events: vec![TimedEvent {
                slot: 0,
                event: ScenarioEvent::CapacityScale { node: 0, factor },
            }],
            ..Scenario::default()
        };
        let err = mk(0.0).validate(4, 6).unwrap_err().to_string();
        assert!(err.contains("node-down"), "{err}");
        assert!(mk(f64::NAN).validate(4, 6).is_err());
        assert!(mk(0.01).validate(4, 6).is_ok());
    }

    /// `to_toml` round-trips: parsing the serialization and serializing
    /// again is byte-identical, and the reparse validates.
    #[test]
    fn to_toml_round_trips_byte_identically() {
        let sc = Scenario::from_toml(SAMPLE).unwrap();
        let toml = sc.to_toml();
        let re = Scenario::from_toml(&toml).unwrap();
        assert_eq!(re.to_toml(), toml, "round-trip must be a fixpoint");
        assert_eq!(re.events.len(), sc.events.len());
        assert!(re.validate(4, 6).is_ok());
        // every event kind serializes
        let all = Scenario {
            name: "all-kinds".into(),
            slots: Some(3),
            trace: Some(TraceConfig { slots: 3, base: 20, ..TraceConfig::default() }),
            events: vec![
                TimedEvent { slot: 0, event: ScenarioEvent::NodeDown { node: 1 } },
                TimedEvent { slot: 0, event: ScenarioEvent::NodeUp { node: 1 } },
                TimedEvent {
                    slot: 1,
                    event: ScenarioEvent::CapacityScale { node: 0, factor: 0.5 },
                },
                TimedEvent { slot: 1, event: ScenarioEvent::SloChange { slo_s: 7.5 } },
                TimedEvent {
                    slot: 1,
                    event: ScenarioEvent::CorpusIngest { node: 2, docs: 8, domain: 3 },
                },
                TimedEvent { slot: 2, event: ScenarioEvent::BurstOverride { queries: 0 } },
                TimedEvent {
                    slot: 2,
                    event: ScenarioEvent::SkewShift {
                        pattern: SkewPattern::Dirichlet { alpha: 0.3 },
                    },
                },
                TimedEvent {
                    slot: 2,
                    event: ScenarioEvent::Reindex {
                        node: 1,
                        to: "quantized-flat".into(),
                        shards: None,
                        rescore_factor: Some(4),
                    },
                },
            ],
        };
        let toml = all.to_toml();
        let re = Scenario::from_toml(&toml).unwrap();
        assert_eq!(re.to_toml(), toml);
        assert_eq!(re.events.len(), 8);
    }

    #[test]
    fn reindex_parses_validates_and_rejects_bad_targets() {
        let sc = Scenario::from_toml(
            "[[scenario.events]]\nslot = 1\nkind = \"reindex\"\nnode = 2\nto = \"hnsw\"\n",
        )
        .unwrap();
        match &sc.events[0].event {
            ScenarioEvent::Reindex { node, to, shards, rescore_factor } => {
                assert_eq!((*node, to.as_str()), (2, "hnsw"));
                assert_eq!((*shards, *rescore_factor), (None, None));
            }
            other => panic!("parsed {other:?}"),
        }
        assert_eq!(sc.events[0].event.label(), "reindex(2,hnsw)");
        assert!(sc.validate(4, 6).is_ok());
        // missing 'to' is a clear error
        let err = Scenario::from_toml("[[scenario.events]]\nslot = 0\nkind = \"reindex\"\nnode = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("'to'"), "{err}");
        // an unknown target kind fails validation listing the valid kinds
        let mk = |to: &str| Scenario {
            events: vec![TimedEvent {
                slot: 0,
                event: ScenarioEvent::Reindex {
                    node: 0,
                    to: to.into(),
                    shards: None,
                    rescore_factor: None,
                },
            }],
            ..Scenario::default()
        };
        let err = mk("bogus").validate(4, 6).unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("quantized-flat"), "{err}");
        for k in crate::vecdb::IndexKind::ALL {
            assert!(mk(k.as_str()).validate(4, 6).is_ok(), "{k}");
        }
    }
}
