//! Scenario-fixture discovery shared by `coedge eval` and `coedge train`.
//!
//! Both subcommands consume the committed `scenarios/*.toml` fixtures.
//! This module is the single resolution path: the same directory
//! auto-detection (repository root or `rust/` working directory), the
//! same `--scenarios DIR` override semantics, and a deterministic
//! filename-sorted loader so a curriculum never depends on directory
//! iteration order.

use std::path::{Path, PathBuf};

use super::event::Scenario;
use crate::Result;

/// Resolve the `scenarios/` fixture directory: the current directory, its
/// parent (CI runs with `rust/` as working directory), then the source
/// checkout the binary was built from. `None` when no fixture directory
/// can be found — callers should suggest `--scenarios DIR`.
pub fn find_scenarios_dir() -> Option<PathBuf> {
    for base in ["scenarios", "../scenarios"] {
        let p = PathBuf::from(base);
        if p.is_dir() {
            return Some(p);
        }
    }
    let built = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    if built.is_dir() {
        Some(built)
    } else {
        None
    }
}

/// Apply a `--scenarios DIR` override, falling back to
/// [`find_scenarios_dir`] auto-detection; errors name the remedy.
pub fn resolve_scenarios_dir(flag: Option<&str>) -> Result<PathBuf> {
    match flag {
        Some(dir) => {
            let p = PathBuf::from(dir);
            anyhow::ensure!(p.is_dir(), "--scenarios {}: not a directory", p.display());
            Ok(p)
        }
        None => find_scenarios_dir().ok_or_else(|| {
            anyhow::anyhow!(
                "no scenarios/ directory found near the working directory; pass --scenarios DIR"
            )
        }),
    }
}

/// One parsed fixture, tagged with its file stem (`burst_storm`, …).
#[derive(Clone, Debug)]
pub struct NamedScenario {
    /// Fixture name: the file stem of the `.toml` it was parsed from.
    pub name: String,
    /// The parsed scenario timeline.
    pub scenario: Scenario,
}

/// Load every `*.toml` fixture in `dir`, sorted by filename so the
/// resulting curriculum order is deterministic across platforms. Errors
/// name the offending file; an empty directory is an error (a silent
/// empty curriculum would train nothing).
pub fn load_fixtures(dir: &Path) -> Result<Vec<NamedScenario>> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read scenario directory {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("toml"))
        .collect();
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for path in paths {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read scenario fixture {}: {e}", path.display()))?;
        let scenario = Scenario::from_toml(&text)
            .map_err(|e| anyhow::anyhow!("parse scenario fixture {}: {e}", path.display()))?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("scenario")
            .to_string();
        out.push(NamedScenario { name, scenario });
    }
    anyhow::ensure!(!out.is_empty(), "no scenario fixtures (*.toml) in {}", dir.display());
    Ok(out)
}
