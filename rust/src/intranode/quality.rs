//! Offline "open-book" quality evaluation → the static Q_mn score
//! (paper §IV-C: "controlled open-book examination … queries paired with
//! ground-truth context documents, isolating generative performance from
//! retrieval noise").

use crate::corpus::synth::SyntheticDataset;
use crate::llmsim::gen::generate;
use crate::llmsim::model::ModelSpec;
use crate::metrics::Evaluator;
use crate::util::rng::Rng;
use crate::util::stats::mean;

/// Average intrinsic quality of `model` on the node's data distribution,
/// with retrieval forced ideal (rel = 1). `qa_sample` are QA ids local to
/// the node's domains.
pub fn open_book_quality(
    ds: &SyntheticDataset,
    qa_sample: &[usize],
    model: &ModelSpec,
    ev: &Evaluator,
    seed: u64,
) -> f64 {
    if qa_sample.is_empty() {
        return 0.0;
    }
    let mut rng = Rng::new(seed ^ 0x0B00);
    let scores: Vec<f64> = qa_sample
        .iter()
        .map(|&qi| {
            let qa = &ds.qa_pairs[qi];
            let gen = generate(ds, qa, model, 1.0, &mut rng);
            // composite feedback with the paper's weights
            ev.feedback(&gen, &qa.answer_tokens, 1.0, 0.5)
        })
        .collect();
    mean(&scores)
}

/// Q_mn for every model in the pool.
pub fn quality_table(
    ds: &SyntheticDataset,
    qa_sample: &[usize],
    pool: &[ModelSpec],
    ev: &Evaluator,
    seed: u64,
) -> Vec<f64> {
    pool.iter()
        .enumerate()
        .map(|(i, m)| open_book_quality(ds, qa_sample, m, ev, seed + i as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{build_dataset, domainqa_spec};
    use crate::llmsim::model::standard_pool;

    #[test]
    fn q_mn_orders_by_model_size() {
        let ds = build_dataset(&domainqa_spec(20, 30), 3);
        let ev = Evaluator::default();
        let sample: Vec<usize> = (0..30).collect();
        let pool = standard_pool();
        let q = quality_table(&ds, &sample, &pool, &ev, 1);
        assert_eq!(q.len(), 3);
        assert!(q[0] < q[1] && q[1] < q[2], "{q:?}");
        assert!(q[2] > 0.9, "large open-book {q:?}"); // rel=1, q=1 -> near perfect
    }

    #[test]
    fn empty_sample_zero() {
        let ds = build_dataset(&domainqa_spec(5, 10), 3);
        let ev = Evaluator::default();
        assert_eq!(open_book_quality(&ds, &[], &standard_pool()[0], &ev, 0), 0.0);
    }
}
