//! Intra-node deployment + allocation solver (paper Eq. 25–29).
//!
//! Per GPU, enumerate feasible deployment sets d ∈ 2^pool (Σ r_m ≤ 1),
//! sweep memory compositions on a grid, charge reload costs (LD/RLD/ULD,
//! Eq. 19–24) against the previous configuration, and compute each
//! model's max feasible load from the quadratic surrogate. Queries are
//! then allocated across all (model, GPU) pairs greedily by Q_mn — which
//! is exact for this linear objective with per-pair capacity bounds.
//!
//! The grid+greedy search is equivalent in effect to the paper's
//! Gurobi solve at edge problem sizes (≤3 models × ≤2 GPUs); a
//! projected-refinement pass polishes the winning memory split.

use std::collections::BTreeMap;

use crate::intranode::latfit::LatencyFit;
use crate::llmsim::gpu::GpuState;
use crate::llmsim::model::ModelSpec;

/// Solver inputs for one node at one slot.
pub struct SolverInput<'a> {
    /// The node's model pool.
    pub pool: &'a [ModelSpec],
    /// Current GPU states (for reload accounting).
    pub gpus: &'a [GpuState],
    /// Fitted latency surrogate per (model idx, gpu idx).
    pub fits: &'a [Vec<LatencyFit>],
    /// Static quality score Q_mn per model idx.
    pub quality: &'a [f64],
    /// Queries assigned to this node this slot (p_n^t · B^t).
    pub queries: usize,
    /// Latency budget in seconds: L^t − TS_n^t.
    pub budget_s: f64,
    /// Fraction of each GPU's memory available to generation models
    /// (normally 1.0). The retrieval-cache tier charges its footprint
    /// here: as a node's cache fills, `mem_cap` shrinks and deployments
    /// that no longer fit are pruned — cache bytes genuinely compete with
    /// generation memory.
    pub mem_cap: f64,
}

/// One model's assignment on a GPU.
#[derive(Clone, Debug)]
pub struct ModelAssignment {
    pub model_idx: usize,
    /// Memory fraction R.
    pub mem: f64,
    /// Queries routed to this model.
    pub queries: usize,
}

/// Plan for one GPU.
#[derive(Clone, Debug, Default)]
pub struct GpuPlan {
    pub assignments: Vec<ModelAssignment>,
    /// Reconfiguration (reload) time charged on this GPU.
    pub reload_s: f64,
}

/// Full node plan.
#[derive(Clone, Debug, Default)]
pub struct NodePlan {
    pub gpus: Vec<GpuPlan>,
    /// Σ p·Q objective value (expected quality mass).
    pub objective: f64,
    /// Queries that exceed total capacity (will likely be dropped).
    pub overflow: usize,
}

impl NodePlan {
    /// Deployment maps per GPU (for GpuState::apply).
    pub fn target_maps(&self, pool: &[ModelSpec]) -> Vec<BTreeMap<String, f64>> {
        self.gpus
            .iter()
            .map(|g| {
                g.assignments
                    .iter()
                    .map(|a| (pool[a.model_idx].name.clone(), a.mem))
                    .collect()
            })
            .collect()
    }

    pub fn total_assigned(&self) -> usize {
        self.gpus
            .iter()
            .flat_map(|g| g.assignments.iter())
            .map(|a| a.queries)
            .sum()
    }
}

/// Candidate deployment on one GPU: model indices + memory fractions.
#[derive(Clone, Debug)]
struct GpuCandidate {
    models: Vec<usize>,
    mems: Vec<f64>,
    reload_s: f64,
    /// Max feasible queries per model within (budget − reload).
    capacity: Vec<f64>,
}

const MEM_STEP: f64 = 0.05;

/// Enumerate memory compositions for `models` on a GPU whose generation
/// share is `mem_cap` (≤ 1; the rest is cache footprint), with min-mem
/// constraints, on a MEM_STEP grid. All remaining memory is distributed
/// (more memory never hurts throughput), so compositions always sum to
/// `mem_cap` on the grid.
fn mem_grid(pool: &[ModelSpec], models: &[usize], mem_cap: f64) -> Vec<Vec<f64>> {
    let mins: Vec<f64> = models.iter().map(|&m| pool[m].min_mem).collect();
    let min_sum: f64 = mins.iter().sum();
    if min_sum > mem_cap + 1e-9 {
        return Vec::new();
    }
    let free = mem_cap - min_sum;
    let steps = (free / MEM_STEP).floor() as usize;
    let k = models.len();
    let mut out = Vec::new();
    // compositions of `steps` increments into k parts
    fn rec(k: usize, steps: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if k == 1 {
            cur.push(steps);
            out.push(cur.clone());
            cur.pop();
            return;
        }
        for s in 0..=steps {
            cur.push(s);
            rec(k - 1, steps - s, cur, out);
            cur.pop();
        }
    }
    let mut comps = Vec::new();
    rec(k, steps, &mut Vec::new(), &mut comps);
    for comp in comps {
        let mems: Vec<f64> = (0..k)
            .map(|i| mins[i] + comp[i] as f64 * MEM_STEP)
            .collect();
        out.push(mems);
    }
    out
}

/// All non-empty deployment subsets of the pool feasible within `mem_cap`.
fn subsets(pool: &[ModelSpec], mem_cap: f64) -> Vec<Vec<usize>> {
    let n = pool.len();
    let mut out = Vec::new();
    for mask in 1u32..(1 << n) {
        let models: Vec<usize> = (0..n).filter(|i| mask & (1 << i) != 0).collect();
        let min_sum: f64 = models.iter().map(|&m| pool[m].min_mem).sum();
        if min_sum <= mem_cap + 1e-9 {
            out.push(models);
        }
    }
    out
}

/// Solve one node's intra-scheduling problem.
pub fn solve_node(input: &SolverInput) -> NodePlan {
    let nk = input.gpus.len();
    let mem_cap = input.mem_cap.clamp(0.0, 1.0);
    // Per GPU: enumerate candidates.
    let mut per_gpu: Vec<Vec<GpuCandidate>> = Vec::with_capacity(nk);
    for (k, gpu) in input.gpus.iter().enumerate() {
        let mut cands = Vec::new();
        for models in subsets(input.pool, mem_cap) {
            for mems in mem_grid(input.pool, &models, mem_cap) {
                let target: BTreeMap<String, f64> = models
                    .iter()
                    .zip(&mems)
                    .map(|(&m, &r)| (input.pool[m].name.clone(), r))
                    .collect();
                let reload_s = gpu.reconfig_time(&target, &|name| {
                    input
                        .pool
                        .iter()
                        .find(|m| m.name == name)
                        .map(|m| m.load_time_s)
                        .unwrap_or(0.0)
                });
                let avail = input.budget_s - reload_s;
                if avail <= 0.0 {
                    continue;
                }
                let capacity: Vec<f64> = models
                    .iter()
                    .zip(&mems)
                    .map(|(&m, &r)| input.fits[m][k].max_queries(r, avail))
                    .collect();
                cands.push(GpuCandidate { models: models.clone(), mems, reload_s, capacity });
            }
        }
        // keeping the previous deployment untouched is always a candidate
        per_gpu.push(cands);
    }

    // For each GPU pick the candidate maximizing *quality-weighted
    // capacity* filled greedily; GPUs are independent given the node's
    // query budget is shared — we select candidates jointly by iterating:
    // score each candidate by its greedy quality mass assuming it serves
    // up to the node's remaining demand. Exhaustive cross-product would be
    // |cands|^K; instead exploit that the objective is separable once the
    // query split is greedy-by-quality: evaluate the joint greedy fill for
    // the cross product of the top few candidates per GPU.
    const KEEP: usize = 24;
    let mut shortlists: Vec<Vec<&GpuCandidate>> = Vec::with_capacity(nk);
    for cands in &per_gpu {
        let mut scored: Vec<(f64, &GpuCandidate)> = cands
            .iter()
            .map(|c| {
                // upper-bound score: quality-weighted capacity (capped by demand)
                let mut pairs: Vec<(f64, f64)> = c
                    .models
                    .iter()
                    .zip(&c.capacity)
                    .map(|(&m, &cap)| (input.quality[m], cap))
                    .collect();
                pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                let mut remaining = input.queries as f64;
                let mut mass = 0.0;
                for (q, cap) in pairs {
                    let take = cap.min(remaining);
                    mass += q * take;
                    remaining -= take;
                    if remaining <= 0.0 {
                        break;
                    }
                }
                // Unserved queries are *invalid* (paper: Eq. 4 hard SLO +
                // "queries exceeding the requirement are invalid"), so
                // dropping must never beat serving on a smaller model:
                // charge each projected drop the maximum quality value.
                // A tiny reload penalty then breaks ties toward configs
                // that do not churn deployments they will not use.
                let qual_max = input.quality.iter().cloned().fold(0.0, f64::max);
                (mass - qual_max * remaining.max(0.0) - 1e-3 * c.reload_s, c)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        shortlists.push(scored.into_iter().take(KEEP).map(|(_, c)| c).collect());
    }
    // A GPU may have no feasible candidate at all (every deployment's
    // reload exceeds the budget): represent it as "deploy nothing".
    let empty = GpuCandidate { models: Vec::new(), mems: Vec::new(), reload_s: 0.0, capacity: Vec::new() };
    for sl in shortlists.iter_mut() {
        if sl.is_empty() {
            sl.push(&empty);
        }
    }

    // Joint greedy evaluation over the shortlist cross-product (bounded:
    // 24^2 for dual-GPU nodes).
    let mut best: Option<(f64, Vec<&GpuCandidate>)> = None;
    let mut combo_idx = vec![0usize; nk];
    loop {
        let combo: Vec<&GpuCandidate> = combo_idx
            .iter()
            .enumerate()
            .map(|(k, &i)| shortlists[k][i])
            .collect();
        // greedy fill across all (model, gpu) pairs by quality
        let mut pairs: Vec<(f64, usize, usize, f64)> = Vec::new(); // (quality, gpu, slot, cap)
        for (k, c) in combo.iter().enumerate() {
            for (slot, (&m, &cap)) in c.models.iter().zip(&c.capacity).enumerate() {
                pairs.push((input.quality[m], k, slot, cap));
            }
        }
        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut remaining = input.queries as f64;
        let mut mass = 0.0;
        for &(q, _, _, cap) in &pairs {
            let take = cap.min(remaining);
            mass += q * take;
            remaining -= take;
        }
        let reload_total: f64 = combo.iter().map(|c| c.reload_s).sum();
        let qual_max = input.quality.iter().cloned().fold(0.0, f64::max);
        let score = mass - qual_max * remaining.max(0.0) - 1e-3 * reload_total;
        if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
            best = Some((score, combo));
        }
        // advance cross-product
        let mut k = 0;
        loop {
            if k == nk {
                break;
            }
            combo_idx[k] += 1;
            if combo_idx[k] < shortlists[k].len() {
                break;
            }
            combo_idx[k] = 0;
            k += 1;
        }
        if k == nk {
            break;
        }
    }

    let (objective_mass, combo) = best.expect("at least one candidate combo");

    // Materialize the plan with integral query counts.
    let mut pairs: Vec<(f64, usize, usize)> = Vec::new();
    for (k, c) in combo.iter().enumerate() {
        for slot in 0..c.models.len() {
            pairs.push((input.quality[c.models[slot]], k, slot));
        }
    }
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut remaining = input.queries;
    let mut assigned: Vec<Vec<usize>> = combo.iter().map(|c| vec![0; c.models.len()]).collect();
    for &(_, k, slot) in &pairs {
        let cap = combo[k].capacity[slot].floor() as usize;
        let take = cap.min(remaining);
        assigned[k][slot] = take;
        remaining -= take;
    }
    // Overflow: spread over pairs proportionally to capacity (they will
    // mostly be dropped, but every query must be dispatched — Eq. 8).
    if remaining > 0 {
        let total_cap: f64 = combo.iter().flat_map(|c| c.capacity.iter()).sum();
        if total_cap > 0.0 {
            let mut left = remaining;
            for &(_, k, slot) in &pairs {
                let share = ((combo[k].capacity[slot] / total_cap)
                    * remaining as f64)
                    .round() as usize;
                let add = share.min(left);
                assigned[k][slot] += add;
                left -= add;
                if left == 0 {
                    break;
                }
            }
            if left > 0 && !pairs.is_empty() {
                let (_, k, slot) = pairs[0];
                assigned[k][slot] += left;
                left = 0;
            }
            remaining = left;
        }
    }

    let gpus: Vec<GpuPlan> = combo
        .iter()
        .enumerate()
        .map(|(k, c)| GpuPlan {
            assignments: c
                .models
                .iter()
                .enumerate()
                .filter(|(slot, _)| assigned[k][*slot] > 0)
                .map(|(slot, &m)| ModelAssignment {
                    model_idx: m,
                    mem: c.mems[slot],
                    queries: assigned[k][slot],
                })
                .collect(),
            reload_s: c.reload_s,
        })
        .collect();

    NodePlan { gpus, objective: objective_mass, overflow: remaining }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::intranode::latfit::LatencyProfiler;
    use crate::llmsim::latency::LatencyGroundTruth;
    use crate::llmsim::model::standard_pool;

    fn make_fits(pool: &[ModelSpec], gpus: usize) -> Vec<Vec<LatencyFit>> {
        let gt = LatencyGroundTruth::default();
        let prof = LatencyProfiler::default();
        pool.iter()
            .map(|m| (0..gpus).map(|g| prof.fit_production(&gt, m, 40 + g as u64)).collect())
            .collect()
    }

    fn input_quality() -> Vec<f64> {
        vec![0.62, 0.76, 0.85] // small < mid < large
    }

    #[test]
    fn strict_budget_prefers_small_models() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0)];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 120,
            budget_s: 4.0,
            mem_cap: 1.0,
        });
        // most queries must land on the small model
        let mut per_model = vec![0usize; 3];
        for g in &plan.gpus {
            for a in &g.assignments {
                per_model[a.model_idx] += a.queries;
            }
        }
        assert!(
            per_model[0] > per_model[2],
            "small={} large={} (plan: {plan:?})",
            per_model[0],
            per_model[2]
        );
        assert_eq!(plan.total_assigned(), 120);
    }

    #[test]
    fn relaxed_budget_prefers_large_models() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0)];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 60,
            budget_s: 30.0,
            mem_cap: 1.0,
        });
        let mut per_model = vec![0usize; 3];
        for g in &plan.gpus {
            for a in &g.assignments {
                per_model[a.model_idx] += a.queries;
            }
        }
        assert!(
            per_model[2] >= per_model[0],
            "large={} small={}",
            per_model[2],
            per_model[0]
        );
    }

    #[test]
    fn memory_constraints_respected() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0), GpuState::new(1.2)];
        let fits = make_fits(&pool, 2);
        let q = input_quality();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 300,
            budget_s: 10.0,
            mem_cap: 1.0,
        });
        for g in &plan.gpus {
            let mem: f64 = g.assignments.iter().map(|a| a.mem).sum();
            assert!(mem <= 1.0 + 1e-9, "mem={mem}");
            for a in &g.assignments {
                assert!(a.mem >= pool[a.model_idx].min_mem - 1e-9);
            }
        }
        assert_eq!(plan.total_assigned() + plan.overflow, 300);
    }

    #[test]
    fn reload_cost_discourages_churn() {
        let pool = standard_pool();
        // GPU currently running the small model at full memory
        let mut gpu = GpuState::new(1.0);
        let mut cur = BTreeMap::new();
        cur.insert("llama-1b".to_string(), 1.0);
        gpu.apply(cur);
        let gpus = vec![gpu];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        // tight budget: switching to mid would cost 1.8 s of the 2.5 s budget
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 80,
            budget_s: 2.5,
            mem_cap: 1.0,
        });
        // must keep the small model deployed (reload-free) and serve on it
        let small_served: usize = plan.gpus[0]
            .assignments
            .iter()
            .filter(|a| a.model_idx == 0)
            .map(|a| a.queries)
            .sum();
        assert!(small_served > 40, "{plan:?}");
    }

    #[test]
    fn overload_reported_as_overflow() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0)];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 100_000,
            budget_s: 5.0,
            mem_cap: 1.0,
        });
        assert!(plan.overflow > 0 || plan.total_assigned() == 100_000);
        assert_eq!(plan.total_assigned() + plan.overflow, 100_000);
    }

    #[test]
    fn mem_cap_shrinks_generation_memory() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0)];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        let solve = |mem_cap: f64| {
            solve_node(&SolverInput {
                pool: &pool,
                gpus: &gpus,
                fits: &fits,
                quality: &q,
                queries: 60,
                budget_s: 30.0,
                mem_cap,
            })
        };
        // every deployment respects the cap
        for cap in [1.0, 0.6, 0.35] {
            let plan = solve(cap);
            for g in &plan.gpus {
                let mem: f64 = g.assignments.iter().map(|a| a.mem).sum();
                assert!(mem <= cap + 1e-9, "cap {cap}: deployed {mem}");
            }
        }
        // a cap below the largest model's min_mem forces it off the GPU
        let largest_min = pool.iter().map(|m| m.min_mem).fold(0.0, f64::max);
        let plan = solve(largest_min - 0.05);
        for g in &plan.gpus {
            for a in &g.assignments {
                assert!(
                    pool[a.model_idx].min_mem < largest_min,
                    "cap excludes the largest model, got {:?}",
                    pool[a.model_idx].name
                );
            }
        }
        // a cap below every min_mem deploys nothing: all queries overflow
        let smallest_min = pool.iter().map(|m| m.min_mem).fold(1.0, f64::min);
        let plan = solve(smallest_min / 2.0);
        assert_eq!(plan.total_assigned(), 0);
        assert_eq!(plan.overflow, 60);
    }

    #[test]
    fn empty_node_plan() {
        let pool = standard_pool();
        let gpus = vec![GpuState::new(1.0)];
        let fits = make_fits(&pool, 1);
        let q = input_quality();
        let plan = solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &q,
            queries: 0,
            budget_s: 10.0,
            mem_cap: 1.0,
        });
        assert_eq!(plan.total_assigned(), 0);
        assert_eq!(plan.overflow, 0);
    }
}
