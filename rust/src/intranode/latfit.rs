//! Latency surrogate fitting (paper Table I + Eq. 13).
//!
//! The ground-truth latency L(Q, R) has no closed form available to the
//! scheduler; we sample it on a (load × memory) grid with measurement
//! noise, fit four convex-candidate families by linear least squares on
//! basis expansions, and compare held-out RMSE. The quadratic family is
//! the paper's surrogate:
//!     L̃ = (a·Q − b·R)² + c·Q + d·R + e + ΔT            (Eq. 13)
//! which expands to the full bivariate quadratic basis fitted here.

use crate::llmsim::latency::LatencyGroundTruth;
use crate::llmsim::model::ModelSpec;
use crate::util::rng::Rng;
use crate::util::stats::{least_squares, predict_linear, rmse};

/// Surrogate families (paper Table I rows are per model, columns these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitFamily {
    Linear,
    Quadratic,
    Exponential,
    Cubic,
}

impl FitFamily {
    pub const ALL: [FitFamily; 4] = [
        FitFamily::Linear,
        FitFamily::Quadratic,
        FitFamily::Exponential,
        FitFamily::Cubic,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            FitFamily::Linear => "Linear",
            FitFamily::Quadratic => "Quadratic",
            FitFamily::Exponential => "Exponential",
            FitFamily::Cubic => "Cubic",
        }
    }

    /// Basis expansion of normalized (q̂, r̂).
    fn features(&self, q: f64, r: f64) -> Vec<f64> {
        match self {
            FitFamily::Linear => vec![1.0, q, r],
            FitFamily::Quadratic => vec![1.0, q, r, q * q, q * r, r * r],
            FitFamily::Exponential => {
                vec![1.0, q, (-r).exp(), q * (-r).exp(), (0.5 * q).exp()]
            }
            FitFamily::Cubic => vec![
                1.0,
                q,
                r,
                q * q,
                q * r,
                r * r,
                q * q * q,
                q * q * r,
                q * r * r,
                r * r * r,
            ],
        }
    }
}

/// A fitted latency surrogate for one model (on one GPU class).
#[derive(Clone, Debug)]
pub struct LatencyFit {
    pub family: FitFamily,
    pub weights: Vec<f64>,
    /// Query normalization scale.
    pub q_scale: f64,
    /// Systematic robustness offset ΔT added to predictions (Eq. 13).
    pub delta_t: f64,
    /// Relative RMSE of the fit on its training samples — drives the
    /// self-calibrating capacity safety margin.
    pub rel_err: f64,
}

impl LatencyFit {
    pub fn predict(&self, q: f64, r: f64) -> f64 {
        let feats = self.family.features(q / self.q_scale, r);
        (predict_linear(&self.weights, &feats) + self.delta_t).max(0.0)
    }

    /// Largest query count with predicted latency ≤ budget (bisection; the
    /// surrogate is monotone increasing in q over the fitted range).
    ///
    /// A multiplicative safety margin (part of the paper's ΔT robustness
    /// term) reserves headroom for surrogate error: the scheduler plans to
    /// ~93% of the predicted limit, keeping the realized drop rate near
    /// zero when the quadratic fit is a few percent optimistic.
    pub fn max_queries(&self, r: f64, budget_s: f64) -> f64 {
        let margin = (1.0 + 1.3 * self.rel_err).clamp(1.05, 1.40);
        let pred = |q: f64| self.predict(q, r) * margin;
        if pred(1.0) > budget_s {
            return 0.0;
        }
        let (mut lo, mut hi) = (1.0, 10.0);
        while pred(hi) < budget_s && hi < 1e7 {
            hi *= 2.0;
        }
        for _ in 0..50 {
            let mid = 0.5 * (lo + hi);
            if pred(mid) <= budget_s {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Profiles a (model, GPU) pair against the ground truth and fits all
/// four families.
pub struct LatencyProfiler {
    /// Max query count in the profiling sweep.
    pub q_max: f64,
    /// Number of load levels × memory levels in the grid.
    pub q_levels: usize,
    pub r_levels: usize,
    pub delta_t: f64,
}

impl Default for LatencyProfiler {
    fn default() -> Self {
        LatencyProfiler { q_max: 2400.0, q_levels: 22, r_levels: 11, delta_t: 0.05 }
    }
}

/// One profiling sample.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub q: f64,
    pub r: f64,
    pub latency: f64,
}

impl LatencyProfiler {
    /// Measure a (Q, R) grid with noise.
    ///
    /// Loads are geometrically spaced from 2 to q_max so the low-load
    /// region — where the scheduler's smallest decisions live — is sampled
    /// as densely as the overload corner; memory levels include both
    /// endpoints (min_mem and 1.0) so the solver never extrapolates.
    pub fn collect(
        &self,
        gt: &LatencyGroundTruth,
        m: &ModelSpec,
        rng: &mut Rng,
    ) -> Vec<Sample> {
        let mut samples = Vec::new();
        let q_lo: f64 = 2.0;
        let ratio = (self.q_max / q_lo).powf(1.0 / (self.q_levels.max(2) - 1) as f64);
        for qi in 0..self.q_levels {
            let q = (q_lo * ratio.powi(qi as i32)).min(self.q_max);
            for ri in 0..self.r_levels {
                let r = m.min_mem
                    + (1.0 - m.min_mem) * ri as f64 / (self.r_levels.max(2) - 1) as f64;
                samples.push(Sample { q, r, latency: gt.measure(m, q, r, rng) });
            }
        }
        samples
    }

    /// Fit one family on training samples.
    ///
    /// Weighted (relative) least squares: latency spans ~2 orders of
    /// magnitude over the profiling grid, and the scheduler needs accuracy
    /// across the whole operating range, not just at the overload corner —
    /// so each sample is weighted by 1/latency (row and target scaled),
    /// minimizing relative error.
    pub fn fit(&self, family: FitFamily, train: &[Sample]) -> Option<LatencyFit> {
        let q_scale = self.q_max;
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(train.len());
        let mut y: Vec<f64> = Vec::with_capacity(train.len());
        for s in train {
            let w = 1.0 / s.latency.max(0.25);
            let mut feats = family.features(s.q / q_scale, s.r);
            for f in feats.iter_mut() {
                *f *= w;
            }
            rows.push(feats);
            y.push(s.latency * w);
        }
        let weights = least_squares(&rows, &y)?;
        let mut fit =
            LatencyFit { family, weights, q_scale, delta_t: self.delta_t, rel_err: 0.0 };
        // Safety margin calibration: p95 of relative *under*-prediction on
        // the training grid. The corners (min memory, high load) are where
        // the quadratic is weakest and also exactly where over-trusting it
        // causes SLO violations, so the margin tracks the tail error, not
        // the average.
        let mut under: Vec<f64> = train
            .iter()
            .map(|s| {
                ((s.latency - (fit.predict(s.q, s.r) - fit.delta_t)) / s.latency.max(0.25))
                    .max(0.0)
            })
            .collect();
        under.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = under[(under.len() as f64 * 0.95) as usize % under.len()];
        fit.rel_err = p95;
        Some(fit)
    }

    /// RMSE of a fit on held-out samples (ΔT excluded: it is a safety
    /// margin, not part of the model).
    pub fn heldout_rmse(fit: &LatencyFit, test: &[Sample]) -> f64 {
        let pred: Vec<f64> = test.iter().map(|s| fit.predict(s.q, s.r) - fit.delta_t).collect();
        let y: Vec<f64> = test.iter().map(|s| s.latency).collect();
        rmse(&pred, &y)
    }

    /// Full Table-I style comparison: train/test split, fit all families,
    /// return (family, rmse) pairs.
    pub fn compare_families(
        &self,
        gt: &LatencyGroundTruth,
        m: &ModelSpec,
        seed: u64,
    ) -> Vec<(FitFamily, f64)> {
        let mut rng = Rng::new(seed);
        let mut samples = self.collect(gt, m, &mut rng);
        rng.shuffle(&mut samples);
        let split = samples.len() * 7 / 10;
        let (train, test) = samples.split_at(split);
        FitFamily::ALL
            .iter()
            .map(|&fam| {
                let fit = self.fit(fam, train).expect("fit");
                (fam, Self::heldout_rmse(&fit, test))
            })
            .collect()
    }

    /// Fit the production surrogate (quadratic, per the paper).
    pub fn fit_production(
        &self,
        gt: &LatencyGroundTruth,
        m: &ModelSpec,
        seed: u64,
    ) -> LatencyFit {
        let mut rng = Rng::new(seed);
        let samples = self.collect(gt, m, &mut rng);
        self.fit(FitFamily::Quadratic, &samples).expect("quadratic fit")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llmsim::model::standard_pool;

    #[test]
    fn quadratic_beats_linear() {
        let gt = LatencyGroundTruth::default();
        let prof = LatencyProfiler::default();
        for m in &standard_pool() {
            let res = prof.compare_families(&gt, m, 11);
            let get = |f: FitFamily| res.iter().find(|(x, _)| *x == f).unwrap().1;
            assert!(
                get(FitFamily::Quadratic) < get(FitFamily::Linear),
                "{}: quad {} vs lin {}",
                m.name,
                get(FitFamily::Quadratic),
                get(FitFamily::Linear)
            );
        }
    }

    #[test]
    fn production_fit_accurate() {
        let gt = LatencyGroundTruth::default();
        let prof = LatencyProfiler::default();
        let m = &standard_pool()[1];
        let fit = prof.fit_production(&gt, m, 5);
        // prediction within 25% + ΔT across the operating range (the
        // p95-calibrated capacity margin absorbs the residual error; see
        // max_queries)
        for q in [40.0, 120.0, 280.0] {
            for r in [0.4, 0.6, 0.9] {
                let truth = gt.latency(m, q, r);
                let pred = fit.predict(q, r);
                assert!(
                    (pred - truth).abs() <= 0.25 * truth + fit.delta_t + 0.05,
                    "q={q} r={r}: pred={pred:.3} truth={truth:.3}"
                );
            }
        }
    }

    #[test]
    fn max_queries_consistent_with_prediction() {
        let gt = LatencyGroundTruth::default();
        let prof = LatencyProfiler::default();
        let m = &standard_pool()[0];
        let fit = prof.fit_production(&gt, m, 7);
        let budget = 5.0;
        let qmax = fit.max_queries(0.8, budget);
        let margin = (1.0 + 1.3 * fit.rel_err).clamp(1.05, 1.40);
        assert!(qmax > 0.0);
        // margin-adjusted prediction sits exactly at the budget
        assert!(fit.predict(qmax, 0.8) * margin <= budget + 1e-6);
        assert!(fit.predict(qmax + 2.0, 0.8) * margin > budget);
    }

    #[test]
    fn surrogate_monotone_in_load_on_range() {
        let gt = LatencyGroundTruth::default();
        let prof = LatencyProfiler::default();
        let m = &standard_pool()[1];
        let fit = prof.fit_production(&gt, m, 9);
        let mut prev = 0.0;
        for qi in 1..10 {
            let q = 40.0 * qi as f64;
            let l = fit.predict(q, 0.7);
            assert!(l >= prev - 1e-9, "q={q}");
            prev = l;
        }
    }
}
