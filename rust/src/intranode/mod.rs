//! Adaptive intra-node scheduling (paper §IV-C).
//!
//! - [`latfit`]: fits the four candidate latency surrogates
//!   (linear/quadratic/exponential/cubic) to measured (Q, R, latency)
//!   samples and selects by held-out RMSE — Table I. The quadratic form is
//!   the paper's Eq. 13.
//! - [`quality`]: the offline "open-book" evaluation producing the static
//!   per-(model, node) quality score Q_mn.
//! - [`solver`]: deployment enumeration + memory-grid / greedy query
//!   allocation solving the convex program Eq. 25–29, including the
//!   LD/RLD/ULD reload accounting of Eq. 19–24.

pub mod latfit;
pub mod quality;
pub mod solver;

pub use latfit::{FitFamily, LatencyFit, LatencyProfiler};
pub use solver::{solve_node, GpuPlan, ModelAssignment, NodePlan, SolverInput};
