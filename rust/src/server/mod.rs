//! TCP serving front-end: line-delimited JSON protocol, bounded admission
//! queue with explicit backpressure, a dynamic batcher, and an optionally
//! pipelined execution engine.
//!
//! The paper serves through vLLM; offline we expose the coordinator over
//! a minimal wire protocol (std::net + the crate's own threads — tokio is
//! unavailable in this build environment, DESIGN.md §5).
//!
//! # Wire protocol (one JSON object per line)
//!
//! Request: `{"id": 7, "qa_id": 123}` — `id` is an opaque client-chosen
//! correlation number echoed back verbatim; `qa_id` indexes the loaded
//! dataset's QA pairs.
//!
//! Success response:
//! `{"id": 7, "node": 2, "dropped": false, "rouge_l": 0.61,
//!   "bert_score": 0.74, "sim_latency_s": 3.2, "wall_s": 0.004}`
//!
//! - `node` — the edge node that served (or admitted then dropped) the
//!   query. `null` when the query was **shed at the coordinator** and
//!   never routed to any node (every node down); internally that state is
//!   `usize::MAX`, which older builds leaked onto the wire as a
//!   meaningless ~1.8e19 float.
//! - `dropped` — the query missed its SLO (or was shed; shed responses
//!   always pair `dropped: true` with `node: null`).
//! - `sim_latency_s` — modeled latency (deterministic, ADR-001);
//!   `wall_s` is the measured batch wall-clock and is the only
//!   machine-dependent field.
//!
//! Error response: `{"id": 7, "error": "..."}`, plus
//! `"retriable": true` when the admission queue was full — the explicit
//! backpressure signal (the queue is bounded by
//! [`ServerConfig::queue_depth`]; an overloaded server answers
//! immediately instead of buffering without limit).
//!
//! # Engine
//!
//! Connections are handled by shutdown-aware reader threads that admit
//! requests without waiting for their responses, so one connection can
//! pipeline any number of requests (responses stream back from a
//! per-connection writer thread and are matched by `id`; ordering across
//! in-flight requests is not guaranteed — error responses in particular
//! can overtake batched successes). Admitted requests are collected by
//! the dynamic batcher until the batch window elapses or `max_batch`
//! requests are pending — the policy every modern LLM server (vLLM/Orca)
//! applies at its front door — then dispatched as one coordinator slot.
//! With [`ServerConfig::pipeline`] enabled, batches flow through a
//! two-stage engine on the coordinator's phase seam: a dedicated encode
//! stage embeds batch `k+1` while the execute stage routes/serves batch
//! `k` ([`Coordinator::run_slot_encoded`]). Pipelining changes wall-clock
//! only, never responses or transcripts.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::node::QueryOutcome;
use crate::coordinator::observer::{SlotEvent, SlotObserver};
use crate::coordinator::pipeline::encode_batch;
use crate::coordinator::{Coordinator, SlotReport};
use crate::log_info;
use crate::util::json::Json;
use crate::Result;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// TCP listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Dynamic batching window: from the first pending request, further
    /// requests are collected this long before the batch dispatches.
    pub batch_window_ms: u64,
    /// Dispatch immediately once this many requests are pending, without
    /// waiting out the batch window.
    pub max_batch: usize,
    /// Bound of the admission queue (clamped to ≥ 1). When the queue is
    /// full, new requests are answered immediately with
    /// `{"error": "overloaded...", "retriable": true}` instead of being
    /// buffered without limit — explicit backpressure the client can act
    /// on (back off and retry).
    pub queue_depth: usize,
    /// Overlap encoding of batch `k+1` with serving of batch `k` through
    /// the coordinator's pipelined phase seam. Affects wall-clock only;
    /// responses and transcripts are byte-identical either way.
    pub pipeline: bool,
    /// Socket read timeout for connection handler threads: how often an
    /// idle connection's reader wakes to re-check the shutdown flag.
    /// Bounds the server's shutdown latency (idle connections used to
    /// block `serve` forever on join).
    pub read_timeout_ms: u64,
    /// When set, record a byte-stable [`RunTranscript`](crate::scenario::RunTranscript)
    /// of every dispatched batch and write it here at shutdown — the same
    /// JSONL format the scenario replay harness asserts on.
    pub transcript_path: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7717".into(),
            batch_window_ms: 20,
            max_batch: 256,
            queue_depth: 1024,
            pipeline: false,
            read_timeout_ms: 50,
            transcript_path: None,
        }
    }
}

/// One admitted request waiting for its slot: the reply sender is a clone
/// of its connection's writer channel, so responses stream back the
/// moment the batch completes.
struct Pending {
    request_id: f64,
    qa_id: usize,
    reply: Sender<String>,
}

/// A batch travelling through the execution engine: the pending requests
/// plus, once the encode stage has run, their embeddings and the encode
/// wall-clock.
struct EngineBatch {
    pending: Vec<Pending>,
    encoded: Option<(Vec<Vec<f32>>, f64)>,
}

#[derive(Clone, Copy, Debug, Default)]
struct MetricsInner {
    slots: usize,
    queries: usize,
    dropped: usize,
    updates: usize,
    makespan_s: f64,
    cache_hits: usize,
    cache_misses: usize,
}

/// Live serving metrics, fed by coordinator [`SlotEvent`]s as batches are
/// dispatched (no post-hoc report scraping). One clone lives inside the
/// coordinator; the server keeps another to read totals.
#[derive(Clone, Default)]
pub struct ServerMetrics {
    inner: Arc<std::sync::Mutex<MetricsInner>>,
}

impl ServerMetrics {
    /// (slots, queries, dropped) served so far.
    pub fn totals(&self) -> (usize, usize, usize) {
        let m = self.inner.lock().unwrap();
        (m.slots, m.queries, m.dropped)
    }

    /// (cache hits, cache misses) across both cache levels so far — all
    /// zero when no cache tier is configured.
    pub fn cache_totals(&self) -> (usize, usize) {
        let m = self.inner.lock().unwrap();
        (m.cache_hits, m.cache_misses)
    }

    /// One-line summary for shutdown logging.
    fn summary(&self) -> String {
        let m = self.inner.lock().unwrap();
        let cache = if m.cache_hits + m.cache_misses > 0 {
            format!(
                ", cache hit rate {:.1}%",
                m.cache_hits as f64 / (m.cache_hits + m.cache_misses) as f64 * 100.0
            )
        } else {
            String::new()
        };
        format!(
            "served {} queries in {} batches ({} dropped, {} policy updates, \
             peak makespan {:.2}s{cache})",
            m.queries, m.slots, m.dropped, m.updates, m.makespan_s
        )
    }
}

impl SlotObserver for ServerMetrics {
    fn on_event(&mut self, event: &SlotEvent) {
        match event {
            SlotEvent::Feedback { stats, .. } => {
                self.inner.lock().unwrap().updates += stats.updates;
            }
            SlotEvent::SlotEnd { report, .. } => {
                let mut m = self.inner.lock().unwrap();
                m.slots += 1;
                m.queries += report.queries;
                m.dropped += report.outcomes.iter().filter(|o| o.dropped).count();
                m.makespan_s = m.makespan_s.max(report.latency_s);
                if let Some(c) = &report.cache {
                    m.cache_hits += c.hits();
                    m.cache_misses += c.misses();
                }
                log_info!(
                    "batch {}: {} queries, drop {:.1}%, makespan {:.2}s",
                    m.slots,
                    report.queries,
                    report.drop_rate * 100.0,
                    report.latency_s
                );
            }
            _ => {}
        }
    }
}

/// Wire response for one served outcome. A query shed at the coordinator
/// was never routed anywhere (internally `node == usize::MAX`): its
/// `node` field is `null` on the wire, never a cast-to-float sentinel.
fn outcome_response(request_id: f64, out: &QueryOutcome, wall_s: f64) -> String {
    let node =
        if out.node == usize::MAX { Json::Null } else { Json::Num(out.node as f64) };
    Json::obj(vec![
        ("id", Json::Num(request_id)),
        ("node", node),
        ("dropped", Json::Bool(out.dropped)),
        ("rouge_l", Json::Num(out.scores.rouge_l)),
        ("bert_score", Json::Num(out.scores.bert_score)),
        ("sim_latency_s", Json::Num(out.latency_s)),
        ("wall_s", Json::Num(wall_s)),
    ])
    .to_string()
}

fn error_response(request_id: f64, error: &str) -> String {
    Json::obj(vec![
        ("id", Json::Num(request_id)),
        ("error", Json::Str(error.to_string())),
    ])
    .to_string()
}

fn overload_response(request_id: f64, queue_depth: usize) -> String {
    Json::obj(vec![
        ("id", Json::Num(request_id)),
        (
            "error",
            Json::Str(format!("overloaded: admission queue full ({queue_depth} pending)")),
        ),
        ("retriable", Json::Bool(true)),
    ])
    .to_string()
}

/// Answer every pending request of one dispatched batch — each exactly
/// once, no matter what the slot produced. A length mismatch between
/// requests and outcomes is an internal invariant violation; it used to
/// truncate the zip silently, dropping the unmatched requests' reply
/// senders so their connections died mid-protocol with no response. Now
/// the whole batch gets an explicit error response instead.
fn respond_batch(pending: Vec<Pending>, result: Result<SlotReport>, wall_s: f64) {
    match result {
        Ok(report) if report.outcomes.len() == pending.len() => {
            for (p, out) in pending.into_iter().zip(&report.outcomes) {
                let _ = p.reply.send(outcome_response(p.request_id, out, wall_s));
            }
        }
        Ok(report) => {
            let msg = format!(
                "internal error: slot produced {} outcomes for {} requests",
                report.outcomes.len(),
                pending.len()
            );
            for p in pending {
                let _ = p.reply.send(error_response(p.request_id, &msg));
            }
        }
        Err(e) => {
            for p in pending {
                let _ = p.reply.send(error_response(p.request_id, &format!("{e}")));
            }
        }
    }
}

/// What became of an admission attempt.
enum Admit {
    /// Queued; the response will arrive via the request's reply channel.
    Accepted,
    /// Not queued; send this response to the client instead.
    Rejected(String),
}

/// Admit one parsed request into the bounded queue, or produce the
/// response to send instead: the backpressure overload response when the
/// queue is full, a shutdown notice once the engine has gone away.
fn admit(p: Pending, tx: &SyncSender<Pending>, queue_depth: usize) -> Admit {
    match tx.try_send(p) {
        Ok(()) => Admit::Accepted,
        Err(TrySendError::Full(p)) => {
            Admit::Rejected(overload_response(p.request_id, queue_depth))
        }
        Err(TrySendError::Disconnected(p)) => {
            Admit::Rejected(error_response(p.request_id, "server shutting down"))
        }
    }
}

/// Parse one request line and either admit it (its response will flow
/// through `resp`) or return the immediate response to write back
/// (malformed request, unknown `qa_id`, backpressure, shutdown).
fn handle_line(
    line: &str,
    tx: &SyncSender<Pending>,
    resp: &Sender<String>,
    qa_count: usize,
    queue_depth: usize,
) -> Option<String> {
    let v = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Some(
                Json::obj(vec![("error", Json::Str(format!("parse: {e}")))]).to_string(),
            )
        }
    };
    let request_id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(-1.0);
    let qa_id = match v.get("qa_id").and_then(|x| x.as_usize()) {
        Some(q) => q,
        None => return Some(error_response(request_id, "missing qa_id")),
    };
    if qa_id >= qa_count {
        // validated at admission: an out-of-range id would otherwise
        // panic the execution engine when the slot indexes the dataset
        return Some(error_response(
            request_id,
            &format!("qa_id {qa_id} out of range (dataset has {qa_count} QA pairs)"),
        ));
    }
    match admit(Pending { request_id, qa_id, reply: resp.clone() }, tx, queue_depth) {
        Admit::Accepted => None,
        Admit::Rejected(r) => Some(r),
    }
}

/// Run the server until `shutdown` is set. Returns the bound address
/// after a clean drain: handlers join (bounded by the read timeout), the
/// batcher flushes its pending batch, the engine finishes in-flight
/// slots, and the optional transcript is written.
pub fn serve(
    mut coordinator: Coordinator,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let queue_depth = cfg.queue_depth.max(1);
    let (req_tx, req_rx) = sync_channel::<Pending>(queue_depth);

    // live metrics through the coordinator's observer hook (chained after
    // any observers the caller attached)
    let metrics = ServerMetrics::default();
    coordinator.add_observer(Box::new(metrics.clone()));

    // optional replayable transcript of every dispatched batch
    let recorder = cfg.transcript_path.as_ref().map(|_| {
        let rec = crate::scenario::TranscriptRecorder::new(
            "serve",
            coordinator.cfg.seed,
            coordinator.nodes.len(),
            coordinator.allocator().name(),
        );
        coordinator.add_observer(Box::new(rec.clone()));
        rec
    });

    // the encode stage needs the embedder and query texts without
    // holding the coordinator, which the execute stage owns
    let embedder = coordinator.embedder.clone();
    let query_texts: Vec<String> =
        coordinator.ds.qa_pairs.iter().map(|p| p.query.clone()).collect();
    let qa_count = query_texts.len();

    // batcher: admission queue → batches (window / max_batch policy)
    let (batch_tx, batch_rx) = sync_channel::<EngineBatch>(1);
    let batch_shutdown = Arc::clone(&shutdown);
    let window = Duration::from_millis(cfg.batch_window_ms);
    let max_batch = cfg.max_batch.max(1);
    let batcher = std::thread::Builder::new()
        .name("coedge-batcher".into())
        .spawn(move || {
            let mut pending: Vec<Pending> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                if batch_shutdown.load(Ordering::Relaxed) && pending.is_empty() {
                    break;
                }
                let timeout = deadline
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::from_millis(50));
                match req_rx.recv_timeout(timeout) {
                    Ok(p) => {
                        if pending.is_empty() {
                            deadline = Some(Instant::now() + window);
                        }
                        pending.push(p);
                        if pending.len() < max_batch
                            && deadline.map(|d| Instant::now() < d).unwrap_or(false)
                        {
                            continue;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if pending.is_empty() {
                            continue;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        if pending.is_empty() {
                            break;
                        }
                    }
                }
                let batch = EngineBatch { pending: std::mem::take(&mut pending), encoded: None };
                if batch_tx.send(batch).is_err() {
                    break; // engine gone; nothing left to dispatch to
                }
                deadline = None;
            }
        })
        .expect("spawn batcher");

    // optional encode stage: embeds batch k+1 while the execute stage
    // serves batch k (the coordinator's pipelined phase seam)
    let (exec_rx, encoder) = if cfg.pipeline {
        let (exec_tx, exec_rx) = sync_channel::<EngineBatch>(1);
        let handle = std::thread::Builder::new()
            .name("coedge-encoder".into())
            .spawn(move || {
                while let Ok(mut batch) = batch_rx.recv() {
                    let qa_ids: Vec<usize> =
                        batch.pending.iter().map(|p| p.qa_id).collect();
                    let t = Instant::now();
                    let embs = encode_batch(&embedder, &query_texts, &qa_ids, 1);
                    batch.encoded = Some((embs, t.elapsed().as_secs_f64()));
                    if exec_tx.send(batch).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn encoder");
        (exec_rx, Some(handle))
    } else {
        (batch_rx, None)
    };

    // execute stage: owns the coordinator, runs one slot per batch, and
    // answers every request of the batch exactly once
    let executor = std::thread::Builder::new()
        .name("coedge-executor".into())
        .spawn(move || {
            let mut co = coordinator;
            while let Ok(batch) = exec_rx.recv() {
                let qa_ids: Vec<usize> = batch.pending.iter().map(|p| p.qa_id).collect();
                let wall = Instant::now();
                let result = match batch.encoded {
                    Some((embs, enc_s)) => co.run_slot_encoded(&qa_ids, embs, enc_s),
                    None => co.run_slot(&qa_ids),
                };
                respond_batch(batch.pending, result, wall.elapsed().as_secs_f64());
            }
        })
        .expect("spawn executor");

    // accept loop (non-blocking poll so shutdown is honored)
    let read_timeout = Duration::from_millis(cfg.read_timeout_ms.max(1));
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = req_tx.clone();
                let sd = Arc::clone(&shutdown);
                handlers.push(
                    std::thread::Builder::new()
                        .name("coedge-conn".into())
                        .spawn(move || {
                            handle_client(stream, tx, sd, qa_count, queue_depth, read_timeout)
                        })
                        .expect("spawn handler"),
                );
                // reap handlers whose connections already closed so a
                // long-lived server doesn't accumulate dead join handles
                handlers.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    // drain order matters: releasing the main admission sender and
    // joining the handlers (each drops its clone) disconnects the
    // batcher, whose exit drops the batch channel, which winds down the
    // encode and execute stages in turn
    drop(req_tx);
    for h in handlers {
        let _ = h.join();
    }
    let _ = batcher.join();
    if let Some(h) = encoder {
        let _ = h.join();
    }
    let _ = executor.join();
    if let (Some(path), Some(rec)) = (&cfg.transcript_path, &recorder) {
        match rec.snapshot().write_to(path) {
            Ok(()) => log_info!("transcript written to {}", path.display()),
            Err(e) => log_info!("transcript write to {} failed: {e}", path.display()),
        }
    }
    log_info!("{}", metrics.summary());
    Ok(addr)
}

/// Per-connection handler: a reader loop that admits requests without
/// waiting for their responses (true request pipelining — a client may
/// keep any number of requests in flight) and a writer thread that
/// streams responses back as their batches complete. The socket read
/// timeout makes the loop shutdown-aware: an idle connection wakes every
/// `read_timeout` to re-check the flag instead of blocking in `read`
/// forever — the old handler hung `serve`'s join on any idle client.
fn handle_client(
    stream: TcpStream,
    tx: SyncSender<Pending>,
    shutdown: Arc<AtomicBool>,
    qa_count: usize,
    queue_depth: usize,
    read_timeout: Duration,
) {
    let _ = stream.set_read_timeout(Some(read_timeout));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let (resp_tx, resp_rx) = channel::<String>();
    let writer_thread = std::thread::Builder::new()
        .name("coedge-conn-writer".into())
        .spawn(move || {
            while let Ok(resp) = resp_rx.recv() {
                if writer.write_all(resp.as_bytes()).is_err()
                    || writer.write_all(b"\n").is_err()
                {
                    break;
                }
            }
        })
        .expect("spawn connection writer");

    let mut buf = String::new();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut buf) {
            Ok(0) => break, // EOF: client closed its write side
            Ok(_) => {
                let line = std::mem::take(&mut buf);
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                if let Some(resp) = handle_line(line, &tx, &resp_tx, qa_count, queue_depth)
                {
                    if resp_tx.send(resp).is_err() {
                        break;
                    }
                }
            }
            // timed out waiting for a newline: loop to re-check shutdown.
            // Any partially read line stays accumulated in `buf` —
            // read_line only returns Ok at a newline or EOF.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(_) => break,
        }
    }
    // release our admission sender first — the batcher only drains once
    // every sender is gone — then let the writer flush responses still in
    // flight before closing the connection
    drop(tx);
    drop(resp_tx);
    let _ = writer_thread.join();
}

/// Minimal blocking client for examples/tests, with support for request
/// pipelining: [`send`](Client::send) any number of requests, then
/// [`recv`](Client::recv) the responses and match them by `id` (the
/// server does not guarantee response order across in-flight requests).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running server.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request without waiting for its response (pipelining).
    pub fn send(&mut self, id: u64, qa_id: usize) -> Result<()> {
        let req = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("qa_id", Json::Num(qa_id as f64)),
        ]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        Ok(())
    }

    /// Block for the next response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("client parse: {e}"))
    }

    /// Send one request and wait for its response.
    pub fn request(&mut self, id: u64, qa_id: usize) -> Result<Json> {
        self.send(id, qa_id)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AllocatorKind, DatasetKind, ExperimentConfig};
    use crate::coordinator::CoordinatorBuilder;
    use crate::metrics::QualityScores;
    use std::sync::mpsc::Receiver;

    fn pending(request_id: f64) -> (Pending, Receiver<String>) {
        let (rtx, rrx) = channel();
        (Pending { request_id, qa_id: 0, reply: rtx }, rrx)
    }

    fn outcome(node: usize) -> QueryOutcome {
        QueryOutcome {
            qa_id: 0,
            node,
            model_idx: None,
            dropped: node == usize::MAX,
            rel: 0.0,
            scores: QualityScores::zeros(),
            feedback: 0.0,
            latency_s: 1.0,
            cached: false,
        }
    }

    fn report_with(outcomes: Vec<QueryOutcome>) -> SlotReport {
        SlotReport {
            queries: outcomes.len(),
            mean_scores: QualityScores::default(),
            drop_rate: 0.0,
            latency_s: 1.0,
            proportions: vec![],
            node_search_s: vec![],
            size_query_share: [0.0; 3],
            size_mem_share: [0.0; 3],
            outcomes,
            feedback: Default::default(),
            ppo_updates: 0,
            active: vec![true],
            slo_s: 15.0,
            cache: None,
        }
    }

    /// Regression (silent client drop): a batch whose slot produced fewer
    /// outcomes than requests must still answer *every* request. The old
    /// `zip` truncated, dropping the extra reply senders unanswered.
    #[test]
    fn respond_batch_answers_every_request_on_length_mismatch() {
        let (pendings, receivers): (Vec<_>, Vec<_>) =
            (0..3).map(|i| pending(i as f64)).unzip();
        // 3 requests, but the slot only produced 2 outcomes
        let report = report_with(vec![outcome(0), outcome(1)]);
        respond_batch(pendings, Ok(report), 0.1);
        for (i, rrx) in receivers.iter().enumerate() {
            let resp = rrx.try_recv().unwrap_or_else(|_| {
                panic!("request {i} got no response on outcome mismatch")
            });
            let v = Json::parse(&resp).unwrap();
            assert_eq!(v.get("id").unwrap().as_f64().unwrap() as usize, i);
            assert!(
                v.get("error").is_some(),
                "mismatched batch must surface an error: {resp}"
            );
        }
    }

    #[test]
    fn respond_batch_happy_path_zips_in_order() {
        let (pendings, receivers): (Vec<_>, Vec<_>) =
            (0..2).map(|i| pending(i as f64)).unzip();
        let report = report_with(vec![outcome(0), outcome(1)]);
        respond_batch(pendings, Ok(report), 0.1);
        for (i, rrx) in receivers.iter().enumerate() {
            let v = Json::parse(&rrx.try_recv().unwrap()).unwrap();
            assert_eq!(v.get("id").unwrap().as_f64().unwrap() as usize, i);
            assert_eq!(v.get("node").unwrap().as_usize().unwrap(), i);
            assert!(v.get("error").is_none());
        }
    }

    /// Regression (shed-query wire encoding): `node == usize::MAX` means
    /// "never routed" and must serialize as `null`, not as the sentinel
    /// cast to a ~1.8e19 float.
    #[test]
    fn shed_outcome_serializes_node_as_null() {
        let resp = outcome_response(7.0, &outcome(usize::MAX), 0.0);
        let v = Json::parse(&resp).unwrap();
        assert!(
            matches!(v.get("node"), Some(Json::Null)),
            "shed query must put node:null on the wire: {resp}"
        );
        assert_eq!(v.get("dropped").unwrap().as_bool(), Some(true));
        // and a genuinely routed query keeps its numeric node id
        let v = Json::parse(&outcome_response(8.0, &outcome(2), 0.0)).unwrap();
        assert_eq!(v.get("node").unwrap().as_usize(), Some(2));
    }

    /// Backpressure: a full admission queue rejects with a retriable
    /// overload response instead of buffering without bound.
    #[test]
    fn admit_rejects_with_overload_when_queue_full() {
        let (tx, rx) = sync_channel::<Pending>(1);
        let (first, _keep) = pending(1.0);
        assert!(matches!(admit(first, &tx, 1), Admit::Accepted));
        let (second, _keep2) = pending(2.0);
        match admit(second, &tx, 1) {
            Admit::Rejected(resp) => {
                let v = Json::parse(&resp).unwrap();
                assert!(v.get("error").unwrap().as_str().unwrap().contains("overloaded"));
                assert_eq!(v.get("retriable").unwrap().as_bool(), Some(true));
            }
            Admit::Accepted => panic!("full queue must reject"),
        }
        drop(rx);
        let (third, _keep3) = pending(3.0);
        match admit(third, &tx, 1) {
            Admit::Rejected(resp) => assert!(resp.contains("shutting down")),
            Admit::Accepted => panic!("disconnected queue must reject"),
        }
    }

    #[test]
    fn server_roundtrip() {
        let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        cfg.qa_per_domain = 20;
        cfg.docs_per_domain = 40;
        cfg.allocator = AllocatorKind::Oracle;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = 80;
        }
        let co = CoordinatorBuilder::new(cfg).build().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let scfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 10,
            max_batch: 8,
            ..Default::default()
        };

        // bind first to learn the port, then serve on that listener config
        let sd = Arc::clone(&shutdown);
        let (addr_tx, addr_rx) = channel();
        let handle = std::thread::spawn(move || {
            // rebind inside serve; report the actual addr
            let listener_probe = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener_probe.local_addr().unwrap();
            drop(listener_probe);
            addr_tx.send(addr).unwrap();
            let cfg = ServerConfig { addr: addr.to_string(), ..scfg };
            serve(co, cfg, sd).unwrap();
        });
        let addr = addr_rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(100));

        let mut client = Client::connect(&addr.to_string()).unwrap();
        for i in 0..5u64 {
            let resp = client.request(i, i as usize).unwrap();
            assert_eq!(resp.get("id").unwrap().as_f64().unwrap() as u64, i);
            assert!(resp.get("rouge_l").is_some(), "{resp:?}");
        }
        shutdown.store(true, Ordering::Relaxed);
        drop(client);
        handle.join().unwrap();
    }
}
