//! TCP serving front-end: line-delimited JSON protocol + dynamic batcher.
//!
//! The paper serves through vLLM; offline we expose the coordinator over a
//! minimal wire protocol (std::net + the crate's own thread pool — tokio
//! is unavailable in this build environment, DESIGN.md §5).
//!
//! Protocol (one JSON object per line):
//!   → {"id": 7, "qa_id": 123}
//!   ← {"id": 7, "node": 2, "dropped": false, "rouge_l": 0.61,
//!      "latency_s": 3.2, "answer": "…"}
//!
//! Requests are collected by the dynamic batcher until either the batch
//! window elapses or `max_batch` requests are pending, then dispatched as
//! one coordinator slot — the batching policy every modern LLM server
//! (vLLM/Orca) applies at its front door.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::observer::{SlotEvent, SlotObserver};
use crate::coordinator::Coordinator;
use crate::log_info;
use crate::util::json::Json;
use crate::Result;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Dynamic batching window.
    pub batch_window_ms: u64,
    /// Dispatch immediately once this many requests are pending.
    pub max_batch: usize,
    /// When set, record a byte-stable [`RunTranscript`](crate::scenario::RunTranscript)
    /// of every dispatched batch and write it here at shutdown — the same
    /// JSONL format the scenario replay harness asserts on.
    pub transcript_path: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7717".into(),
            batch_window_ms: 20,
            max_batch: 256,
            transcript_path: None,
        }
    }
}

struct Pending {
    request_id: f64,
    qa_id: usize,
    reply: Sender<String>,
}

#[derive(Clone, Copy, Debug, Default)]
struct MetricsInner {
    slots: usize,
    queries: usize,
    dropped: usize,
    updates: usize,
    makespan_s: f64,
    cache_hits: usize,
    cache_misses: usize,
}

/// Live serving metrics, fed by coordinator [`SlotEvent`]s as batches are
/// dispatched (no post-hoc report scraping). One clone lives inside the
/// coordinator; the server keeps another to read totals.
#[derive(Clone, Default)]
pub struct ServerMetrics {
    inner: Arc<std::sync::Mutex<MetricsInner>>,
}

impl ServerMetrics {
    /// (slots, queries, dropped) served so far.
    pub fn totals(&self) -> (usize, usize, usize) {
        let m = self.inner.lock().unwrap();
        (m.slots, m.queries, m.dropped)
    }

    /// (cache hits, cache misses) across both cache levels so far — all
    /// zero when no cache tier is configured.
    pub fn cache_totals(&self) -> (usize, usize) {
        let m = self.inner.lock().unwrap();
        (m.cache_hits, m.cache_misses)
    }

    /// One-line summary for shutdown logging.
    fn summary(&self) -> String {
        let m = self.inner.lock().unwrap();
        let cache = if m.cache_hits + m.cache_misses > 0 {
            format!(
                ", cache hit rate {:.1}%",
                m.cache_hits as f64 / (m.cache_hits + m.cache_misses) as f64 * 100.0
            )
        } else {
            String::new()
        };
        format!(
            "served {} queries in {} batches ({} dropped, {} policy updates, \
             peak makespan {:.2}s{cache})",
            m.queries, m.slots, m.dropped, m.updates, m.makespan_s
        )
    }
}

impl SlotObserver for ServerMetrics {
    fn on_event(&mut self, event: &SlotEvent) {
        match event {
            SlotEvent::Feedback { stats, .. } => {
                self.inner.lock().unwrap().updates += stats.updates;
            }
            SlotEvent::SlotEnd { report, .. } => {
                let mut m = self.inner.lock().unwrap();
                m.slots += 1;
                m.queries += report.queries;
                m.dropped += report.outcomes.iter().filter(|o| o.dropped).count();
                m.makespan_s = m.makespan_s.max(report.latency_s);
                if let Some(c) = &report.cache {
                    m.cache_hits += c.hits();
                    m.cache_misses += c.misses();
                }
                log_info!(
                    "batch {}: {} queries, drop {:.1}%, makespan {:.2}s",
                    m.slots,
                    report.queries,
                    report.drop_rate * 100.0,
                    report.latency_s
                );
            }
            _ => {}
        }
    }
}

/// Run the server until `shutdown` is set. Returns the bound address.
pub fn serve(
    mut coordinator: Coordinator,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let (req_tx, req_rx): (Sender<Pending>, Receiver<Pending>) = channel();

    // live metrics through the coordinator's observer hook (chained after
    // any observers the caller attached)
    let metrics = ServerMetrics::default();
    coordinator.add_observer(Box::new(metrics.clone()));

    // optional replayable transcript of every dispatched batch
    let recorder = cfg.transcript_path.as_ref().map(|_| {
        let rec = crate::scenario::TranscriptRecorder::new(
            "serve",
            coordinator.cfg.seed,
            coordinator.nodes.len(),
            coordinator.allocator().name(),
        );
        coordinator.add_observer(Box::new(rec.clone()));
        rec
    });

    // batcher thread: owns the coordinator
    let batch_shutdown = Arc::clone(&shutdown);
    let window = Duration::from_millis(cfg.batch_window_ms);
    let max_batch = cfg.max_batch;
    let batcher = std::thread::Builder::new()
        .name("coedge-batcher".into())
        .spawn(move || {
            let mut pending: Vec<Pending> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                if batch_shutdown.load(Ordering::Relaxed) && pending.is_empty() {
                    break;
                }
                let timeout = deadline
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::from_millis(50));
                match req_rx.recv_timeout(timeout) {
                    Ok(p) => {
                        if pending.is_empty() {
                            deadline = Some(Instant::now() + window);
                        }
                        pending.push(p);
                        if pending.len() < max_batch
                            && deadline.map(|d| Instant::now() < d).unwrap_or(false)
                        {
                            continue;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if pending.is_empty() {
                            continue;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        if pending.is_empty() {
                            break;
                        }
                    }
                }
                // dispatch the batch as one coordinator slot
                let qa_ids: Vec<usize> = pending.iter().map(|p| p.qa_id).collect();
                let wall = Instant::now();
                match coordinator.run_slot(&qa_ids) {
                    Ok(report) => {
                        let wall_s = wall.elapsed().as_secs_f64();
                        for (p, out) in pending.drain(..).zip(report.outcomes) {
                            let resp = Json::obj(vec![
                                ("id", Json::Num(p.request_id)),
                                ("node", Json::Num(out.node as f64)),
                                ("dropped", Json::Bool(out.dropped)),
                                ("rouge_l", Json::Num(out.scores.rouge_l)),
                                ("bert_score", Json::Num(out.scores.bert_score)),
                                ("sim_latency_s", Json::Num(out.latency_s)),
                                ("wall_s", Json::Num(wall_s)),
                            ]);
                            let _ = p.reply.send(resp.to_string());
                        }
                    }
                    Err(e) => {
                        for p in pending.drain(..) {
                            let resp = Json::obj(vec![
                                ("id", Json::Num(p.request_id)),
                                ("error", Json::Str(format!("{e}"))),
                            ]);
                            let _ = p.reply.send(resp.to_string());
                        }
                    }
                }
                deadline = None;
            }
        })
        .expect("spawn batcher");

    // accept loop (non-blocking poll so shutdown is honored)
    let mut handlers = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = req_tx.clone();
                handlers.push(std::thread::spawn(move || handle_client(stream, tx)));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    drop(req_tx);
    for h in handlers {
        let _ = h.join();
    }
    let _ = batcher.join();
    if let (Some(path), Some(rec)) = (&cfg.transcript_path, &recorder) {
        match rec.snapshot().write_to(path) {
            Ok(()) => log_info!("transcript written to {}", path.display()),
            Err(e) => log_info!("transcript write to {} failed: {e}", path.display()),
        }
    }
    log_info!("{}", metrics.summary());
    Ok(addr)
}

fn handle_client(stream: TcpStream, tx: Sender<Pending>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Ok(v) => {
                let request_id = v.get("id").and_then(|x| x.as_f64()).unwrap_or(-1.0);
                match v.get("qa_id").and_then(|x| x.as_usize()) {
                    Some(qa_id) => {
                        let (rtx, rrx) = channel();
                        if tx.send(Pending { request_id, qa_id, reply: rtx }).is_err() {
                            break;
                        }
                        match rrx.recv() {
                            Ok(resp) => resp,
                            Err(_) => break,
                        }
                    }
                    None => Json::obj(vec![
                        ("id", Json::Num(request_id)),
                        ("error", Json::Str("missing qa_id".into())),
                    ])
                    .to_string(),
                }
            }
            Err(e) => Json::obj(vec![("error", Json::Str(format!("parse: {e}")))]).to_string(),
        };
        if writer.write_all(reply.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and wait for its response.
    pub fn request(&mut self, id: u64, qa_id: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("qa_id", Json::Num(qa_id as f64)),
        ]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("client parse: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AllocatorKind, DatasetKind, ExperimentConfig};
    use crate::coordinator::CoordinatorBuilder;

    #[test]
    fn server_roundtrip() {
        let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        cfg.qa_per_domain = 20;
        cfg.docs_per_domain = 40;
        cfg.allocator = AllocatorKind::Oracle;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = 80;
        }
        let co = CoordinatorBuilder::new(cfg).build().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let scfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            batch_window_ms: 10,
            max_batch: 8,
            ..Default::default()
        };

        // bind first to learn the port, then serve on that listener config
        let sd = Arc::clone(&shutdown);
        let (addr_tx, addr_rx) = channel();
        let handle = std::thread::spawn(move || {
            // rebind inside serve; report the actual addr
            let listener_probe = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener_probe.local_addr().unwrap();
            drop(listener_probe);
            addr_tx.send(addr).unwrap();
            let cfg = ServerConfig { addr: addr.to_string(), ..scfg };
            serve(co, cfg, sd).unwrap();
        });
        let addr = addr_rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(100));

        let mut client = Client::connect(&addr.to_string()).unwrap();
        for i in 0..5u64 {
            let resp = client.request(i, i as usize).unwrap();
            assert_eq!(resp.get("id").unwrap().as_f64().unwrap() as u64, i);
            assert!(resp.get("rouge_l").is_some(), "{resp:?}");
        }
        shutdown.store(true, Ordering::Relaxed);
        drop(client);
        handle.join().unwrap();
    }
}
