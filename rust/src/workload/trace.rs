//! Arrival-trace and query-stream generation.

use crate::corpus::synth::SyntheticDataset;
use crate::util::rng::Rng;
use crate::util::toml::Table;
use crate::Result;

/// Arrival-trace parameters (ECW-like diurnal load with bursts).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub slots: usize,
    /// Mean queries per slot.
    pub base: usize,
    /// Diurnal amplitude as a fraction of base (0 = flat).
    pub diurnal_amp: f64,
    /// Slots per diurnal period.
    pub period: usize,
    /// Per-slot probability of a burst.
    pub burst_prob: f64,
    /// Burst multiplier.
    pub burst_mult: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            slots: 24,
            base: 1000,
            diurnal_amp: 0.4,
            period: 12,
            burst_prob: 0.08,
            burst_mult: 1.8,
            seed: 7,
        }
    }
}

/// Queries per slot.
pub fn arrival_trace(cfg: &TraceConfig) -> Vec<usize> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.slots)
        .map(|t| {
            let phase = std::f64::consts::TAU * t as f64 / cfg.period.max(1) as f64;
            let mut q = cfg.base as f64 * (1.0 + cfg.diurnal_amp * phase.sin());
            q *= 1.0 + 0.08 * rng.normal(); // jitter
            if rng.chance(cfg.burst_prob) {
                q *= cfg.burst_mult;
            }
            q.round().max(1.0) as usize
        })
        .collect()
}

/// Per-slot domain-mix patterns (paper §II-B / §V-B skew setups).
#[derive(Clone, Debug)]
pub enum SkewPattern {
    /// Even across all domains.
    Balanced,
    /// One primary domain takes `frac`, the rest split evenly
    /// (Fig. 5's x-axis: frac ∈ 0.5..0.9; Fig. 2's moderate=0.5/high≈0.67).
    Primary { domain: usize, frac: f64 },
    /// Dirichlet(alpha) resampled per slot (the paper's synthetic bias).
    Dirichlet { alpha: f64 },
}

impl SkewPattern {
    /// Valid kind strings for TOML / scenario parsing.
    pub const KINDS: [&'static str; 3] = ["balanced", "primary", "dirichlet"];

    /// Check the pattern's parameters alone (no dataset needed): `frac`
    /// must be finite and in `[0, 1]` — out-of-range values used to flow
    /// straight into [`domain_mix`] as negative or NaN weights, which
    /// `Rng::sample_weighted` consumes silently (a NaN total always
    /// returns the last index, corrupting the mix with no error).
    /// `alpha` must be finite and > 0 for the same reason.
    pub fn validate_params(&self) -> Result<()> {
        match self {
            SkewPattern::Balanced => {}
            SkewPattern::Primary { frac, .. } => anyhow::ensure!(
                frac.is_finite() && (0.0..=1.0).contains(frac),
                "skew primary frac must be finite and in [0, 1], got {frac}"
            ),
            SkewPattern::Dirichlet { alpha } => anyhow::ensure!(
                alpha.is_finite() && *alpha > 0.0,
                "skew dirichlet alpha must be finite and > 0, got {alpha}"
            ),
        }
        Ok(())
    }

    /// Check the pattern against a dataset's domain count — the error a
    /// typo'd `domain` gets instead of an index panic deep in sampling.
    /// Also enforces [`SkewPattern::validate_params`].
    pub fn validate(&self, nd: usize) -> Result<()> {
        anyhow::ensure!(nd > 0, "domain mix over a dataset with no domains");
        self.validate_params()?;
        if let SkewPattern::Primary { domain, .. } = self {
            anyhow::ensure!(
                *domain < nd,
                "skew primary domain {domain} out of range (dataset has {nd} domains)"
            );
        }
        Ok(())
    }

    /// Read a pattern from a TOML table: the kind string under `kind_key`
    /// (one of [`SkewPattern::KINDS`]), parameters under `domain` / `frac`
    /// (primary) and `alpha` (dirichlet). `Ok(None)` when `kind_key` is
    /// absent, so callers can keep their default.
    pub fn from_table(t: &Table, kind_key: &str) -> Result<Option<SkewPattern>> {
        let Some(kind) = t.get(kind_key).and_then(|v| v.as_str()) else {
            return Ok(None);
        };
        let pattern = match kind {
            "balanced" => SkewPattern::Balanced,
            "primary" => SkewPattern::Primary {
                domain: t.get("domain").and_then(|v| v.as_usize()).unwrap_or(0),
                frac: t.get("frac").and_then(|v| v.as_f64()).unwrap_or(0.6),
            },
            "dirichlet" => SkewPattern::Dirichlet {
                alpha: t.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.6),
            },
            other => anyhow::bail!(
                "unknown skew kind {other:?}; valid kinds: {}",
                SkewPattern::KINDS.join(", ")
            ),
        };
        // reject out-of-range frac / alpha at parse time, where the error
        // can still name the offending table
        pattern.validate_params()?;
        Ok(Some(pattern))
    }
}

/// Realize a mixture over `nd` domains for one slot.
///
/// Degenerate cases are handled explicitly: a single-domain dataset gets
/// the whole mass regardless of the pattern, and an out-of-range primary
/// domain is a clear error rather than an index panic.
pub fn domain_mix(pattern: &SkewPattern, nd: usize, rng: &mut Rng) -> Result<Vec<f64>> {
    pattern.validate(nd)?;
    Ok(match pattern {
        SkewPattern::Balanced => vec![1.0 / nd as f64; nd],
        SkewPattern::Primary { domain, frac } => {
            if nd == 1 {
                // the lone domain takes everything (the nd-1 division
                // below would be 0/0)
                vec![1.0]
            } else {
                let rest = (1.0 - frac) / (nd - 1) as f64;
                let mut w = vec![rest; nd];
                w[*domain] = *frac;
                w
            }
        }
        SkewPattern::Dirichlet { alpha } => rng.dirichlet(&vec![*alpha; nd]),
    })
}

/// Sample `count` QA ids for one slot according to a domain mixture.
///
/// Domains with no QA pairs are dropped from the mixture (their weight
/// redistributed over the populated domains by renormalization) — an
/// empty pool used to reach `pool[Rng::below(0)]`, a release-mode index
/// panic. If every domain with positive weight is empty, this is a clear
/// error rather than a panic or a silently wrong sample.
pub fn sample_slot_queries(
    ds: &SyntheticDataset,
    mix: &[f64],
    count: usize,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let by_domain: Vec<Vec<usize>> = (0..ds.num_domains()).map(|d| ds.qa_of_domain(d)).collect();
    // restrict the mixture to populated domains with positive finite
    // weight; `idx` maps positions in the reduced weight vector back to
    // domain ids (identical sampling stream to the unreduced vector,
    // since `sample_weighted` draws exactly one value either way)
    let idx: Vec<usize> = (0..by_domain.len())
        .filter(|&d| !by_domain[d].is_empty() && mix.get(d).is_some_and(|&w| w > 0.0))
        .collect();
    let weights: Vec<f64> = idx.iter().map(|&d| mix[d]).collect();
    let total: f64 = weights.iter().sum();
    anyhow::ensure!(
        total.is_finite() && total > 0.0,
        "cannot sample queries: every domain with positive weight has no QA pairs \
         (mix {mix:?} over {} domains)",
        by_domain.len()
    );
    Ok((0..count)
        .map(|_| {
            let d = idx[rng.sample_weighted(&weights)];
            let pool = &by_domain[d];
            pool[rng.below(pool.len())]
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{build_dataset, domainqa_spec};

    #[test]
    fn trace_length_and_positivity() {
        let cfg = TraceConfig::default();
        let t = arrival_trace(&cfg);
        assert_eq!(t.len(), cfg.slots);
        assert!(t.iter().all(|&q| q > 0));
    }

    #[test]
    fn trace_diurnal_variation() {
        let cfg = TraceConfig { diurnal_amp: 0.5, burst_prob: 0.0, slots: 24, ..Default::default() };
        let t = arrival_trace(&cfg);
        let max = *t.iter().max().unwrap() as f64;
        let min = *t.iter().min().unwrap() as f64;
        assert!(max / min > 1.5, "max={max} min={min}");
    }

    #[test]
    fn trace_deterministic() {
        let cfg = TraceConfig::default();
        assert_eq!(arrival_trace(&cfg), arrival_trace(&cfg));
    }

    #[test]
    fn primary_mix_shapes() {
        let mut rng = Rng::new(1);
        let w = domain_mix(&SkewPattern::Primary { domain: 2, frac: 0.75 }, 6, &mut rng).unwrap();
        assert!((w[2] - 0.75).abs() < 1e-12);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[0] - 0.05).abs() < 1e-12);
    }

    #[test]
    fn sampled_queries_follow_mix() {
        let ds = build_dataset(&domainqa_spec(50, 20), 3);
        let mut rng = Rng::new(2);
        let mix = domain_mix(&SkewPattern::Primary { domain: 1, frac: 0.8 }, 6, &mut rng).unwrap();
        let qs = sample_slot_queries(&ds, &mix, 2000, &mut rng).unwrap();
        assert_eq!(qs.len(), 2000);
        let d1 = qs.iter().filter(|&&q| ds.qa_pairs[q].domain == 1).count();
        let f = d1 as f64 / 2000.0;
        assert!((f - 0.8).abs() < 0.04, "f={f}");
    }

    #[test]
    fn dirichlet_mix_valid() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let w = domain_mix(&SkewPattern::Dirichlet { alpha: 0.3 }, 6, &mut rng).unwrap();
            assert_eq!(w.len(), 6);
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    /// `Primary` over a single-domain dataset used to divide by `nd - 1 ==
    /// 0`, yielding an inf/NaN mixture; it must collapse to `[1.0]`.
    #[test]
    fn primary_mix_single_domain_is_whole_mass() {
        let mut rng = Rng::new(4);
        for frac in [0.0, 0.5, 1.0] {
            let w = domain_mix(&SkewPattern::Primary { domain: 0, frac }, 1, &mut rng).unwrap();
            assert_eq!(w, vec![1.0], "frac={frac}");
        }
        // the other patterns are well-defined at nd == 1 too
        assert_eq!(domain_mix(&SkewPattern::Balanced, 1, &mut rng).unwrap(), vec![1.0]);
        let d = domain_mix(&SkewPattern::Dirichlet { alpha: 0.3 }, 1, &mut rng).unwrap();
        assert!((d[0] - 1.0).abs() < 1e-9);
    }

    /// An out-of-range primary domain is a clear error, not an index panic.
    #[test]
    fn primary_mix_out_of_range_domain_errors() {
        let mut rng = Rng::new(5);
        let err = domain_mix(&SkewPattern::Primary { domain: 6, frac: 0.7 }, 6, &mut rng)
            .unwrap_err()
            .to_string();
        assert!(err.contains("domain 6") && err.contains("6 domains"), "{err}");
        let err = domain_mix(&SkewPattern::Balanced, 0, &mut rng).unwrap_err().to_string();
        assert!(err.contains("no domains"), "{err}");
    }

    /// Regression: `frac` outside `[0, 1]` (or NaN) used to pass
    /// validation and produce negative/NaN weights in `domain_mix` —
    /// `sample_weighted` then corrupted the mix silently (a NaN total
    /// always picked the last index). Must be a clear error instead.
    #[test]
    fn primary_frac_out_of_range_errors() {
        let mut rng = Rng::new(6);
        for frac in [1.3, -0.2, f64::NAN, f64::INFINITY] {
            let err = domain_mix(&SkewPattern::Primary { domain: 0, frac }, 6, &mut rng)
                .expect_err(&format!("frac={frac} must be rejected"))
                .to_string();
            assert!(err.contains("[0, 1]"), "frac={frac}: {err}");
        }
        // boundary values are explicitly allowed
        for frac in [0.0, 1.0] {
            let w = domain_mix(&SkewPattern::Primary { domain: 2, frac }, 6, &mut rng).unwrap();
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12, "frac={frac}: {w:?}");
            assert!(w.iter().all(|x| x.is_finite() && *x >= 0.0), "frac={frac}: {w:?}");
        }
    }

    /// Regression: non-finite or non-positive dirichlet `alpha` must be
    /// rejected rather than fed to `Rng::gamma` (0 and negatives hang or
    /// NaN inside Marsaglia–Tsang).
    #[test]
    fn dirichlet_alpha_invalid_errors() {
        let mut rng = Rng::new(7);
        for alpha in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = domain_mix(&SkewPattern::Dirichlet { alpha }, 6, &mut rng)
                .expect_err(&format!("alpha={alpha} must be rejected"))
                .to_string();
            assert!(err.contains("> 0"), "alpha={alpha}: {err}");
        }
    }

    /// Regression: out-of-range parameters are rejected at TOML parse
    /// time too, where the error can still name the offending table.
    #[test]
    fn skew_pattern_from_table_rejects_bad_params() {
        use crate::util::toml::TomlDoc;
        let doc = TomlDoc::parse("kind = \"primary\"\ndomain = 1\nfrac = 1.3\n").unwrap();
        let err = SkewPattern::from_table(&doc.root, "kind").unwrap_err().to_string();
        assert!(err.contains("[0, 1]"), "{err}");
        let doc = TomlDoc::parse("kind = \"dirichlet\"\nalpha = -0.5\n").unwrap();
        let err = SkewPattern::from_table(&doc.root, "kind").unwrap_err().to_string();
        assert!(err.contains("> 0"), "{err}");
    }

    /// Regression: a domain with zero QA pairs used to reach
    /// `pool[Rng::below(0)]` — an index panic in release builds (and a
    /// debug assert in tests). Empty domains must be dropped from the
    /// mixture, and an all-empty mixture must be a clear error.
    #[test]
    fn empty_domain_is_excluded_from_sampling() {
        let mut ds = build_dataset(&domainqa_spec(20, 10), 3);
        ds.qa_pairs.retain(|q| q.domain != 1); // empty out domain 1
        assert!(ds.qa_of_domain(1).is_empty());
        let mut rng = Rng::new(8);
        // a mix that puts most of its mass on the empty domain still samples
        let mix = domain_mix(&SkewPattern::Primary { domain: 1, frac: 0.8 }, 6, &mut rng).unwrap();
        let qs = sample_slot_queries(&ds, &mix, 500, &mut rng).unwrap();
        assert_eq!(qs.len(), 500);
        let domain_of: std::collections::HashMap<usize, usize> =
            ds.qa_pairs.iter().map(|q| (q.id, q.domain)).collect();
        assert!(
            qs.iter().all(|q| domain_of[q] != 1),
            "sampled ids must never come from the empty domain"
        );
        // every weighted domain empty -> error, not a panic
        ds.qa_pairs.clear();
        let err = sample_slot_queries(&ds, &mix, 10, &mut rng).unwrap_err().to_string();
        assert!(err.contains("no QA pairs"), "{err}");
    }

    #[test]
    fn skew_pattern_from_table_parses_all_kinds() {
        use crate::util::toml::TomlDoc;
        let doc = TomlDoc::parse("kind = \"primary\"\ndomain = 2\nfrac = 0.7\n").unwrap();
        match SkewPattern::from_table(&doc.root, "kind").unwrap() {
            Some(SkewPattern::Primary { domain: 2, frac }) => assert!((frac - 0.7).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
        let doc = TomlDoc::parse("kind = \"dirichlet\"\nalpha = 0.3\n").unwrap();
        assert!(matches!(
            SkewPattern::from_table(&doc.root, "kind").unwrap(),
            Some(SkewPattern::Dirichlet { .. })
        ));
        let doc = TomlDoc::parse("kind = \"balanced\"\n").unwrap();
        assert!(matches!(
            SkewPattern::from_table(&doc.root, "kind").unwrap(),
            Some(SkewPattern::Balanced)
        ));
        // absent key keeps the caller's default; unknown kinds list the valid ones
        let doc = TomlDoc::parse("x = 1\n").unwrap();
        assert!(SkewPattern::from_table(&doc.root, "kind").unwrap().is_none());
        let doc = TomlDoc::parse("kind = \"zipf\"\n").unwrap();
        let err = SkewPattern::from_table(&doc.root, "kind").unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("dirichlet"), "{err}");
    }
}
