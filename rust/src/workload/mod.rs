//! Workload synthesis: arrival traces and per-slot query streams.
//!
//! Substitutes the ECW-New-App request trace with a diurnal + burst
//! arrival process, and implements the paper's Dirichlet-sampled per-slot
//! domain skew (§V-A "Dynamic query patterns").

pub mod trace;

pub use trace::{arrival_trace, domain_mix, sample_slot_queries, SkewPattern, TraceConfig};
