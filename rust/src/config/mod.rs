//! Experiment / cluster configuration.
//!
//! Typed configuration with paper-testbed presets, loadable from the
//! TOML-subset parser (`configs/*.toml`) so deployments are declarative
//! like vLLM/MaxText config files.

use std::path::PathBuf;

use crate::llmsim::model::ModelSize;
use crate::util::toml::{Table, TomlDoc};
use crate::workload::SkewPattern;
use anyhow::{anyhow, Result};

pub use crate::cache::registry::{CacheKind, CacheSpec};
pub use crate::vecdb::registry::{IndexKind, IndexSpec};

/// Which dataset family an experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// BAAI-style industry corpora with generated QA (paper "DomainQA").
    DomainQa,
    /// Personalized-Proactive-Conversations: shorter persona-flavored texts.
    Ppc,
}

/// Per-node static configuration.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    /// Display name (also used in logs and the TUI node panel).
    pub name: String,
    /// One entry per GPU: relative speed factor.
    pub gpu_speeds: Vec<f64>,
    /// Model size classes available in this node's pool.
    pub pool: Vec<ModelSize>,
    /// Primary domains for the dual-distribution partition.
    pub primary_domains: Vec<usize>,
    /// Documents stored (before overlap scaling).
    pub corpus_docs: usize,
    /// Retrieval index configuration (kind + parameters; default: exact
    /// flat, the paper's setup).
    pub index: IndexSpec,
    /// Retrieval-cache configuration (policy + byte budget; default:
    /// `none` — no caching, the pre-cache behavior).
    pub cache: CacheSpec,
}

/// Intra-node scheduling strategy (Table III rows).
#[derive(Clone, Debug, PartialEq)]
pub enum IntraStrategy {
    /// The paper's solver (Eq. 25–29).
    Solver,
    /// Fixed deployment: per GPU, a list of (size, memory fraction);
    /// queries split evenly among deployed models.
    Fixed(Vec<Vec<(ModelSize, f64)>>),
}

impl IntraStrategy {
    /// Table III baseline: small models only, full memory.
    pub fn small_param(gpus: usize) -> Self {
        IntraStrategy::Fixed(vec![vec![(ModelSize::Small, 1.0)]; gpus])
    }
    /// Mid models only.
    pub fn mid_param(gpus: usize) -> Self {
        IntraStrategy::Fixed(vec![vec![(ModelSize::Mid, 1.0)]; gpus])
    }
    /// Mixed-Param.1: small+mid on every GPU with fixed split.
    pub fn mixed1(gpus: usize) -> Self {
        IntraStrategy::Fixed(vec![
            vec![(ModelSize::Small, 0.35), (ModelSize::Mid, 0.65)];
            gpus
        ])
    }
    /// Mixed-Param.2: GPU0 small+mid; further GPUs large-only.
    pub fn mixed2(gpus: usize) -> Self {
        let mut plans = vec![vec![(ModelSize::Small, 0.35), (ModelSize::Mid, 0.65)]];
        for _ in 1..gpus {
            plans.push(vec![(ModelSize::Large, 1.0)]);
        }
        IntraStrategy::Fixed(plans)
    }
}

/// Query-allocation strategy at the coordinator (Table II rows + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocatorKind {
    /// Uniform-random node choice (Table II lower bound).
    Random,
    /// Route by the query's true domain to the node owning it.
    Domain,
    /// Perfect knowledge of gold-document locations.
    Oracle,
    /// LinUCB contextual bandit.
    Mab,
    /// The paper's PPO online query identification.
    Ppo,
}

impl AllocatorKind {
    /// Every built-in kind (also the coordinator registry's built-in keys).
    pub const ALL: [AllocatorKind; 5] = [
        AllocatorKind::Random,
        AllocatorKind::Domain,
        AllocatorKind::Oracle,
        AllocatorKind::Mab,
        AllocatorKind::Ppo,
    ];

    /// Stable string key (CLI flag values, TOML, registry keys).
    pub fn as_str(&self) -> &'static str {
        match self {
            AllocatorKind::Random => "random",
            AllocatorKind::Domain => "domain",
            AllocatorKind::Oracle => "oracle",
            AllocatorKind::Mab => "mab",
            AllocatorKind::Ppo => "ppo",
        }
    }
}

/// Registry key of the frozen-checkpoint PPO allocator
/// (`--allocator ppo-pretrained --checkpoint FILE`). Deliberately NOT an
/// [`AllocatorKind`] variant: the enum enumerates the paper's Table II
/// comparison rows, while pretrained deployment is a registry-only
/// extension resolved through
/// [`ExperimentConfig::allocator_override`].
pub const PPO_PRETRAINED_KEY: &str = "ppo-pretrained";

impl std::fmt::Display for AllocatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for AllocatorKind {
    type Err = anyhow::Error;

    /// Exhaustive over [`AllocatorKind::ALL`]; the error lists every
    /// valid kind.
    fn from_str(s: &str) -> Result<Self> {
        AllocatorKind::ALL
            .iter()
            .find(|k| k.as_str() == s)
            .copied()
            .ok_or_else(|| {
                let valid: Vec<&str> = AllocatorKind::ALL.iter().map(|k| k.as_str()).collect();
                anyhow!("unknown allocator {s:?}; valid kinds: {}", valid.join(", "))
            })
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Master seed: dataset synthesis, partitioning, workload and policy
    /// RNGs all fork from it deterministically.
    pub seed: u64,
    /// Dataset family to synthesize.
    pub dataset: DatasetKind,
    /// QA pairs generated per domain.
    pub qa_per_domain: usize,
    /// Documents generated per domain.
    pub docs_per_domain: usize,
    /// i.i.d. share s of the dual-distribution partition.
    pub s_iid: f64,
    /// Overlap factor scaling node corpora.
    pub overlap: f64,
    /// Static per-node configuration (one entry per edge node).
    pub nodes: Vec<NodeConfig>,
    /// Latency SLO per slot (seconds).
    pub slo_s: f64,
    /// Queries arriving per scheduling slot.
    pub queries_per_slot: usize,
    /// Number of scheduling slots the experiment runs.
    pub slots: usize,
    /// Per-slot query domain mix.
    pub skew: SkewPattern,
    /// Retrieval depth (paper: top-5).
    pub top_k: usize,
    /// Query-allocation strategy at the coordinator.
    pub allocator: AllocatorKind,
    /// Registry-key allocator override (e.g. [`PPO_PRETRAINED_KEY`]):
    /// when set, the coordinator builder resolves this key through the
    /// allocator registry instead of `allocator` — the extension point
    /// for allocators that are not Table II comparison rows.
    pub allocator_override: Option<String>,
    /// Policy checkpoint the `ppo-pretrained` allocator loads
    /// (`--checkpoint FILE` / TOML `checkpoint = "..."`).
    pub checkpoint: Option<PathBuf>,
    /// Intra-node scheduling strategy (Table III rows).
    pub intra: IntraStrategy,
    /// Cluster-level semantic answer cache (also the default every node's
    /// retrieval cache inherits unless `[nodes.cache]` overrides it).
    pub cache: CacheSpec,
    /// Enable Algorithm-1 capacity-aware reassignment (Fig. 5 ablation).
    pub inter_enabled: bool,
    /// PPO experience-buffer threshold triggering an update.
    pub ppo_buffer: usize,
    /// PPO optimization epochs per update.
    pub ppo_epochs: usize,
}

impl ExperimentConfig {
    /// The paper's testbed: 4 nodes — two with a single GPU, two with dual
    /// GPUs (§V-A), six domains split 3+3 across node groups.
    pub fn paper_cluster(dataset: DatasetKind) -> Self {
        let nodes = vec![
            NodeConfig {
                name: "edge-a".into(),
                gpu_speeds: vec![1.0],
                pool: vec![ModelSize::Small, ModelSize::Mid, ModelSize::Large],
                primary_domains: vec![0, 1, 2],
                corpus_docs: 260,
                index: IndexSpec::default(),
                cache: CacheSpec::default(),
            },
            NodeConfig {
                name: "edge-b".into(),
                gpu_speeds: vec![0.95],
                pool: vec![ModelSize::Small, ModelSize::Mid, ModelSize::Large],
                primary_domains: vec![3, 4, 5],
                corpus_docs: 260,
                index: IndexSpec::default(),
                cache: CacheSpec::default(),
            },
            NodeConfig {
                name: "edge-c".into(),
                gpu_speeds: vec![1.05, 1.0],
                pool: vec![ModelSize::Small, ModelSize::Mid, ModelSize::Large],
                primary_domains: vec![1, 3, 5],
                corpus_docs: 300,
                index: IndexSpec::default(),
                cache: CacheSpec::default(),
            },
            NodeConfig {
                name: "edge-d".into(),
                gpu_speeds: vec![1.0, 0.9],
                pool: vec![ModelSize::Small, ModelSize::Mid, ModelSize::Large],
                primary_domains: vec![0, 2, 4],
                corpus_docs: 300,
                index: IndexSpec::default(),
                cache: CacheSpec::default(),
            },
        ];
        ExperimentConfig {
            seed: 42,
            dataset,
            qa_per_domain: 120,
            docs_per_domain: 150,
            s_iid: 0.2,
            overlap: 0.15,
            nodes,
            slo_s: 15.0,
            queries_per_slot: 1000,
            slots: 12,
            skew: SkewPattern::Dirichlet { alpha: 0.6 },
            top_k: 5,
            allocator: AllocatorKind::Ppo,
            allocator_override: None,
            checkpoint: None,
            intra: IntraStrategy::Solver,
            cache: CacheSpec::default(),
            inter_enabled: true,
            ppo_buffer: 256,
            ppo_epochs: 8,
        }
    }

    /// The §II motivation testbed: 3 single-GPU nodes, one primary domain
    /// each (60/20/20 corpus mix), LLaMA-3B only.
    pub fn motivation_cluster() -> Self {
        let mk = |i: usize, name: &str| NodeConfig {
            name: name.into(),
            gpu_speeds: vec![1.0],
            pool: vec![ModelSize::Mid],
            primary_domains: vec![i],
            corpus_docs: 220,
            index: IndexSpec::default(),
            cache: CacheSpec::default(),
        };
        ExperimentConfig {
            seed: 7,
            dataset: DatasetKind::DomainQa,
            qa_per_domain: 150,
            docs_per_domain: 150,
            s_iid: 0.4, // 60% primary + 40% spread over the other two
            overlap: 0.0,
            nodes: vec![mk(3, "sports"), mk(2, "law"), mk(1, "finance")],
            slo_s: 30.0,
            queries_per_slot: 500,
            slots: 3,
            skew: SkewPattern::Balanced,
            top_k: 5,
            allocator: AllocatorKind::Oracle,
            allocator_override: None,
            checkpoint: None,
            intra: IntraStrategy::Solver,
            cache: CacheSpec::default(),
            inter_enabled: true,
            ppo_buffer: 128,
            ppo_epochs: 6,
        }
    }

    /// Load from a TOML file (see configs/paper.toml for the schema).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("toml: {e}"))?;
        let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        let root = &doc.root;
        if let Some(v) = root.get("seed").and_then(|v| v.as_i64()) {
            cfg.seed = v as u64;
        }
        if let Some(v) = root.get("dataset").and_then(|v| v.as_str()) {
            cfg.dataset = match v {
                "ppc" | "PPC" => DatasetKind::Ppc,
                _ => DatasetKind::DomainQa,
            };
        }
        for (key, field) in [
            ("qa_per_domain", &mut cfg.qa_per_domain as *mut usize),
            ("docs_per_domain", &mut cfg.docs_per_domain as *mut usize),
            ("queries_per_slot", &mut cfg.queries_per_slot as *mut usize),
            ("slots", &mut cfg.slots as *mut usize),
            ("top_k", &mut cfg.top_k as *mut usize),
            ("ppo_buffer", &mut cfg.ppo_buffer as *mut usize),
            ("ppo_epochs", &mut cfg.ppo_epochs as *mut usize),
        ] {
            if let Some(v) = root.get(key).and_then(|v| v.as_usize()) {
                unsafe { *field = v };
            }
        }
        if let Some(v) = root.get("slo_s").and_then(|v| v.as_f64()) {
            cfg.slo_s = v;
        }
        if let Some(v) = root.get("s_iid").and_then(|v| v.as_f64()) {
            cfg.s_iid = v;
        }
        if let Some(v) = root.get("overlap").and_then(|v| v.as_f64()) {
            cfg.overlap = v;
        }
        if let Some(v) = root.get("allocator").and_then(|v| v.as_str()) {
            if v == PPO_PRETRAINED_KEY {
                cfg.allocator_override = Some(PPO_PRETRAINED_KEY.to_string());
            } else {
                cfg.allocator = v.parse()?;
            }
        }
        if let Some(v) = root.get("checkpoint").and_then(|v| v.as_str()) {
            cfg.checkpoint = Some(PathBuf::from(v));
        }
        if let Some(v) = root.get("inter_enabled").and_then(|v| v.as_bool()) {
            cfg.inter_enabled = v;
        }
        // per-slot query domain mix from `[skew]` (kind + domain/frac/alpha)
        if let Some(t) = doc.tables.get("skew") {
            if let Some(p) = SkewPattern::from_table(t, "kind")? {
                cfg.skew = p;
            }
        }
        // cluster-wide index default from `[index]`, overridable per node
        // via `[nodes.index]` (stored as `index.*` keys in the node table)
        let index_default = doc
            .tables
            .get("index")
            .map(|t| index_spec_from(t, "", IndexSpec::default()))
            .unwrap_or_default();
        // cluster-wide cache config from `[cache]`: the coordinator's
        // semantic answer cache AND the default every node's retrieval
        // cache inherits, overridable per node via `[nodes.cache]`
        if let Some(t) = doc.tables.get("cache") {
            cfg.cache = cache_spec_from(t, "", cfg.cache.clone())?;
        }
        let cache_default = cfg.cache.clone();
        if let Some(nodes) = doc.arrays.get("nodes") {
            cfg.nodes = nodes
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let pool = t
                        .get("pool")
                        .and_then(|v| v.as_str_vec())
                        .unwrap_or_else(|| vec!["small".into(), "mid".into(), "large".into()])
                        .iter()
                        .map(|s| match s.as_str() {
                            "small" => ModelSize::Small,
                            "mid" => ModelSize::Mid,
                            _ => ModelSize::Large,
                        })
                        .collect();
                    Ok(NodeConfig {
                        name: t
                            .get("name")
                            .and_then(|v| v.as_str())
                            .map(|s| s.to_string())
                            .unwrap_or_else(|| format!("node-{i}")),
                        gpu_speeds: t
                            .get("gpu_speeds")
                            .and_then(|v| v.as_f64_vec())
                            .unwrap_or_else(|| vec![1.0]),
                        pool,
                        primary_domains: t
                            .get("primary_domains")
                            .and_then(|v| v.as_f64_vec())
                            .map(|v| v.iter().map(|&x| x as usize).collect())
                            .unwrap_or_default(),
                        corpus_docs: t
                            .get("corpus_docs")
                            .and_then(|v| v.as_usize())
                            .unwrap_or(250),
                        index: index_spec_from(t, "index.", index_default.clone()),
                        cache: cache_spec_from(t, "cache.", cache_default.clone())?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        } else {
            for n in cfg.nodes.iter_mut() {
                n.index = index_default.clone();
                n.cache = cache_default.clone();
            }
        }
        Ok(cfg)
    }

    /// Number of configured edge nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Read an [`IndexSpec`] from `prefix`-qualified keys of a table, starting
/// from `base` (keys absent from the table keep the base value).
fn index_spec_from(t: &Table, prefix: &str, base: IndexSpec) -> IndexSpec {
    let mut spec = base;
    let get = |key: &str| t.get(&format!("{prefix}{key}"));
    if let Some(v) = get("kind").and_then(|v| v.as_str()) {
        spec.kind = v.to_string();
    }
    for (key, field) in [
        ("nlist", &mut spec.nlist),
        ("nprobe", &mut spec.nprobe),
        ("shards", &mut spec.shards),
        ("hnsw_m", &mut spec.hnsw_m),
        ("hnsw_ef_construction", &mut spec.hnsw_ef_construction),
        ("hnsw_ef_search", &mut spec.hnsw_ef_search),
        ("rescore_factor", &mut spec.rescore_factor),
    ] {
        if let Some(v) = get(key).and_then(|v| v.as_usize()) {
            *field = v;
        }
    }
    spec
}

/// Read a [`CacheSpec`] from `prefix`-qualified keys of a table, starting
/// from `base` (keys absent from the table keep the base value). Errors on
/// out-of-range thresholds — a typo'd similarity bound should fail at
/// parse time, not silently serve wrong answers.
fn cache_spec_from(t: &Table, prefix: &str, base: CacheSpec) -> Result<CacheSpec> {
    let mut spec = base;
    let get = |key: &str| t.get(&format!("{prefix}{key}"));
    if let Some(v) = get("kind").and_then(|v| v.as_str()) {
        spec.kind = v.to_string();
    }
    for (key, field) in [
        ("capacity_mb", &mut spec.capacity_mb),
        ("node_mem_mb", &mut spec.node_mem_mb),
    ] {
        if let Some(v) = get(key).and_then(|v| v.as_usize()) {
            *field = v;
        }
    }
    if let Some(v) = get("threshold").and_then(|v| v.as_f64()) {
        anyhow::ensure!(
            v.is_finite() && v > 0.0 && v <= 1.0,
            "cache threshold must be in (0, 1], got {v}"
        );
        spec.threshold = v;
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_shape() {
        let cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
        assert_eq!(cfg.nodes.len(), 4);
        let gpus: Vec<usize> = cfg.nodes.iter().map(|n| n.gpu_speeds.len()).collect();
        assert_eq!(gpus, vec![1, 1, 2, 2]);
        // all six domains covered as primaries
        let mut all: Vec<usize> =
            cfg.nodes.iter().flat_map(|n| n.primary_domains.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn motivation_cluster_shape() {
        let cfg = ExperimentConfig::motivation_cluster();
        assert_eq!(cfg.nodes.len(), 3);
        assert!(cfg.nodes.iter().all(|n| n.pool == vec![ModelSize::Mid]));
    }

    #[test]
    fn from_toml_overrides() {
        let text = r#"
seed = 9
dataset = "ppc"
slo_s = 5.0
queries_per_slot = 400
allocator = "mab"
inter_enabled = false

[[nodes]]
name = "n0"
gpu_speeds = [1.0, 1.5]
pool = ["small", "mid"]
primary_domains = [0, 1, 2]
corpus_docs = 100
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.dataset, DatasetKind::Ppc);
        assert_eq!(cfg.slo_s, 5.0);
        assert_eq!(cfg.allocator, AllocatorKind::Mab);
        assert!(!cfg.inter_enabled);
        assert_eq!(cfg.nodes.len(), 1);
        assert_eq!(cfg.nodes[0].gpu_speeds, vec![1.0, 1.5]);
        assert_eq!(cfg.nodes[0].pool, vec![ModelSize::Small, ModelSize::Mid]);
    }

    #[test]
    fn from_toml_index_global_default_and_per_node_override() {
        let text = r#"
[index]
kind = "ivf"
nlist = 48
nprobe = 12

[[nodes]]
name = "n0"

[[nodes]]
name = "n1"

[nodes.index]
kind = "sharded-flat"
shards = 8
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.nodes.len(), 2);
        // n0 inherits the cluster-wide [index] default
        assert_eq!(cfg.nodes[0].index.kind, "ivf");
        assert_eq!(cfg.nodes[0].index.nlist, 48);
        assert_eq!(cfg.nodes[0].index.nprobe, 12);
        // n1 overrides kind + shards but inherits the rest
        assert_eq!(cfg.nodes[1].index.kind, "sharded-flat");
        assert_eq!(cfg.nodes[1].index.shards, 8);
        assert_eq!(cfg.nodes[1].index.nlist, 48);
    }

    #[test]
    fn from_toml_quantized_index_rescore_factor() {
        let text = r#"
[index]
kind = "quantized-flat"
rescore_factor = 8

[[nodes]]
name = "n0"

[[nodes]]
name = "n1"

[nodes.index]
kind = "sharded-quantized"
rescore_factor = 1
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.nodes[0].index.kind, "quantized-flat");
        assert_eq!(cfg.nodes[0].index.rescore_factor, 8);
        assert_eq!(cfg.nodes[1].index.kind, "sharded-quantized");
        assert_eq!(cfg.nodes[1].index.rescore_factor, 1);
        // absent key keeps the default
        let d = ExperimentConfig::from_toml("[index]\nkind = \"quantized-flat\"\n").unwrap();
        assert!(d.nodes.iter().all(|n| n.index.rescore_factor == 4));
    }

    #[test]
    fn from_toml_skew_table() {
        let text = "[skew]\nkind = \"primary\"\ndomain = 3\nfrac = 0.75\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        match cfg.skew {
            SkewPattern::Primary { domain: 3, frac } => assert!((frac - 0.75).abs() < 1e-12),
            ref other => panic!("{other:?}"),
        }
        // bad kinds error with the valid list; absent [skew] keeps the preset
        assert!(ExperimentConfig::from_toml("[skew]\nkind = \"nope\"\n").is_err());
        let cfg = ExperimentConfig::from_toml("seed = 1\n").unwrap();
        assert!(matches!(cfg.skew, SkewPattern::Dirichlet { .. }));
    }

    #[test]
    fn from_toml_cache_global_default_and_per_node_override() {
        let text = r#"
[cache]
kind = "lru"
capacity_mb = 16
threshold = 0.95
node_mem_mb = 4096

[[nodes]]
name = "n0"

[[nodes]]
name = "n1"

[nodes.cache]
kind = "lfu"
capacity_mb = 8
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        // the cluster-level answer cache takes the [cache] table
        assert_eq!(cfg.cache.kind, "lru");
        assert_eq!(cfg.cache.capacity_mb, 16);
        assert!((cfg.cache.threshold - 0.95).abs() < 1e-12);
        assert_eq!(cfg.cache.node_mem_mb, 4096);
        // n0 inherits the global default; n1 overrides kind + budget only
        assert_eq!(cfg.nodes[0].cache.kind, "lru");
        assert_eq!(cfg.nodes[0].cache.capacity_mb, 16);
        assert_eq!(cfg.nodes[1].cache.kind, "lfu");
        assert_eq!(cfg.nodes[1].cache.capacity_mb, 8);
        assert_eq!(cfg.nodes[1].cache.node_mem_mb, 4096);
    }

    #[test]
    fn from_toml_cache_defaults_to_none_and_rejects_bad_threshold() {
        let cfg = ExperimentConfig::from_toml("seed = 1\n").unwrap();
        assert_eq!(cfg.cache, CacheSpec::default());
        assert!(!cfg.cache.enabled());
        assert!(cfg.nodes.iter().all(|n| !n.cache.enabled()));
        // a global [cache] also applies when no [[nodes]] are declared
        let cfg = ExperimentConfig::from_toml("[cache]\nkind = \"lru\"\n").unwrap();
        assert!(cfg.nodes.iter().all(|n| n.cache.kind == "lru"));
        let err = ExperimentConfig::from_toml("[cache]\nthreshold = 1.5\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("threshold"), "{err}");
        assert!(ExperimentConfig::from_toml("[cache]\nthreshold = 0.0\n").is_err());
    }

    #[test]
    fn cache_kind_roundtrips_and_errors_list_valid() {
        for k in CacheKind::ALL {
            assert_eq!(k.as_str().parse::<CacheKind>().unwrap(), k);
        }
        let err = "memcached".parse::<CacheKind>().unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("lfu"), "{err}");
    }

    #[test]
    fn from_toml_index_defaults_to_flat() {
        let cfg = ExperimentConfig::from_toml("seed = 1\n").unwrap();
        assert!(cfg.nodes.iter().all(|n| n.index == IndexSpec::default()));
        assert_eq!(cfg.nodes[0].index.kind, "flat");
        // a global [index] also applies when no [[nodes]] are declared
        let cfg = ExperimentConfig::from_toml("[index]\nkind = \"hnsw\"\nhnsw_m = 24\n").unwrap();
        assert!(cfg.nodes.iter().all(|n| n.index.kind == "hnsw" && n.index.hnsw_m == 24));
    }

    #[test]
    fn allocator_kind_roundtrips_and_errors_list_valid() {
        for k in AllocatorKind::ALL {
            assert_eq!(k.as_str().parse::<AllocatorKind>().unwrap(), k);
        }
        let err = "bogus".parse::<AllocatorKind>().unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("ppo"), "{err}");
        assert!(ExperimentConfig::from_toml("allocator = \"bogus\"").is_err());
    }

    #[test]
    fn from_toml_ppo_pretrained_sets_override_and_checkpoint() {
        let text = "allocator = \"ppo-pretrained\"\ncheckpoint = \"models/policy.ckpt\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        // the enum kind is untouched; the registry-key override carries it
        assert_eq!(cfg.allocator, AllocatorKind::Ppo);
        assert_eq!(cfg.allocator_override.as_deref(), Some(PPO_PRETRAINED_KEY));
        assert_eq!(cfg.checkpoint.as_deref(), Some(std::path::Path::new("models/policy.ckpt")));
        // defaults: no override, no checkpoint
        let cfg = ExperimentConfig::from_toml("seed = 1\n").unwrap();
        assert!(cfg.allocator_override.is_none() && cfg.checkpoint.is_none());
    }

    #[test]
    fn fixed_strategies_shapes() {
        match IntraStrategy::mixed2(2) {
            IntraStrategy::Fixed(plans) => {
                assert_eq!(plans.len(), 2);
                assert_eq!(plans[0].len(), 2);
                assert_eq!(plans[1][0].0, ModelSize::Large);
            }
            _ => panic!(),
        }
        match IntraStrategy::small_param(1) {
            IntraStrategy::Fixed(plans) => assert_eq!(plans[0][0].0, ModelSize::Small),
            _ => panic!(),
        }
    }
}
