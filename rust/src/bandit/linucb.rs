//! LinUCB with disjoint linear models (one ridge regression per arm).
//!
//! Context: a low-dimensional projection of the query embedding (the MAB
//! baseline "fails to model high-dimensional query features" — we give it
//! the standard treatment: a fixed random projection to CTX_DIM).
//! Arm score: θ_aᵀx + α·√(xᵀA_a⁻¹x); A_a updated by rank-1, solved per
//! query via Gaussian elimination (CTX_DIM is small).

use crate::text::embed::EMBED_DIM;
use crate::util::rng::Rng;
use crate::util::stats::solve_linear;

/// Bandit context dimensionality.
pub const CTX_DIM: usize = 24;

/// LinUCB allocator.
#[derive(Clone, Debug)]
pub struct LinUcb {
    pub n_arms: usize,
    pub alpha: f64,
    /// Random projection EMBED_DIM -> CTX_DIM (row-major).
    proj: Vec<f32>,
    /// Per arm: A (d×d) and b (d).
    a: Vec<Vec<f64>>,
    b: Vec<Vec<f64>>,
}

impl LinUcb {
    pub fn new(n_arms: usize, alpha: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let proj: Vec<f32> = (0..CTX_DIM * EMBED_DIM)
            .map(|_| (rng.normal() / (CTX_DIM as f64).sqrt()) as f32)
            .collect();
        // A initialized to identity (ridge)
        let mut a = Vec::with_capacity(n_arms);
        for _ in 0..n_arms {
            let mut m = vec![0.0; CTX_DIM * CTX_DIM];
            for i in 0..CTX_DIM {
                m[i * CTX_DIM + i] = 1.0;
            }
            a.push(m);
        }
        LinUcb { n_arms, alpha, proj, a, b: vec![vec![0.0; CTX_DIM]; n_arms] }
    }

    /// Project an embedding into bandit context space.
    pub fn context(&self, emb: &[f32]) -> Vec<f64> {
        assert_eq!(emb.len(), EMBED_DIM);
        (0..CTX_DIM)
            .map(|i| {
                let row = &self.proj[i * EMBED_DIM..(i + 1) * EMBED_DIM];
                row.iter().zip(emb).map(|(&p, &e)| (p * e) as f64).sum()
            })
            .collect()
    }

    fn solve(&self, arm: usize, rhs: &[f64]) -> Vec<f64> {
        let d = CTX_DIM;
        let mut m: Vec<Vec<f64>> = (0..d)
            .map(|i| self.a[arm][i * d..(i + 1) * d].to_vec())
            .collect();
        let mut r = rhs.to_vec();
        solve_linear(&mut m, &mut r).expect("A is PD")
    }

    /// UCB scores for all arms.
    pub fn scores(&self, ctx: &[f64]) -> Vec<f64> {
        (0..self.n_arms)
            .map(|arm| {
                let theta = self.solve(arm, &self.b[arm]);
                let mean: f64 = theta.iter().zip(ctx).map(|(t, x)| t * x).sum();
                let ainv_x = self.solve(arm, ctx);
                let var: f64 = ainv_x.iter().zip(ctx).map(|(v, x)| v * x).sum();
                mean + self.alpha * var.max(0.0).sqrt()
            })
            .collect()
    }

    /// Pick the argmax-UCB arm for an embedding.
    pub fn choose(&self, emb: &[f32]) -> usize {
        let ctx = self.context(emb);
        let scores = self.scores(&ctx);
        scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Observe reward for (embedding, arm).
    pub fn update(&mut self, emb: &[f32], arm: usize, reward: f64) {
        let ctx = self.context(emb);
        let d = CTX_DIM;
        for i in 0..d {
            self.b[arm][i] += reward * ctx[i];
            for j in 0..d {
                self.a[arm][i * d + j] += ctx[i] * ctx[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;

    fn cluster_emb(rng: &mut Rng, c: usize, n: usize) -> Vec<f32> {
        let span = EMBED_DIM / n;
        let mut x = vec![0f32; EMBED_DIM];
        for i in 0..span {
            x[c * span + i] = 1.0 + 0.1 * rng.normal() as f32;
        }
        l2_normalize(&mut x);
        x
    }

    #[test]
    fn learns_linear_cluster_mapping() {
        let n = 3;
        let mut ucb = LinUcb::new(n, 0.5, 7);
        let mut rng = Rng::new(8);
        let mut correct = 0;
        let mut total = 0;
        for step in 0..1500 {
            let c = rng.below(n);
            let x = cluster_emb(&mut rng, c, n);
            let a = ucb.choose(&x);
            let r = if a == c { 1.0 } else { -1.0 };
            ucb.update(&x, a, r);
            if step >= 1200 {
                total += 1;
                if a == c {
                    correct += 1;
                }
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.7, "acc={acc}");
    }

    #[test]
    fn exploration_bonus_decreases_with_data() {
        let mut ucb = LinUcb::new(2, 1.0, 3);
        let mut rng = Rng::new(4);
        let x = cluster_emb(&mut rng, 0, 2);
        let ctx = ucb.context(&x);
        let s_before = ucb.scores(&ctx)[0];
        for _ in 0..50 {
            ucb.update(&x, 0, 0.0); // zero reward, arm 0
        }
        let s_after = ucb.scores(&ctx)[0];
        // mean stays 0, bonus shrinks
        assert!(s_after < s_before, "{s_after} vs {s_before}");
    }

    #[test]
    fn context_deterministic_per_seed() {
        let u1 = LinUcb::new(2, 0.5, 11);
        let u2 = LinUcb::new(2, 0.5, 11);
        let mut rng = Rng::new(1);
        let x = cluster_emb(&mut rng, 1, 2);
        assert_eq!(u1.context(&x), u2.context(&x));
    }
}
