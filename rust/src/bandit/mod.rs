//! Contextual-bandit baseline for query allocation.
//!
//! The paper's "MAB-based Allocation" baseline uses LinUCB (Li et al.,
//! 2010) over historical performance + uncertainty, without neural feature
//! extraction — implemented here from scratch.

pub mod linucb;

pub use linucb::LinUcb;
