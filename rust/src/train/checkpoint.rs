//! Versioned binary persistence for policy parameters + Adam state.
//!
//! The format is a fixed little-endian layout: an 8-byte magic, a version
//! word, a header pinning the network dimensions (`EMBED_DIM`, `HIDDEN`,
//! `n_actions`) and the training provenance (dataset key + domain count),
//! the Adam timestep, and an FNV-1a checksum of the tensor payload;
//! then the 10 parameter tensors followed by both Adam moment groups as
//! length-prefixed `f32` arrays. Every quantity is written with
//! `to_le_bytes`, so `save → load → save` round-trips **bitwise** — the
//! property CI's `train-smoke` step byte-diffs — and every load failure
//! names the offending file and field instead of producing garbage
//! inference from a mismatched network.

use std::path::Path;

use crate::policy::params::{param_shapes, PolicyParams, EMBED_DIM, HIDDEN, NUM_TENSORS};
use crate::Result;

/// File magic: the first 8 bytes of every checkpoint.
pub const MAGIC: [u8; 8] = *b"COEDGPPO";
/// Format version this build writes and reads.
pub const VERSION: u32 = 1;

/// Training provenance stored in the checkpoint header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointMeta {
    /// Dataset key the policy was trained on (`domainqa` / `ppc`).
    pub dataset: String,
    /// Number of query domains in that dataset — deploying onto a
    /// cluster with a different domain count is a clear error.
    pub num_domains: usize,
}

/// A fully parsed checkpoint: parameters + provenance.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Policy parameters + Adam state, exactly as saved.
    pub params: PolicyParams,
    /// Training provenance from the header.
    pub meta: CheckpointMeta,
}

/// FNV-1a 64-bit hash (dependency-free payload checksum).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Serialize parameters + provenance to the versioned binary format.
pub fn to_bytes(params: &PolicyParams, meta: &CheckpointMeta) -> Vec<u8> {
    let mut payload = Vec::new();
    for group in [&params.tensors, &params.adam_m, &params.adam_v] {
        for t in group.iter() {
            payload.extend_from_slice(&(t.len() as u32).to_le_bytes());
            for &v in t {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    let ds = meta.dataset.as_bytes();
    let mut out = Vec::with_capacity(64 + ds.len() + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(EMBED_DIM as u32).to_le_bytes());
    for h in HIDDEN {
        out.extend_from_slice(&(h as u32).to_le_bytes());
    }
    out.extend_from_slice(&(params.n_actions as u32).to_le_bytes());
    out.extend_from_slice(&(meta.num_domains as u32).to_le_bytes());
    out.extend_from_slice(&(ds.len() as u32).to_le_bytes());
    out.extend_from_slice(ds);
    out.extend_from_slice(&params.step.to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Cursor over the raw bytes; every read names the field it was after,
/// so truncation errors say exactly what is missing.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    src: &'a str,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "checkpoint {}: truncated while reading {what} (need {n} bytes at offset {}, \
             file has {})",
            self.src,
            self.pos,
            self.buf.len()
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

/// Read one tensor group (`NUM_TENSORS` length-prefixed f32 arrays),
/// validating each array's length against the expected shape.
fn read_group(
    r: &mut Reader,
    group: &str,
    shapes: &[(usize, usize); NUM_TENSORS],
) -> Result<Vec<Vec<f32>>> {
    const NAMES: [&str; NUM_TENSORS] =
        ["w1", "b1", "ln_g", "ln_b", "w2", "b2", "w3", "b3", "w4", "b4"];
    let mut out = Vec::with_capacity(NUM_TENSORS);
    for (name, &(rows, cols)) in NAMES.iter().zip(shapes.iter()) {
        let what = format!("{group}.{name}");
        let len = r.u32(&what)? as usize;
        anyhow::ensure!(
            len == rows * cols,
            "checkpoint {}: field {what} has {len} values, expected {rows}×{cols} for the \
             stored n_actions",
            r.src
        );
        let raw = r.take(len * 4, &what)?;
        out.push(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
    }
    Ok(out)
}

/// Parse a checkpoint from raw bytes. `source` names the origin (usually
/// a file path) in every error message.
pub fn from_bytes(bytes: &[u8], source: &str) -> Result<Checkpoint> {
    let mut r = Reader { buf: bytes, pos: 0, src: source };
    let magic = r.take(MAGIC.len(), "magic")?;
    anyhow::ensure!(
        magic == MAGIC,
        "checkpoint {source}: bad magic — not a CoEdge policy checkpoint"
    );
    let version = r.u32("version")?;
    anyhow::ensure!(
        version == VERSION,
        "checkpoint {source}: unsupported version {version} (this build reads version \
         {VERSION})"
    );
    let embed = r.u32("embed_dim")? as usize;
    anyhow::ensure!(
        embed == EMBED_DIM,
        "checkpoint {source}: embed_dim {embed} does not match this build's {EMBED_DIM}"
    );
    let mut hidden = [0usize; 3];
    for h in hidden.iter_mut() {
        *h = r.u32("hidden")? as usize;
    }
    anyhow::ensure!(
        hidden == HIDDEN,
        "checkpoint {source}: hidden dims {hidden:?} do not match this build's {HIDDEN:?}"
    );
    let n_actions = r.u32("n_actions")? as usize;
    anyhow::ensure!(
        (1..=65_536).contains(&n_actions),
        "checkpoint {source}: n_actions {n_actions} out of range"
    );
    let num_domains = r.u32("num_domains")? as usize;
    let ds_len = r.u32("dataset")? as usize;
    anyhow::ensure!(
        ds_len <= 256,
        "checkpoint {source}: dataset key length {ds_len} out of range"
    );
    let dataset = std::str::from_utf8(r.take(ds_len, "dataset")?)
        .map_err(|_| anyhow::anyhow!("checkpoint {source}: dataset key is not valid UTF-8"))?
        .to_string();
    let step = r.u64("step")?;
    let stored = r.u64("checksum")?;
    let computed = fnv1a64(&bytes[r.pos..]);
    anyhow::ensure!(
        stored == computed,
        "checkpoint {source}: checksum mismatch (stored {stored:016x}, computed \
         {computed:016x}) — file is corrupt"
    );
    let shapes = param_shapes(n_actions);
    let tensors = read_group(&mut r, "tensors", &shapes)?;
    let adam_m = read_group(&mut r, "adam_m", &shapes)?;
    let adam_v = read_group(&mut r, "adam_v", &shapes)?;
    anyhow::ensure!(
        r.pos == bytes.len(),
        "checkpoint {source}: {} trailing bytes after the parameter payload",
        bytes.len() - r.pos
    );
    Ok(Checkpoint {
        params: PolicyParams { n_actions, tensors, adam_m, adam_v, step },
        meta: CheckpointMeta { dataset, num_domains },
    })
}

/// Write a checkpoint file (parent directories are created).
pub fn save(path: &Path, params: &PolicyParams, meta: &CheckpointMeta) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| {
                anyhow::anyhow!("checkpoint {}: create parent directory: {e}", path.display())
            })?;
        }
    }
    std::fs::write(path, to_bytes(params, meta))
        .map_err(|e| anyhow::anyhow!("checkpoint {}: write failed: {e}", path.display()))
}

/// Read and parse a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("checkpoint {}: read failed: {e}", path.display()))?;
    from_bytes(&bytes, &path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_params() -> PolicyParams {
        let mut p = PolicyParams::init(4, 9);
        p.step = 17;
        p.adam_m[0][0] = 0.25;
        p.adam_v[3][1] = -1.5;
        p
    }

    fn demo_meta() -> CheckpointMeta {
        CheckpointMeta { dataset: "domainqa".into(), num_domains: 6 }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let bytes = to_bytes(&demo_params(), &demo_meta());
        let ck = from_bytes(&bytes, "<memory>").unwrap();
        assert_eq!(ck.params.n_actions, 4);
        assert_eq!(ck.params.step, 17);
        assert_eq!(ck.meta, demo_meta());
        assert_eq!(to_bytes(&ck.params, &ck.meta), bytes, "save → load → save must be byte-equal");
    }

    #[test]
    fn truncated_bytes_name_the_missing_field() {
        let bytes = to_bytes(&demo_params(), &demo_meta());
        let err = from_bytes(&bytes[..bytes.len() - 7], "demo.ckpt").unwrap_err().to_string();
        assert!(err.contains("demo.ckpt") && err.contains("truncated"), "{err}");
        let err = from_bytes(&bytes[..6], "demo.ckpt").unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn corrupt_payload_fails_the_checksum() {
        let mut bytes = to_bytes(&demo_params(), &demo_meta());
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let err = from_bytes(&bytes, "demo.ckpt").unwrap_err().to_string();
        assert!(err.contains("checksum") && err.contains("demo.ckpt"), "{err}");
    }

    #[test]
    fn wrong_magic_and_version_are_descriptive() {
        let mut bytes = to_bytes(&demo_params(), &demo_meta());
        bytes[0] = b'X';
        let err = from_bytes(&bytes, "demo.ckpt").unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        let mut bytes = to_bytes(&demo_params(), &demo_meta());
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = from_bytes(&bytes, "demo.ckpt").unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn file_save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("coedge-ckpt-{}", std::process::id()));
        let path = dir.join("p.ckpt");
        save(&path, &demo_params(), &demo_meta()).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params.tensors, demo_params().tensors);
        let err = load(&dir.join("missing.ckpt")).unwrap_err().to_string();
        assert!(err.contains("missing.ckpt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
