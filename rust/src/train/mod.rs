//! Vectorized PPO training farm + policy persistence (`coedge train`).
//!
//! The paper trains its query-identification policy *online*, inside the
//! serving loop (§IV-A) — which couples learning progress to a single
//! trajectory. This tier decouples them: a [`TrainFarm`] runs N seeded
//! [`ScenarioRunner`](crate::scenario::ScenarioRunner) replicas in
//! parallel on the crate thread pool — one replica per (scenario fixture
//! × seed) cell, a curriculum over every committed fixture in
//! `scenarios/` — collects each replica's `(state, action, reward)`
//! [`Transition`](crate::policy::Transition)s through a shared rollout
//! sink, and steps ONE shared PPO learner on the merged batches.
//!
//! **Determinism contract (ADR-001).** Each epoch snapshots the learner
//! parameters; every replica routes with that frozen snapshot (on-policy
//! rollouts), so replicas are independent and their transition lists can
//! be collected in cell-index order via
//! [`parallel_map`](crate::util::threadpool::parallel_map). The learner
//! then consumes the merged list in that order — the thread count can
//! never change a byte of the learning curve, the checkpoint, or
//! `BENCH_train.json`. CI double-runs `coedge train` at `--threads 4`
//! vs `--threads 1` and byte-diffs both artifacts.
//!
//! The other half of the tier is persistence: [`checkpoint`] defines a
//! versioned binary format (dimension-pinning header + checksum) and
//! [`PretrainedPpoAllocator`] deploys a saved policy through the existing
//! allocator registry (`--allocator ppo-pretrained --checkpoint FILE`)
//! as a permanently frozen allocator — the coordinator skips its feedback
//! phase entirely, so replays are byte-identical across runs.

pub mod checkpoint;
mod pretrained;
mod rollout;

use std::sync::Arc;

use crate::bench_harness::BenchCase;
use crate::config::{DatasetKind, ExperimentConfig};
use crate::coordinator::CoordinatorBuilder;
use crate::experiments::{aggregate, dataset_key, eval_capacities, CellMetrics, EvalProfile};
use crate::policy::ppo::{Backend, PpoConfig};
use crate::policy::{OnlinePolicy, PolicyParams, Transition};
use crate::scenario::{NamedScenario, ScenarioRunner};
use crate::util::threadpool::parallel_map;
use crate::Result;

pub use checkpoint::{Checkpoint, CheckpointMeta};
pub use pretrained::PretrainedPpoAllocator;

use rollout::{RolloutAllocator, TransitionSink};

/// Farm configuration (`coedge train` flags map 1:1 onto these fields).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Seeded replicas per scenario fixture (the farm runs
    /// `fixtures × replicas` cells per epoch).
    pub replicas: usize,
    /// Training epochs: one epoch = rollouts from the current snapshot
    /// across every cell, then learner updates on the merged transitions.
    pub epochs: usize,
    /// Base seed; every cell and the learner derive their streams from it.
    pub seed: u64,
    /// Worker threads for the rollout fan-out (`0` ⇒ one per core).
    /// Never affects output bytes (ADR-001).
    pub threads: usize,
    /// Learner minibatch size: merged transitions are chunked into
    /// batches of this many rows, each stepped independently.
    pub minibatch: usize,
    /// PPO optimization epochs per minibatch (batch reuse).
    pub ppo_epochs: usize,
    /// Exploration floor for rollout action sampling.
    pub explore_eps: f64,
    /// Workload scale each replica's cluster runs at.
    pub profile: EvalProfile,
    /// Dataset the curriculum trains on (pinned into the checkpoint).
    pub dataset: DatasetKind,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            replicas: 2,
            epochs: 3,
            seed: 42,
            threads: 0,
            minibatch: 128,
            ppo_epochs: 4,
            explore_eps: 0.05,
            profile: EvalProfile::smoke(),
            dataset: DatasetKind::DomainQa,
        }
    }
}

/// Learning-curve sample for one epoch.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Transitions collected across all cells this epoch.
    pub transitions: usize,
    /// Learner update rounds run on those transitions.
    pub updates: usize,
    /// Mean raw feedback (Eq. 9 composite) across the epoch's
    /// transitions — the reward curve.
    pub mean_reward: f64,
    /// Query-weighted mean ROUGE-L across the epoch's cells.
    pub rouge_l: f64,
    /// Query-weighted drop rate across the epoch's cells.
    pub drop_rate: f64,
    /// Loss from the epoch's final PPO step.
    pub loss: f32,
    /// Policy entropy from the epoch's final PPO step.
    pub entropy: f32,
}

/// Everything one farm run produced: the learning curve, the trained
/// parameters, and the provenance metadata a checkpoint pins.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Fixture names of the curriculum, in cell order.
    pub scenarios: Vec<String>,
    /// Replicas per fixture.
    pub replicas: usize,
    /// Base seed the run derived from.
    pub seed: u64,
    /// Per-epoch learning-curve samples.
    pub curve: Vec<EpochStats>,
    /// The trained policy parameters (+ Adam state).
    pub params: PolicyParams,
    /// Provenance the checkpoint header pins (dataset, domain count).
    pub meta: CheckpointMeta,
}

impl TrainReport {
    /// The learning curve as [`BenchCase`]s for
    /// [`write_bench_json`](crate::bench_harness::write_bench_json)
    /// (`BENCH_train.json`): one case per epoch.
    pub fn to_bench_cases(&self) -> Vec<BenchCase> {
        self.curve
            .iter()
            .map(|e| {
                BenchCase::new(format!("epoch/{:03}", e.epoch))
                    .field("transitions", e.transitions as f64)
                    .field("updates", e.updates as f64)
                    .field("mean_reward", e.mean_reward)
                    .field("rouge_l", e.rouge_l)
                    .field("drop_rate", e.drop_rate)
                    .field("loss", e.loss as f64)
                    .field("entropy", e.entropy as f64)
            })
            .collect()
    }

    /// Save the trained parameters as a versioned checkpoint file.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        checkpoint::save(path, &self.params, &self.meta)
    }
}

/// One replica's harvest, collected in cell-index order.
struct ReplicaRun {
    transitions: Vec<Transition>,
    metrics: CellMetrics,
    num_domains: usize,
}

/// The vectorized rollout farm: a curriculum of scenario fixtures, N
/// seeded replicas each, one shared PPO learner.
pub struct TrainFarm {
    cfg: TrainConfig,
    fixtures: Vec<NamedScenario>,
}

impl TrainFarm {
    /// A farm over an explicit curriculum (custom fixture lists; the CLI
    /// uses [`TrainFarm::from_dir`]). Errors on an empty curriculum or a
    /// zero replica/epoch budget.
    pub fn new(cfg: TrainConfig, fixtures: Vec<NamedScenario>) -> Result<Self> {
        anyhow::ensure!(!fixtures.is_empty(), "training curriculum is empty — no scenario fixtures");
        anyhow::ensure!(cfg.replicas >= 1, "--replicas must be at least 1");
        anyhow::ensure!(cfg.epochs >= 1, "--epochs must be at least 1");
        Ok(TrainFarm { cfg, fixtures })
    }

    /// A farm over every `*.toml` fixture in `dir` (filename-sorted, the
    /// same resolution `coedge eval` uses — see
    /// [`crate::scenario::fixtures`]).
    pub fn from_dir(dir: &std::path::Path, cfg: TrainConfig) -> Result<Self> {
        let fixtures = crate::scenario::load_fixtures(dir)?;
        Self::new(cfg, fixtures)
    }

    /// Rollout cells per epoch (`fixtures × replicas`).
    pub fn num_cells(&self) -> usize {
        self.fixtures.len() * self.cfg.replicas
    }

    /// The cluster configuration cell `i` rolls out on: the paper cluster
    /// at the farm's workload scale, seeded per-cell so replicas of the
    /// same fixture see distinct workloads.
    fn cell_cfg(&self, cell: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_cluster(self.cfg.dataset);
        cfg.seed = self.cfg.seed ^ ((cell as u64 + 1).wrapping_mul(0x9E37_79B9));
        cfg.qa_per_domain = self.cfg.profile.qa_per_domain;
        cfg.docs_per_domain = self.cfg.profile.docs_per_domain;
        cfg.queries_per_slot = self.cfg.profile.queries_per_slot;
        for n in cfg.nodes.iter_mut() {
            n.corpus_docs = self.cfg.profile.corpus_docs;
        }
        cfg
    }

    /// Run one rollout cell with the epoch's parameter snapshot: build a
    /// fresh seeded coordinator around a [`RolloutAllocator`], replay the
    /// cell's fixture, harvest the sink.
    fn run_replica(&self, cell: usize, snapshot: &PolicyParams) -> Result<ReplicaRun> {
        let fixture = &self.fixtures[cell / self.cfg.replicas];
        let cfg = self.cell_cfg(cell);
        let caps = eval_capacities(&cfg);
        let sink: TransitionSink = Arc::default();
        let pcfg = PpoConfig {
            explore_eps: self.cfg.explore_eps,
            seed: cfg.seed ^ 0x9090,
            ..Default::default()
        };
        let alloc =
            RolloutAllocator::new(snapshot.clone(), pcfg, cfg.seed ^ 0x707E, Arc::clone(&sink));
        let mut co =
            CoordinatorBuilder::new(cfg).capacities(caps).allocator(Box::new(alloc)).build()?;
        let num_domains = co.ds.num_domains();
        let run = ScenarioRunner::new(fixture.scenario.clone()).run(&mut co)?;
        drop(co);
        let transitions = std::mem::take(&mut *sink.lock().unwrap());
        Ok(ReplicaRun { transitions, metrics: aggregate(&run.reports), num_domains })
    }

    /// Train: per epoch, snapshot the learner, fan the cells out on the
    /// thread pool, merge transitions in cell-index order, and step the
    /// shared learner per minibatch chunk. Byte-deterministic for a given
    /// [`TrainConfig`] regardless of `threads`.
    pub fn run(&self) -> Result<TrainReport> {
        let n_nodes = ExperimentConfig::paper_cluster(self.cfg.dataset).num_nodes();
        let lcfg = PpoConfig {
            epochs: self.cfg.ppo_epochs,
            seed: self.cfg.seed ^ 0x1EA2,
            ..Default::default()
        };
        let mut learner = OnlinePolicy::new(n_nodes, lcfg, Backend::Reference);
        let threads = if self.cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.cfg.threads
        };
        let cells = self.num_cells();
        let mut curve = Vec::with_capacity(self.cfg.epochs);
        let mut num_domains = 0usize;
        for epoch in 0..self.cfg.epochs {
            // on-policy: every cell rolls out with this epoch's snapshot
            let snapshot = learner.params.clone();
            let runs: Vec<Result<ReplicaRun>> =
                parallel_map(cells, threads, |i| self.run_replica(i, &snapshot));
            let runs = runs.into_iter().collect::<Result<Vec<_>>>()?;
            num_domains = runs.first().map(|r| r.num_domains).unwrap_or(0);
            let total_q: usize = runs.iter().map(|r| r.metrics.queries).sum();
            let wmean = |f: &dyn Fn(&CellMetrics) -> f64| {
                if total_q == 0 {
                    0.0
                } else {
                    runs.iter().map(|r| f(&r.metrics) * r.metrics.queries as f64).sum::<f64>()
                        / total_q as f64
                }
            };
            let rouge_l = wmean(&|m: &CellMetrics| m.rouge_l);
            let drop_rate = wmean(&|m: &CellMetrics| m.drop_rate);
            // merge in cell-index order — the determinism anchor
            let merged: Vec<Transition> =
                runs.into_iter().flat_map(|r| r.transitions).collect();
            let updates_before = learner.updates;
            for chunk in merged.chunks(self.cfg.minibatch.max(2)) {
                learner.update_on(chunk)?;
            }
            let mean_reward = if merged.is_empty() {
                0.0
            } else {
                merged.iter().map(|t| t.feedback).sum::<f64>() / merged.len() as f64
            };
            let (loss, entropy) =
                learner.last_stats.map(|s| (s.loss, s.entropy)).unwrap_or((0.0, 0.0));
            curve.push(EpochStats {
                epoch,
                transitions: merged.len(),
                updates: learner.updates - updates_before,
                mean_reward,
                rouge_l,
                drop_rate,
                loss,
                entropy,
            });
        }
        Ok(TrainReport {
            scenarios: self.fixtures.iter().map(|f| f.name.clone()).collect(),
            replicas: self.cfg.replicas,
            seed: self.cfg.seed,
            curve,
            params: learner.params.clone(),
            meta: CheckpointMeta {
                dataset: dataset_key(self.cfg.dataset).to_string(),
                num_domains,
            },
        })
    }
}
