//! The per-replica rollout actor: a frozen-parameter PPO allocator whose
//! `observe` phase writes `(state, action, reward)` transitions into a
//! shared sink instead of mutating any learner state.
//!
//! Each farm replica owns one of these, built from the epoch's parameter
//! snapshot: routing behavior is exactly [`PpoAllocator`]'s (masked
//! matching probabilities feeding Algorithm-1 inter-node scheduling), but
//! learning is centralized — the farm merges every replica's sink in
//! cell-index order and steps the single shared learner, which is what
//! keeps training byte-deterministic under any thread count (ADR-001).

use std::sync::{Arc, Mutex};

use crate::cluster::node::QueryOutcome;
use crate::coordinator::allocator::{
    Allocator, Assignment, FeedbackStats, PpoAllocator, SlotContext,
};
use crate::policy::ppo::{Backend, PpoConfig, Transition};
use crate::policy::PolicyParams;
use crate::Result;

/// Shared transition buffer one replica appends to.
pub(crate) type TransitionSink = Arc<Mutex<Vec<Transition>>>;

/// A PPO allocator routing with snapshot parameters and exporting
/// transitions instead of learning from them.
pub(crate) struct RolloutAllocator {
    inner: PpoAllocator,
    sink: TransitionSink,
}

impl RolloutAllocator {
    /// Wrap an epoch snapshot for one replica. `pcfg.seed` drives the
    /// replica's action-sampling stream and `route_seed` its Algorithm-1
    /// routing noise, so replicas explore distinct trajectories.
    pub(crate) fn new(
        snapshot: PolicyParams,
        pcfg: PpoConfig,
        route_seed: u64,
        sink: TransitionSink,
    ) -> Self {
        let n = snapshot.n_actions;
        let mut inner = PpoAllocator::new(n, pcfg, Backend::Reference, route_seed);
        inner.policy.params = snapshot;
        RolloutAllocator { inner, sink }
    }
}

impl Allocator for RolloutAllocator {
    fn name(&self) -> &str {
        "ppo-rollout"
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        self.inner.assign(ctx)
    }

    fn observe(
        &mut self,
        ctx: &SlotContext,
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        if assignment.logps.len() != outcomes.len() {
            return Ok(FeedbackStats::default());
        }
        let mut sink = self.sink.lock().unwrap();
        for (i, out) in outcomes.iter().enumerate() {
            sink.push(Transition {
                x: ctx.embs[i].clone(),
                action: assignment.node_of[i],
                old_logp: assignment.logps[i],
                feedback: out.feedback,
            });
        }
        Ok(FeedbackStats { observed: outcomes.len(), updates: 0 })
    }
}
