//! The `ppo-pretrained` allocator: frozen checkpoint weights deployed
//! through the existing [`AllocatorRegistry`] — train once offline, then
//! replay the policy with zero exploration and zero learning, so two runs
//! over the same fixture are byte-identical.
//!
//! [`AllocatorRegistry`]: crate::coordinator::AllocatorRegistry

use std::path::Path;

use crate::cluster::node::QueryOutcome;
use crate::config::PPO_PRETRAINED_KEY;
use crate::coordinator::allocator::{
    Allocator, Assignment, FeedbackStats, PpoAllocator, SlotContext,
};
use crate::policy::ppo::{Backend, PpoConfig};
use crate::policy::{OnlinePolicy, PolicyParams};
use crate::train::checkpoint;
use crate::Result;

/// A frozen PPO allocator serving checkpoint weights.
///
/// Routing is [`PpoAllocator`]'s (masked matching probabilities through
/// Algorithm-1 scheduling) with the exploration floor pinned to 0;
/// `observe` never touches the parameters and [`Allocator::is_frozen`]
/// reports `true`, so the coordinator skips the feedback phase entirely.
pub struct PretrainedPpoAllocator {
    inner: PpoAllocator,
}

impl PretrainedPpoAllocator {
    /// Wrap already-loaded parameters (`route_seed` drives the
    /// Algorithm-1 routing-noise stream).
    pub fn from_params(params: PolicyParams, route_seed: u64) -> Self {
        let n = params.n_actions;
        let pcfg = PpoConfig { explore_eps: 0.0, ..Default::default() };
        let mut inner = PpoAllocator::new(n, pcfg, Backend::Reference, route_seed);
        inner.policy.params = params;
        inner.freeze();
        PretrainedPpoAllocator { inner }
    }

    /// Load a checkpoint and validate it against the deployment target:
    /// the stored `n_actions` must equal the cluster's node count and the
    /// stored `num_domains` the dataset's domain count — a mismatched
    /// checkpoint is a clear error naming the file and field, never
    /// garbage inference.
    pub fn load(
        path: &Path,
        expected_nodes: usize,
        expected_domains: usize,
        route_seed: u64,
    ) -> Result<Self> {
        let ck = checkpoint::load(path)?;
        anyhow::ensure!(
            ck.params.n_actions == expected_nodes,
            "checkpoint {}: field n_actions = {} does not match the cluster's {} nodes",
            path.display(),
            ck.params.n_actions,
            expected_nodes
        );
        anyhow::ensure!(
            ck.meta.num_domains == expected_domains,
            "checkpoint {}: field num_domains = {} does not match the dataset's {} domains \
             (trained on {:?})",
            path.display(),
            ck.meta.num_domains,
            expected_domains,
            ck.meta.dataset
        );
        Ok(Self::from_params(ck.params, route_seed))
    }

    /// The frozen policy (diagnostics; e.g. `params.step` provenance).
    pub fn policy(&self) -> &OnlinePolicy {
        &self.inner.policy
    }
}

impl Allocator for PretrainedPpoAllocator {
    fn name(&self) -> &str {
        PPO_PRETRAINED_KEY
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        self.inner.assign(ctx)
    }

    fn observe(
        &mut self,
        _ctx: &SlotContext,
        _assignment: &Assignment,
        _outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        // defensive: the coordinator already skips observe for frozen
        // allocators, but a direct caller must not mutate anything either
        Ok(FeedbackStats::default())
    }

    fn freeze(&mut self) {
        // already permanently frozen
    }

    fn is_frozen(&self) -> bool {
        true
    }
}
