//! Staged construction of a [`Coordinator`].
//!
//! The pipeline runs dataset → partition → nodes → capacity → allocator;
//! every stage can be overridden independently (injected datasets,
//! precomputed partitions, stub capacity models, mock allocators), which
//! is how the test suites isolate single stages.

use std::sync::Arc;

use crate::cache::{CacheBuildCtx, CacheRegistry, QueryCache};
use crate::cluster::node::EdgeNode;
use crate::config::{DatasetKind, ExperimentConfig};
use crate::coordinator::allocator::{Allocator, AllocatorBuildCtx, AllocatorRegistry};
use crate::coordinator::observer::SlotObserver;
use crate::coordinator::Coordinator;
use crate::corpus::partition::{gold_locations, partition_corpus, NodeCorpusSpec};
use crate::corpus::synth::SyntheticDataset;
use crate::corpus::{build_dataset, domainqa_spec, ppc_spec};
use crate::metrics::Evaluator;
use crate::policy::ppo::Backend;
use crate::router::capacity::{profile_capacity, CapacityModel};
use crate::text::embed::Embedder;
use crate::util::rng::Rng;
use crate::vecdb::{IndexBuildCtx, IndexRegistry, VectorIndex};
use crate::Result;

/// Builder for the full CoEdge-RAG system.
///
/// Registering a custom allocator requires no coordinator changes:
///
/// ```
/// use coedge_rag::config::{DatasetKind, ExperimentConfig};
/// use coedge_rag::coordinator::allocator::{Allocator, Assignment, SlotContext};
/// use coedge_rag::coordinator::CoordinatorBuilder;
/// use coedge_rag::router::capacity::CapacityModel;
///
/// struct FirstNode;
/// impl Allocator for FirstNode {
///     fn name(&self) -> &str { "first-node" }
///     fn assign(&mut self, ctx: &SlotContext) -> coedge_rag::Result<Assignment> {
///         Ok(Assignment::all_to(ctx.batch(), 0))
///     }
/// }
///
/// let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
/// cfg.qa_per_domain = 10;
/// cfg.docs_per_domain = 15;
/// for n in cfg.nodes.iter_mut() { n.corpus_docs = 20; }
/// let mut co = CoordinatorBuilder::new(cfg)
///     .register_allocator("first-node", |_| Ok(Box::new(FirstNode)))
///     .allocator_kind("first-node")
///     .capacities(vec![CapacityModel { k: 50.0, b: 0.0 }; 4]) // skip profiling
///     .build()
///     .unwrap();
/// let qids = co.sample_queries(6).unwrap();
/// let report = co.run_slot(&qids).unwrap();
/// assert!(report.outcomes.iter().all(|o| o.node == 0));
/// ```
pub struct CoordinatorBuilder {
    cfg: ExperimentConfig,
    backend: Backend,
    registry: AllocatorRegistry,
    index_registry: IndexRegistry,
    cache_registry: CacheRegistry,
    dataset: Option<SyntheticDataset>,
    partitions: Option<Vec<Vec<usize>>>,
    capacities: Option<Vec<CapacityModel>>,
    allocator: Option<Box<dyn Allocator>>,
    allocator_kind: Option<String>,
    observers: Vec<Box<dyn SlotObserver>>,
    embedder: Embedder,
    evaluator: Evaluator,
}

impl CoordinatorBuilder {
    /// Start a build pipeline from an experiment configuration.
    pub fn new(cfg: ExperimentConfig) -> Self {
        CoordinatorBuilder {
            cfg,
            backend: Backend::Reference,
            registry: AllocatorRegistry::with_builtins(),
            index_registry: IndexRegistry::with_builtins(),
            cache_registry: CacheRegistry::with_builtins(),
            dataset: None,
            partitions: None,
            capacities: None,
            allocator: None,
            allocator_kind: None,
            observers: Vec::new(),
            embedder: Embedder::default(),
            evaluator: Evaluator::default(),
        }
    }

    /// Policy-network execution backend (default: pure-Rust reference).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Inject a dataset instead of synthesizing one from the config.
    pub fn dataset(mut self, ds: SyntheticDataset) -> Self {
        self.dataset = Some(ds);
        self
    }

    /// Inject per-node document partitions (one doc-id list per node)
    /// instead of running the dual-distribution partitioner.
    pub fn partitions(mut self, parts: Vec<Vec<usize>>) -> Self {
        self.partitions = Some(parts);
        self
    }

    /// Inject per-node capacity models, skipping the profiling phase
    /// (§IV-B) — the big time-saver for unit tests.
    pub fn capacities(mut self, caps: Vec<CapacityModel>) -> Self {
        self.capacities = Some(caps);
        self
    }

    /// Inject a ready-made allocator (takes precedence over
    /// [`allocator_kind`](Self::allocator_kind) and the config's kind).
    pub fn allocator(mut self, allocator: Box<dyn Allocator>) -> Self {
        self.allocator = Some(allocator);
        self
    }

    /// Select the allocator by registry key (built-ins use the
    /// `AllocatorKind` names; customs whatever was registered).
    pub fn allocator_kind(mut self, kind: &str) -> Self {
        self.allocator_kind = Some(kind.to_string());
        self
    }

    /// Register a custom allocator factory under `kind`.
    pub fn register_allocator(
        mut self,
        kind: &str,
        factory: impl Fn(&AllocatorBuildCtx) -> Result<Box<dyn Allocator>> + Send + Sync + 'static,
    ) -> Self {
        self.registry.register(kind, factory);
        self
    }

    /// Register a custom vector-index factory under `kind`; node configs
    /// (TOML `[nodes.index]` / CLI `--index`) can then select it by name,
    /// exactly like custom allocators.
    pub fn register_index(
        mut self,
        kind: &str,
        factory: impl Fn(&IndexBuildCtx) -> Result<Box<dyn VectorIndex>> + Send + Sync + 'static,
    ) -> Self {
        self.index_registry.register(kind, factory);
        self
    }

    /// Register a custom query-cache factory under `kind`; the global
    /// `[cache]` table, per-node `[nodes.cache]` sub-tables and the
    /// `--cache` flag can then select it by name, exactly like custom
    /// allocators and indexes.
    pub fn register_cache(
        mut self,
        kind: &str,
        factory: impl Fn(&CacheBuildCtx) -> Result<Box<dyn QueryCache>> + Send + Sync + 'static,
    ) -> Self {
        self.cache_registry.register(kind, factory);
        self
    }

    /// Attach a [`SlotObserver`] receiving per-phase events (may be called
    /// repeatedly; all observers receive every event).
    pub fn observer(mut self, observer: Box<dyn SlotObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Replace the embedder (tests inject deterministic stubs).
    pub fn embedder(mut self, embedder: Embedder) -> Self {
        self.embedder = embedder;
        self
    }

    /// Replace the evaluator.
    pub fn evaluator(mut self, evaluator: Evaluator) -> Self {
        self.evaluator = evaluator;
        self
    }

    /// Run the pipeline: dataset → partition → nodes → capacity →
    /// allocator.
    pub fn build(self) -> Result<Coordinator> {
        let CoordinatorBuilder {
            cfg,
            backend,
            registry,
            index_registry,
            cache_registry,
            dataset,
            partitions,
            capacities,
            allocator,
            allocator_kind,
            observers,
            embedder,
            evaluator,
        } = self;

        // stage 1: dataset
        let ds = match dataset {
            Some(ds) => ds,
            None => {
                let spec = match cfg.dataset {
                    DatasetKind::DomainQa => domainqa_spec(cfg.qa_per_domain, cfg.docs_per_domain),
                    DatasetKind::Ppc => ppc_spec(cfg.qa_per_domain, cfg.docs_per_domain),
                };
                build_dataset(&spec, cfg.seed)
            }
        };
        let nd = ds.num_domains();

        // stage 2: partition (dual-distribution, paper §V-A)
        let parts = match partitions {
            Some(p) => {
                anyhow::ensure!(
                    p.len() == cfg.nodes.len(),
                    "partitions: got {} lists for {} nodes",
                    p.len(),
                    cfg.nodes.len()
                );
                p
            }
            None => {
                let specs: Vec<NodeCorpusSpec> = cfg
                    .nodes
                    .iter()
                    .map(|n| NodeCorpusSpec::dual(n.corpus_docs, nd, &n.primary_domains, cfg.s_iid))
                    .collect();
                partition_corpus(&ds, &specs, cfg.overlap, cfg.seed ^ 0x9A87)
            }
        };
        let gold_locs = gold_locations(&ds, &parts);

        // stage 3: nodes (embed all documents once, shared cache)
        let doc_embs: Arc<Vec<Vec<f32>>> =
            Arc::new(ds.documents.iter().map(|d| embedder.embed(&d.text())).collect());
        let nodes: Vec<EdgeNode> = cfg
            .nodes
            .iter()
            .enumerate()
            .map(|(i, ncfg)| {
                EdgeNode::build(
                    i,
                    ncfg,
                    &ds,
                    parts[i].clone(),
                    Arc::clone(&doc_embs),
                    &evaluator,
                    cfg.intra.clone(),
                    cfg.top_k,
                    cfg.seed ^ 0x0D0E ^ i as u64,
                    &index_registry,
                    &cache_registry,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        // stage 4: capacity profiling (initialization phase, §IV-B)
        let capacities: Vec<CapacityModel> = match capacities {
            Some(c) => {
                anyhow::ensure!(
                    c.len() == nodes.len(),
                    "capacities: got {} models for {} nodes",
                    c.len(),
                    nodes.len()
                );
                c
            }
            None => nodes
                .iter()
                .map(|n| profile_capacity(|q, l| n.dry_run_drop_rate(q, l), 0.01))
                .collect(),
        };

        // stage 5: allocator
        let allocator = match allocator {
            Some(a) => a,
            None => {
                let build_ctx = AllocatorBuildCtx {
                    cfg: &cfg,
                    ds: &ds,
                    gold_locs: &gold_locs,
                    backend: &backend,
                    seed: cfg.seed,
                };
                // precedence: explicit builder key > config registry-key
                // override (e.g. `ppo-pretrained`) > the Table II enum
                let kind = allocator_kind
                    .or_else(|| cfg.allocator_override.clone())
                    .unwrap_or_else(|| cfg.allocator.as_str().to_string());
                registry.build(&kind, &build_ctx)?
            }
        };

        // stage 6: the cache tier — cluster answer cache from the global
        // `[cache]` spec; `cache_enabled` is false only when NOTHING is
        // cached anywhere (the default), which pins byte-identical
        // pre-cache behavior in the golden-trace harness
        let answer_cache =
            cache_registry.build(&cfg.cache.kind, &CacheBuildCtx { spec: &cfg.cache })?;
        let answer_cache_active = cfg.cache.enabled();
        let cache_enabled =
            answer_cache_active || cfg.nodes.iter().any(|n| n.cache.enabled());

        let n_nodes = nodes.len();
        Ok(Coordinator {
            rng: Rng::new(cfg.seed ^ 0xC00D),
            cfg,
            ds,
            nodes,
            capacities,
            embedder,
            evaluator,
            gold_locs,
            allocator,
            observers,
            slot_idx: 0,
            active: vec![true; n_nodes],
            cap_scale: vec![1.0; n_nodes],
            answer_cache,
            answer_cache_active,
            cache_enabled,
            pending_invalidations: 0,
            index_registry: Arc::new(index_registry),
            reindex_seen: false,
            migration_swap_skew: 0,
        })
    }
}
