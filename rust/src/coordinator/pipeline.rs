//! Pipelined slot execution: overlap `encode` of slot `t+1` with
//! `route`/`serve`/`feedback` of slot `t`.
//!
//! [`Coordinator::run_slot`] decomposes into the paper's four phases, and
//! the first of them is pure: encoding a slot touches only the
//! deterministic, stateless [`Embedder`] and the query texts, never the
//! coordinator's mutable state. That is the seam this module exploits
//! (EdgeShard-style pipelined collaborative edge inference): a prefetch
//! thread encodes upcoming slots through a bounded handoff channel while
//! the caller's thread drives routing, serving, and feedback in slot
//! order via [`Coordinator::run_slot_encoded`].
//!
//! Because only wall-clock overlap changes — the rng stream, allocator
//! state, observer event sequence, and every report field are produced by
//! the exact same code in the exact same order — the pipelined executor
//! is byte-identical to the synchronous loop. `tests/scenarios.rs` pins
//! this by replaying every committed golden fixture through
//! [`PipelinedExecutor`] at several encode-thread counts (ADR-001).

use std::sync::mpsc::sync_channel;

use crate::coordinator::{Coordinator, SlotReport};
use crate::text::embed::Embedder;
use crate::util::threadpool::parallel_map;
use crate::util::timer::Timer;
use crate::Result;

/// Tuning knobs for the pipelined executor. Neither knob can change a
/// single output byte — they trade memory (prefetch depth) and CPU
/// (encode threads) against wall-clock only.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// How many encoded slots the prefetch thread may run ahead of the
    /// executor (the bound of the handoff channel; clamped to ≥ 1).
    pub depth: usize,
    /// Threads used to embed one slot's queries (1 = serial on the
    /// prefetch thread). Any value produces identical embeddings —
    /// [`parallel_map`] collects results in index order.
    pub encode_threads: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { depth: 2, encode_threads: 1 }
    }
}

/// Embed one slot's queries outside the coordinator: `queries[qa_ids[i]]`
/// through a clone of the stack's deterministic embedder. Produces
/// exactly what [`Coordinator::encode`] would, for any thread count.
pub fn encode_batch(
    embedder: &Embedder,
    queries: &[String],
    qa_ids: &[usize],
    encode_threads: usize,
) -> Vec<Vec<f32>> {
    if encode_threads <= 1 {
        qa_ids.iter().map(|&q| embedder.embed(&queries[q])).collect()
    } else {
        parallel_map(qa_ids.len(), encode_threads, |i| embedder.embed(&queries[qa_ids[i]]))
    }
}

/// Modeled per-query encode cost in seconds, used wherever a
/// deterministic (machine-independent) encode time is needed — the
/// serving bench derives its committed pipeline-occupancy figures from it
/// per ADR-001. The constant approximates the hash embedder's measured
/// per-query cost order of magnitude; its exact value only scales the
/// occupancy curve, it never enters transcripts.
pub const MODELED_ENCODE_S_PER_QUERY: f64 = 2.0e-5;

/// Modeled pipeline occupancy for a run of slots: the fraction of the
/// pipelined makespan during which the serve stage is busy, with encode
/// of slot `t+1` hidden behind serve of slot `t`.
///
/// With per-slot encode cost `E_t = queries[t] ×`
/// [`MODELED_ENCODE_S_PER_QUERY`] and serve cost `S_t = serve_s[t]`, the
/// pipelined makespan is `E_0 + Σ_t max(S_t, E_{t+1})` (the last slot
/// prefetches nothing) and occupancy is `Σ_t S_t` over that makespan.
/// `1.0` means every encode is perfectly hidden; lower values mean the
/// serve stage stalls waiting on encodes. Purely modeled — deterministic
/// across machines and thread counts.
pub fn modeled_pipeline_occupancy(queries: &[usize], serve_s: &[f64]) -> f64 {
    assert_eq!(queries.len(), serve_s.len(), "one serve time per slot");
    if queries.is_empty() {
        return 0.0;
    }
    let encode: Vec<f64> =
        queries.iter().map(|&q| q as f64 * MODELED_ENCODE_S_PER_QUERY).collect();
    let mut makespan = encode[0];
    for (t, &s) in serve_s.iter().enumerate() {
        let next_encode = if t + 1 < encode.len() { encode[t + 1] } else { 0.0 };
        makespan += s.max(next_encode);
    }
    let busy: f64 = serve_s.iter().sum();
    if makespan <= 0.0 { 0.0 } else { busy / makespan }
}

/// Drives a pre-sampled sequence of slots through
/// [`Coordinator::run_slot_encoded`] with encode prefetching.
///
/// The caller supplies every slot's QA ids up front (sampling consumes
/// the coordinator's rng, so it must happen in slot order *before* the
/// prefetch thread starts — see
/// [`ScenarioRunner::run_pipelined`](crate::scenario::ScenarioRunner::run_pipelined)
/// for how the scenario engine hoists sampling without disturbing the rng
/// stream). Reports come back in slot order and are bitwise identical to
/// calling [`Coordinator::run_slot`] in a loop.
pub struct PipelinedExecutor {
    cfg: PipelineConfig,
}

impl PipelinedExecutor {
    /// Executor with the given pipeline tuning.
    pub fn new(cfg: PipelineConfig) -> Self {
        PipelinedExecutor { cfg }
    }

    /// Run every slot in order, prefetching encodes up to
    /// `cfg.depth` slots ahead.
    pub fn run(&self, co: &mut Coordinator, slots: &[Vec<usize>]) -> Result<Vec<SlotReport>> {
        self.run_with(co, slots, |_, _| Ok(()), |_, _| {})
    }

    /// [`run`](Self::run) with per-slot hooks: `before_slot(co, t)` fires
    /// before slot `t` executes (the scenario runner applies timeline
    /// events here) and `after_slot(t, report)` right after (transcript
    /// recording). Hooks run on the caller's thread, in slot order,
    /// exactly where the synchronous loop would run the same code.
    pub fn run_with(
        &self,
        co: &mut Coordinator,
        slots: &[Vec<usize>],
        mut before_slot: impl FnMut(&mut Coordinator, usize) -> Result<()>,
        mut after_slot: impl FnMut(usize, &SlotReport),
    ) -> Result<Vec<SlotReport>> {
        let depth = self.cfg.depth.max(1);
        let encode_threads = self.cfg.encode_threads.max(1);
        // the prefetch thread needs the embedder and query texts without
        // borrowing the coordinator the executor is mutating
        let embedder = co.embedder.clone();
        let queries: Vec<String> = co.ds.qa_pairs.iter().map(|p| p.query.clone()).collect();
        let mut reports = Vec::with_capacity(slots.len());
        std::thread::scope(|scope| -> Result<()> {
            let (tx, rx) = sync_channel::<(usize, Vec<Vec<f32>>, f64)>(depth);
            let embedder = &embedder;
            let queries = &queries;
            scope.spawn(move || {
                for (t, qa_ids) in slots.iter().enumerate() {
                    let timer = Timer::start();
                    let embs = encode_batch(embedder, queries, qa_ids, encode_threads);
                    if tx.send((t, embs, timer.secs())).is_err() {
                        break; // executor bailed early; stop prefetching
                    }
                }
            });
            for (t, qa_ids) in slots.iter().enumerate() {
                before_slot(co, t)?;
                let (enc_t, embs, enc_s) = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("encode prefetch thread died"))?;
                debug_assert_eq!(enc_t, t, "prefetch out of order");
                let report = co.run_slot_encoded(qa_ids, embs, enc_s)?;
                after_slot(t, &report);
                reports.push(report);
            }
            Ok(())
            // on error the receiver drops here; the prefetch thread's
            // next send fails and it exits, so the scope joins cleanly
        })?;
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_batch_matches_serial_for_any_thread_count() {
        let embedder = Embedder::default();
        let queries: Vec<String> =
            (0..17).map(|i| format!("how does node {i} route")).collect();
        let qa_ids: Vec<usize> = vec![3, 0, 16, 7, 7, 12, 1];
        let serial = encode_batch(&embedder, &queries, &qa_ids, 1);
        for threads in [2, 4, 8] {
            let parallel = encode_batch(&embedder, &queries, &qa_ids, threads);
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn occupancy_is_one_when_encodes_hide_fully() {
        // serve dominates every prefetched encode; only E_0 is exposed
        let queries = vec![100, 100, 100];
        let serve = vec![1.0, 1.0, 1.0];
        let occ = modeled_pipeline_occupancy(&queries, &serve);
        let e0 = 100.0 * MODELED_ENCODE_S_PER_QUERY;
        let expected = 3.0 / (e0 + 3.0);
        assert!((occ - expected).abs() < 1e-12, "{occ} vs {expected}");
    }

    #[test]
    fn occupancy_drops_when_encode_dominates() {
        // serve is negligible next to encode: the pipe is encode-bound
        let queries = vec![1_000_000, 1_000_000];
        let serve = vec![1e-9, 1e-9];
        let occ = modeled_pipeline_occupancy(&queries, &serve);
        assert!(occ < 0.01, "encode-bound occupancy should collapse: {occ}");
    }

    #[test]
    fn occupancy_of_empty_run_is_zero() {
        assert_eq!(modeled_pipeline_occupancy(&[], &[]), 0.0);
    }
}
