//! The global coordinator (paper Fig. 4) and its public scheduling API.
//!
//! Per slot the coordinator runs four phases, each a method you can call
//! individually or together through [`Coordinator::run_slot`]:
//!
//! 1. [`encode`](Coordinator::encode) — embed the slot's queries;
//! 2. [`route`](Coordinator::route) — the pluggable [`Allocator`] maps
//!    queries to nodes (PPO identification + Algorithm-1 inter-node
//!    scheduling, or any baseline/custom policy);
//! 3. [`serve`](Coordinator::serve) — nodes retrieve and generate in
//!    parallel under their intra-node plans;
//! 4. [`feedback`](Coordinator::feedback) — outcomes flow back into the
//!    allocator (PPO updates, bandit rewards, …).
//!
//! After each phase a structured [`SlotEvent`](observer::SlotEvent) is
//! emitted to the optional [`SlotObserver`](observer::SlotObserver) —
//! live metrics without scraping [`SlotReport`]s.
//!
//! The encode phase is pure (`&self`, a deterministic stateless
//! embedder), which opens a pipelining seam:
//! [`run_slot_encoded`](Coordinator::run_slot_encoded) accepts
//! pre-computed embeddings so a prefetch thread can encode slot `t+1`
//! while slot `t` routes and serves — see [`pipeline`] for the executor
//! that exploits it without changing a single output byte.
//!
//! Construction goes through [`CoordinatorBuilder`], whose stages
//! (dataset → partition → nodes → capacity → allocator) are individually
//! overridable. Routing policies implement the [`Allocator`] trait
//! ([`allocator`]) and plug in through a string-keyed registry; the
//! built-in baselines live in [`baselines`].

pub mod allocator;
pub mod baselines;
mod builder;
pub mod observer;
pub mod pipeline;

pub use allocator::{Allocator, AllocatorRegistry, Assignment, FeedbackStats, SlotContext};
pub use builder::CoordinatorBuilder;
pub use pipeline::{PipelineConfig, PipelinedExecutor};

use crate::cache::{
    embedding_guard, quantize_embedding, CacheEntry, CachePayload, CacheSlotStats, CachedAnswer,
    EntryTag, QueryCache,
};
use crate::cluster::node::{EdgeNode, NodeSlotReport, QueryOutcome};
use crate::config::{ExperimentConfig, IntraStrategy};
use crate::corpus::synth::SyntheticDataset;
use crate::metrics::{Evaluator, QualityScores};
use crate::router::capacity::CapacityModel;
use crate::scenario::ScenarioEvent;
use crate::text::embed::Embedder;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::vecdb::{modeled_build_slots, IndexKind, IndexRegistry};
use crate::workload::trace::{domain_mix, sample_slot_queries};
use crate::Result;
use observer::{SlotEvent, SlotObserver};
use std::sync::Arc;

/// Aggregated result of one slot.
#[derive(Clone, Debug, Default)]
pub struct SlotReport {
    /// Queries the slot received (served + cached + dropped).
    pub queries: usize,
    /// Mean quality over all queries (dropped ones count as zeros).
    pub mean_scores: QualityScores,
    /// Dropped queries / total queries.
    pub drop_rate: f64,
    /// Makespan across nodes (max node completion time, Eq. 4 LHS).
    pub latency_s: f64,
    /// p_j^t per node.
    pub proportions: Vec<f64>,
    /// Per node: (modeled TS_n^t, measured wall-clock) of the slot's
    /// batched index search — the solver can be driven by either.
    pub node_search_s: Vec<(f64, f64)>,
    /// Per model-size (small/mid/large): share of served queries.
    pub size_query_share: [f64; 3],
    /// Per model-size (small/mid/large): share of GPU memory.
    pub size_mem_share: [f64; 3],
    /// All individual outcomes (for fine-grained analysis).
    pub outcomes: Vec<QueryOutcome>,
    /// Allocator learning activity this slot.
    pub feedback: FeedbackStats,
    /// Parameter-update rounds this slot (alias of `feedback.updates`).
    pub ppo_updates: usize,
    /// Per-node availability when the slot ran (scenario NodeDown/NodeUp).
    pub active: Vec<bool>,
    /// The latency SLO the slot ran under (varies under SloChange events).
    pub slo_s: f64,
    /// Cache-tier activity this slot; `None` when no cache is configured
    /// anywhere (the default), keeping pre-cache transcripts byte-stable.
    pub cache: Option<CacheSlotStats>,
    /// Per-node serving index kind — `Some` only once a `reindex` event
    /// has fired (reindex-free runs stay byte-identical). The slot where
    /// an entry changes pins the migration's swap boundary.
    pub index_kinds: Option<Vec<String>>,
    /// Per-node migration state (`from->to:slots_remaining`, `-` when
    /// idle) — `Some` under the same gate as `index_kinds`.
    pub migrations: Option<Vec<String>>,
}

/// Modeled coordinator-side latency of a semantic answer-cache hit: one
/// similarity lookup, no retrieval, no generation. Deterministic (never
/// wall-clock) so cached runs stay transcript-stable.
pub const ANSWER_HIT_LATENCY_S: f64 = 0.005;

/// What the serve phase produced, before aggregation.
pub struct ServedSlot {
    /// One outcome per query, in slot order.
    pub outcomes: Vec<QueryOutcome>,
    /// Makespan across nodes (s).
    pub latency_s: f64,
    /// Queries per model-size class (small/mid/large).
    pub size_queries: [usize; 3],
    /// GPU memory per model-size class.
    pub size_mem: [f64; 3],
    /// Per node: (modeled TS_n^t, measured wall-clock search time).
    pub node_search_s: Vec<(f64, f64)>,
    /// Retrieval-cache hits summed over nodes.
    pub cache_hits: usize,
    /// Retrieval-cache misses summed over nodes.
    pub cache_misses: usize,
    /// Retrieval-cache evictions summed over nodes.
    pub cache_evictions: usize,
}

impl ServedSlot {
    /// The serve phase of a slot where nothing needed serving (every
    /// query was answered from the cluster cache).
    fn empty(n_nodes: usize) -> Self {
        ServedSlot {
            outcomes: Vec::new(),
            latency_s: 0.0,
            size_queries: [0; 3],
            size_mem: [0.0; 3],
            node_search_s: vec![(0.0, 0.0); n_nodes],
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
        }
    }
}

/// The CoEdge-RAG coordinator.
pub struct Coordinator {
    /// The experiment configuration the system was built from.
    pub cfg: ExperimentConfig,
    /// The shared synthetic dataset (documents, QA pairs, domains).
    pub ds: SyntheticDataset,
    /// The edge nodes, in configuration order.
    pub nodes: Vec<EdgeNode>,
    /// Per-node capacity models C_n(L) (profiled or injected).
    pub capacities: Vec<CapacityModel>,
    /// The deterministic query/document embedder.
    pub embedder: Embedder,
    /// The quality-metrics evaluator.
    pub evaluator: Evaluator,
    /// Gold-doc locations per QA id (Oracle + diagnostics).
    pub gold_locs: Vec<Vec<usize>>,
    allocator: Box<dyn Allocator>,
    observers: Vec<Box<dyn SlotObserver>>,
    rng: Rng,
    slot_idx: usize,
    /// Per-node availability (scenario NodeDown/NodeUp); all up initially.
    active: Vec<bool>,
    /// Multiplicative per-node capacity scaling (scenario CapacityScale).
    cap_scale: Vec<f64>,
    /// Cluster-level semantic answer cache (`cfg.cache`; `NoneCache` by
    /// default). Hits are served at the coordinator without routing.
    pub(crate) answer_cache: Box<dyn QueryCache>,
    /// Whether the answer cache participates in `run_slot` at all.
    pub(crate) answer_cache_active: bool,
    /// Whether ANY cache (answer or per-node retrieval) is configured —
    /// gates `SlotReport::cache` so default runs stay byte-identical.
    pub(crate) cache_enabled: bool,
    /// Entries dropped by event-driven invalidation since the last slot
    /// report (folded into the next `CacheSlotStats`).
    pending_invalidations: usize,
    /// The index registry nodes were built from, kept for reindex
    /// migrations (background builds need the factories).
    pub(crate) index_registry: Arc<IndexRegistry>,
    /// Whether any `reindex` event has fired — gates the migration
    /// fields of [`SlotReport`] so reindex-free transcripts stay
    /// byte-identical to the pre-migration system.
    reindex_seen: bool,
    /// Fault-injection offset on every reindex's modeled build-slot
    /// countdown (fuzz-oracle swap-ordering test); 0 in production.
    migration_swap_skew: i64,
}

/// Scope of a cache-invalidation request, the hook scenario events reach
/// the cache tier through ([`Coordinator::apply_event`] →
/// [`Coordinator::invalidate_caches`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheInvalidate {
    /// `node`'s corpus changed (corpus-ingest): its retrieval cache is
    /// flushed (new vectors can enter *any* query's top-k) and answer
    /// entries produced by that node are dropped.
    Corpus { node: usize },
    /// The query mix changed (skew-shift): the semantic answer cache is
    /// flushed — the hot set it was warmed for no longer represents the
    /// arrival distribution (EACO-RAG-style adaptive knowledge update).
    QueryMix,
    /// Flush everything, everywhere.
    All,
}

impl Coordinator {
    /// The active allocator.
    pub fn allocator(&self) -> &dyn Allocator {
        self.allocator.as_ref()
    }

    /// Mutable access to the active allocator (swap-free tuning).
    pub fn allocator_mut(&mut self) -> &mut dyn Allocator {
        self.allocator.as_mut()
    }

    /// Freeze allocator learning — measurement sweeps must vary only the
    /// workload, not the policy's training progress.
    pub fn freeze_learning(&mut self) {
        self.allocator.freeze();
    }

    /// Attach an additional slot observer (all attached observers receive
    /// every event, in attachment order).
    pub fn add_observer(&mut self, observer: Box<dyn SlotObserver>) {
        self.observers.push(observer);
    }

    /// Drop all attached observers and install `observer` alone.
    pub fn set_observer(&mut self, observer: Box<dyn SlotObserver>) {
        self.observers.clear();
        self.observers.push(observer);
    }

    fn emit(&mut self, event: &SlotEvent) {
        for obs in self.observers.iter_mut() {
            obs.on_event(event);
        }
    }

    /// Sample one slot's queries per the configured skew pattern. Errors
    /// when the pattern is invalid for the dataset (e.g. an out-of-range
    /// primary domain injected by a SkewShift event).
    pub fn sample_queries(&mut self, count: usize) -> Result<Vec<usize>> {
        let mix = domain_mix(&self.cfg.skew, self.ds.num_domains(), &mut self.rng)?;
        sample_slot_queries(&self.ds, &mix, count, &mut self.rng)
    }

    /// Phase ①: embed the slot's queries.
    pub fn encode(&self, qa_ids: &[usize]) -> Vec<Vec<f32>> {
        qa_ids
            .iter()
            .map(|&q| self.embedder.embed(&self.ds.qa_pairs[q].query))
            .collect()
    }

    /// Effective per-node capacities C_n(L) at the current SLO: a down
    /// node contributes exactly 0; live nodes are scaled by any
    /// CapacityScale factors applied so far.
    pub fn slot_capacities(&self) -> Vec<f64> {
        let slo = self.cfg.slo_s;
        self.capacities
            .iter()
            .enumerate()
            .map(|(j, c)| if self.active[j] { c.eval(slo) * self.cap_scale[j] } else { 0.0 })
            .collect()
    }

    /// Per-node availability mask (scenario NodeDown/NodeUp events).
    pub fn node_active(&self) -> &[bool] {
        &self.active
    }

    /// Mark a node down (`up = false`) or back up. Down nodes have
    /// capacity 0 and must receive no queries — `route` enforces it.
    pub fn set_node_active(&mut self, node: usize, up: bool) -> Result<()> {
        anyhow::ensure!(
            node < self.nodes.len(),
            "node {node} out of range (cluster has {} nodes)",
            self.nodes.len()
        );
        self.active[node] = up;
        Ok(())
    }

    /// Multiply a node's effective capacity by `factor` (composes with
    /// earlier scalings; <1 models degradation, >1 an upgrade).
    pub fn scale_capacity(&mut self, node: usize, factor: f64) -> Result<()> {
        anyhow::ensure!(
            node < self.nodes.len(),
            "node {node} out of range (cluster has {} nodes)",
            self.nodes.len()
        );
        anyhow::ensure!(
            factor.is_finite() && factor > 0.0,
            "capacity factor must be finite and > 0 (a factor of 0 would brick the node \
             permanently — node-up cannot undo a zeroed scale; use node-down for outages), \
             got {factor}"
        );
        self.cap_scale[node] *= factor;
        Ok(())
    }

    /// Live corpus update: replicate up to `docs` documents of `domain`
    /// (lowest ids first, deterministic) onto `node`, adding them to its
    /// running index without a rebuild or re-finalize (post-train IVF
    /// routes online; HNSW builds incrementally). Gold-document locations
    /// are updated for diagnostics and future Oracle builds; an already
    /// built OracleAllocator keeps its snapshot, which stays *valid*
    /// (ingest only adds replicas) just not refreshed. Returns how many
    /// documents were actually new to the node.
    pub fn ingest_corpus(&mut self, node: usize, domain: usize, docs: usize) -> Result<usize> {
        anyhow::ensure!(
            node < self.nodes.len(),
            "node {node} out of range (cluster has {} nodes)",
            self.nodes.len()
        );
        let nd = self.ds.num_domains();
        anyhow::ensure!(domain < nd, "domain {domain} out of range (dataset has {nd} domains)");
        let held: std::collections::HashSet<usize> =
            self.nodes[node].doc_ids.iter().copied().collect();
        let new_ids: Vec<usize> = self
            .ds
            .docs_of_domain(domain)
            .into_iter()
            .filter(|d| !held.contains(d))
            .take(docs)
            .collect();
        self.nodes[node].ingest_docs(&new_ids);
        let ingested: std::collections::HashSet<usize> = new_ids.iter().copied().collect();
        for qa in &self.ds.qa_pairs {
            if ingested.contains(&qa.gold_doc) && !self.gold_locs[qa.id].contains(&node) {
                self.gold_locs[qa.id].push(node);
                self.gold_locs[qa.id].sort_unstable();
            }
        }
        // the corpus actually changed: cached retrieval results and
        // answers derived from this node's old corpus are now stale
        if !new_ids.is_empty() {
            self.invalidate_caches(CacheInvalidate::Corpus { node });
        }
        Ok(new_ids.len())
    }

    /// Drop cache entries a cluster change may have staled. Called by
    /// [`apply_event`](Self::apply_event) for `corpus-ingest` and
    /// `skew-shift` (and by [`ingest_corpus`](Self::ingest_corpus)
    /// directly, so programmatic ingest is covered too); also public for
    /// custom invalidation flows. Returns how many entries were dropped;
    /// the count is folded into the next slot's `CacheSlotStats`.
    pub fn invalidate_caches(&mut self, scope: CacheInvalidate) -> usize {
        let dropped = match scope {
            CacheInvalidate::Corpus { node } => {
                self.nodes[node].invalidate_cache()
                    + self.answer_cache.invalidate(&mut |tag: &EntryTag| tag.node == node)
            }
            CacheInvalidate::QueryMix => self.answer_cache.clear(),
            CacheInvalidate::All => {
                self.answer_cache.clear()
                    + self
                        .nodes
                        .iter_mut()
                        .map(|n| n.invalidate_cache())
                        .sum::<usize>()
            }
        };
        self.pending_invalidations += dropped;
        dropped
    }

    /// Apply one scenario event (between slots). `BurstOverride` is a
    /// no-op here — it is a per-slot load override consumed by the
    /// [`ScenarioRunner`](crate::scenario::ScenarioRunner)'s arrival loop.
    pub fn apply_event(&mut self, event: &ScenarioEvent) -> Result<()> {
        match event {
            ScenarioEvent::NodeDown { node } => self.set_node_active(*node, false),
            ScenarioEvent::NodeUp { node } => self.set_node_active(*node, true),
            ScenarioEvent::CapacityScale { node, factor } => self.scale_capacity(*node, *factor),
            ScenarioEvent::SloChange { slo_s } => {
                anyhow::ensure!(
                    slo_s.is_finite() && *slo_s > 0.0,
                    "slo change must be positive, got {slo_s}"
                );
                self.set_slo(*slo_s);
                Ok(())
            }
            ScenarioEvent::CorpusIngest { node, docs, domain } => {
                self.ingest_corpus(*node, *domain, *docs).map(|_| ())
            }
            ScenarioEvent::BurstOverride { .. } => Ok(()),
            ScenarioEvent::SkewShift { pattern } => {
                pattern.validate(self.ds.num_domains())?;
                self.cfg.skew = pattern.clone();
                self.invalidate_caches(CacheInvalidate::QueryMix);
                Ok(())
            }
            ScenarioEvent::Reindex { node, to, shards, rescore_factor } => {
                anyhow::ensure!(
                    *node < self.nodes.len(),
                    "node {node} out of range (cluster has {} nodes)",
                    self.nodes.len()
                );
                anyhow::ensure!(
                    self.active[*node],
                    "reindex: node {node} is down — bring it back with node-up before \
                     migrating its index"
                );
                let kind: IndexKind = to.parse()?;
                let rows = self.nodes[*node].corpus_size();
                let modeled = modeled_build_slots(rows, kind) as i64;
                let build_slots = (modeled + self.migration_swap_skew).max(1) as usize;
                self.nodes[*node].begin_reindex(
                    kind,
                    *shards,
                    *rescore_factor,
                    Arc::clone(&self.index_registry),
                    build_slots,
                );
                self.reindex_seen = true;
                Ok(())
            }
        }
    }

    /// Fault-injection hook for the fuzz oracle's swap-ordering test:
    /// offsets every subsequent reindex's modeled build-slot countdown
    /// (clamped to ≥ 1), making the engine swap earlier/later than the
    /// modeled contract. Zero (the default) is the production behavior.
    #[doc(hidden)]
    pub fn set_migration_swap_skew(&mut self, skew: i64) {
        self.migration_swap_skew = skew;
    }

    /// Advance every in-flight reindex migration by one slot boundary
    /// (called after each slot's report is assembled, on the shed path
    /// too, so every executor swaps at the identical boundary). A node
    /// whose countdown elapsed atomically swaps to the freshly built
    /// index and has its caches flushed — retrieval cache plus answer
    /// entries it produced, since a different index kind may rank ties
    /// differently.
    fn tick_migrations(&mut self) -> Result<()> {
        for i in 0..self.nodes.len() {
            if self.nodes[i].tick_migration()? {
                self.invalidate_caches(CacheInvalidate::Corpus { node: i });
            }
        }
        Ok(())
    }

    /// Per-node serving index kinds for the slot report; `None` until the
    /// first `reindex` event (keeps reindex-free transcripts byte-stable).
    fn slot_index_kinds(&self) -> Option<Vec<String>> {
        self.reindex_seen.then(|| self.nodes.iter().map(|n| n.index_kind.clone()).collect())
    }

    /// Per-node migration labels (`-` when idle), under the same gate.
    fn slot_migrations(&self) -> Option<Vec<String>> {
        self.reindex_seen.then(|| {
            self.nodes
                .iter()
                .map(|n| n.migration_label().unwrap_or_else(|| "-".into()))
                .collect()
        })
    }

    /// Phase ②: identification + inter-node routing via the allocator.
    pub fn route(
        &mut self,
        slot: usize,
        qa_ids: &[usize],
        embs: &[Vec<f32>],
        caps: &[f64],
    ) -> Result<Assignment> {
        let ctx = SlotContext {
            slot_idx: slot,
            qa_ids,
            embs,
            ds: &self.ds,
            capacities: caps,
            active: &self.active,
            slo_s: self.cfg.slo_s,
            inter_enabled: self.cfg.inter_enabled,
        };
        let assignment = self.allocator.assign(&ctx)?;
        anyhow::ensure!(
            assignment.node_of.len() == qa_ids.len(),
            "allocator {:?} returned {} assignments for {} queries",
            self.allocator.name(),
            assignment.node_of.len(),
            qa_ids.len()
        );
        if let Some(&bad) = assignment.node_of.iter().find(|&&a| a >= self.nodes.len()) {
            anyhow::bail!(
                "allocator {:?} routed to node {bad} (cluster has {})",
                self.allocator.name(),
                self.nodes.len()
            );
        }
        if let Some(&bad) = assignment.node_of.iter().find(|&&a| !self.active[a]) {
            anyhow::bail!(
                "allocator {:?} routed to down node {bad}",
                self.allocator.name()
            );
        }
        Ok(assignment)
    }

    /// Phase ③: serve at each node — nodes are independent, so they serve
    /// in parallel on scoped threads (§Perf: ~2.5× on the 4-node slot).
    pub fn serve(
        &mut self,
        qa_ids: &[usize],
        embs: &[Vec<f32>],
        assignment: &Assignment,
    ) -> ServedSlot {
        let slo = self.cfg.slo_s;
        let n_nodes = self.nodes.len();
        let b = qa_ids.len();

        // dispatch per node (preserving query order within node)
        let mut per_node: Vec<Vec<usize>> = vec![Vec::new(); n_nodes]; // indices into qa_ids
        for (i, &a) in assignment.node_of.iter().enumerate() {
            per_node[a].push(i);
        }

        let inputs: Vec<(Vec<usize>, Vec<Vec<f32>>)> = per_node
            .iter()
            .map(|idxs| {
                (
                    idxs.iter().map(|&i| qa_ids[i]).collect(),
                    idxs.iter().map(|&i| embs[i].clone()).collect(),
                )
            })
            .collect();
        let node_reports: Vec<NodeSlotReport> = {
            let ds = &self.ds;
            let ev = &self.evaluator;
            let em = &self.embedder;
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .nodes
                    .iter_mut()
                    .zip(&inputs)
                    .map(|(node, (qids, nembs))| {
                        scope.spawn(move || node.serve_slot(ds, ev, em, Some(nembs), qids, slo))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("node thread")).collect()
            })
        };

        let mut outcomes_by_pos: Vec<Option<QueryOutcome>> = vec![None; b];
        let mut latency_s = 0.0f64;
        let mut size_queries = [0usize; 3];
        let mut size_mem = [0.0f64; 3];
        let mut node_search_s = Vec::with_capacity(n_nodes);
        let (mut cache_hits, mut cache_misses, mut cache_evictions) = (0usize, 0usize, 0usize);
        for (nid, (idxs, report)) in per_node.iter().zip(node_reports).enumerate() {
            latency_s = latency_s.max(report.makespan_s);
            node_search_s.push((report.search_time_s, report.measured_search_s));
            cache_hits += report.cache_hits;
            cache_misses += report.cache_misses;
            cache_evictions += report.cache_evictions;
            for (mi, m) in self.nodes[nid].pool.iter().enumerate() {
                let si = m.size as usize;
                size_queries[si] += report.per_model_queries[mi];
                size_mem[si] += report.per_model_mem[mi];
            }
            for (pos_in_node, out) in report.outcomes.into_iter().enumerate() {
                let orig = idxs[pos_in_node];
                outcomes_by_pos[orig] = Some(out);
            }
        }
        let outcomes: Vec<QueryOutcome> =
            outcomes_by_pos.into_iter().map(|o| o.expect("outcome")).collect();
        ServedSlot {
            outcomes,
            latency_s,
            size_queries,
            size_mem,
            node_search_s,
            cache_hits,
            cache_misses,
            cache_evictions,
        }
    }

    /// Phase ④: feed outcomes back into the allocator. Skipped entirely
    /// for frozen allocators ([`Allocator::is_frozen`]): no `observe`
    /// call can mutate learner state or drift [`FeedbackStats`], so a
    /// frozen policy replays a fixture byte-identically.
    pub fn feedback(
        &mut self,
        slot: usize,
        qa_ids: &[usize],
        embs: &[Vec<f32>],
        caps: &[f64],
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        if self.allocator.is_frozen() {
            return Ok(FeedbackStats::default());
        }
        let ctx = SlotContext {
            slot_idx: slot,
            qa_ids,
            embs,
            ds: &self.ds,
            capacities: caps,
            active: &self.active,
            slo_s: self.cfg.slo_s,
            inter_enabled: self.cfg.inter_enabled,
        };
        self.allocator.observe(&ctx, assignment, outcomes)
    }

    /// All nodes down: shed the whole slot at the coordinator. There is
    /// nothing to route to, so the allocator is bypassed; every query is
    /// dropped with `node == usize::MAX` ("never routed") and proportions
    /// are all zero. Observers still receive the closing `SlotEnd`.
    fn shed_slot(&mut self, slot: usize, qa_ids: &[usize]) -> Result<SlotReport> {
        let b = qa_ids.len();
        let n_nodes = self.nodes.len();
        let cache = self.slot_cache_stats((0, 0, 0), (0, 0, 0));
        let outcomes: Vec<QueryOutcome> = qa_ids
            .iter()
            .map(|&q| QueryOutcome {
                qa_id: q,
                node: usize::MAX,
                model_idx: None,
                dropped: true,
                rel: 0.0,
                scores: QualityScores::zeros(),
                feedback: 0.0,
                latency_s: self.cfg.slo_s,
                cached: false,
            })
            .collect();
        let report = SlotReport {
            queries: b,
            mean_scores: QualityScores::default(),
            drop_rate: if b == 0 { 0.0 } else { 1.0 },
            latency_s: 0.0,
            proportions: vec![0.0; n_nodes],
            node_search_s: vec![(0.0, 0.0); n_nodes],
            size_query_share: [0.0; 3],
            size_mem_share: [0.0; 3],
            outcomes,
            feedback: FeedbackStats::default(),
            ppo_updates: 0,
            active: self.active.clone(),
            slo_s: self.cfg.slo_s,
            cache,
            index_kinds: self.slot_index_kinds(),
            migrations: self.slot_migrations(),
        };
        self.emit(&SlotEvent::SlotEnd { slot, report: &report });
        self.tick_migrations()?;
        Ok(report)
    }

    /// Per-slot cache statistics, or `None` when no cache tier is
    /// configured anywhere (keeps default-run reports and transcripts
    /// byte-identical to the pre-cache system). Folds in — and resets —
    /// the invalidation count accumulated by events since the last slot.
    fn slot_cache_stats(
        &mut self,
        retrieval: (usize, usize, usize),
        answer: (usize, usize, usize),
    ) -> Option<CacheSlotStats> {
        if !self.cache_enabled {
            return None;
        }
        let bytes = self.answer_cache.bytes()
            + self.nodes.iter().map(|n| n.cache.bytes()).sum::<usize>();
        Some(CacheSlotStats {
            retrieval_hits: retrieval.0,
            retrieval_misses: retrieval.1,
            retrieval_evictions: retrieval.2,
            answer_hits: answer.0,
            answer_misses: answer.1,
            answer_evictions: answer.2,
            invalidations: std::mem::take(&mut self.pending_invalidations),
            bytes,
        })
    }

    /// Run one complete slot for the given QA ids.
    pub fn run_slot(&mut self, qa_ids: &[usize]) -> Result<SlotReport> {
        let t = Timer::start();
        let embs = self.encode(qa_ids);
        self.run_slot_encoded(qa_ids, embs, t.secs())
    }

    /// [`run_slot`](Self::run_slot) with the encode phase hoisted out: the
    /// caller supplies the slot's embeddings (plus the wall-clock the
    /// encode took, carried into the `Encoded` observer event). This is
    /// the seam the pipelined executor ([`pipeline`]) drives — encode of
    /// slot `t+1` runs on a prefetch thread while slot `t` routes and
    /// serves here. `embs` must equal `self.encode(qa_ids)` (the embedder
    /// is deterministic and stateless, so a clone computes identical
    /// vectors); anything else would change routing and break transcript
    /// byte-stability. On the all-nodes-down shed path the embeddings are
    /// discarded and — exactly as in the synchronous path — no `Encoded`
    /// event is emitted.
    pub fn run_slot_encoded(
        &mut self,
        qa_ids: &[usize],
        embs: Vec<Vec<f32>>,
        encode_elapsed_s: f64,
    ) -> Result<SlotReport> {
        anyhow::ensure!(
            embs.len() == qa_ids.len(),
            "run_slot_encoded: {} embeddings for {} queries",
            embs.len(),
            qa_ids.len()
        );
        let slot = self.slot_idx;
        self.slot_idx += 1;
        if !self.active.iter().any(|&a| a) {
            return self.shed_slot(slot, qa_ids);
        }
        let b = qa_ids.len();
        let n_nodes = self.nodes.len();

        self.emit(&SlotEvent::Encoded { slot, queries: b, elapsed_s: encode_elapsed_s });

        // semantic answer-cache pre-pass: a hit replays the stored answer
        // (bitwise-equal scores at threshold 1.0) without ever routing the
        // query. Inactive ⇒ everything "misses" without a single cache
        // call — the pre-cache path, bit for bit.
        let mut cached_out: Vec<Option<QueryOutcome>> = vec![None; b];
        let (mut answer_hits, mut answer_misses, mut answer_evictions) = (0usize, 0usize, 0usize);
        let mut keys: Vec<Vec<i8>> = Vec::new();
        let mut guards: Vec<u64> = Vec::new();
        let mut miss_pos: Vec<usize> = Vec::with_capacity(b);
        if self.answer_cache_active {
            let threshold = self.cfg.cache.threshold;
            keys = embs.iter().map(|e| quantize_embedding(e)).collect();
            guards = embs.iter().map(|e| embedding_guard(e)).collect();
            for (i, &q) in qa_ids.iter().enumerate() {
                // at exact-only thresholds a key hit must also match the
                // full-precision guard — a quantization collision becomes
                // a miss, never someone else's answer
                match self.answer_cache.get_similar(&keys[i], threshold) {
                    Some(CacheEntry { guard, payload: CachePayload::Answer(a), .. })
                        if threshold < 1.0 || guard == guards[i] =>
                    {
                        answer_hits += 1;
                        cached_out[i] = Some(QueryOutcome {
                            qa_id: q,
                            node: a.node,
                            model_idx: a.model_idx,
                            dropped: false,
                            rel: a.rel,
                            scores: a.scores,
                            feedback: a.feedback,
                            latency_s: ANSWER_HIT_LATENCY_S,
                            cached: true,
                        });
                    }
                    _ => {
                        answer_misses += 1;
                        miss_pos.push(i);
                    }
                }
            }
        } else {
            miss_pos.extend(0..b);
        }

        // route / serve / feedback run over the cache misses only (== the
        // whole slot whenever the answer cache is off)
        let all_miss = miss_pos.len() == b;
        let qa_sub: Vec<usize>;
        let emb_sub: Vec<Vec<f32>>;
        let (qa_m, embs_m): (&[usize], &[Vec<f32>]) = if all_miss {
            (qa_ids, &embs)
        } else {
            qa_sub = miss_pos.iter().map(|&i| qa_ids[i]).collect();
            emb_sub = miss_pos.iter().map(|&i| embs[i].clone()).collect();
            (&qa_sub, &emb_sub)
        };

        let (assignment, served, stats) = if self.answer_cache_active && qa_m.is_empty() {
            // the whole slot was answered from cache: nothing to route,
            // the allocator is not consulted (and learns nothing). (With
            // the cache off an empty slot still takes the normal path —
            // allocators see exactly the pre-cache call sequence.)
            (Assignment::default(), ServedSlot::empty(n_nodes), FeedbackStats::default())
        } else {
            let t = Timer::start();
            let caps = self.slot_capacities();
            let assignment = self.route(slot, qa_m, embs_m, &caps)?;
            self.emit(&SlotEvent::Routed { slot, assignment: &assignment, elapsed_s: t.secs() });

            let t = Timer::start();
            let served = self.serve(qa_m, embs_m, &assignment);
            self.emit(&SlotEvent::Served {
                slot,
                outcomes: &served.outcomes,
                makespan_s: served.latency_s,
                elapsed_s: t.secs(),
            });

            let t = Timer::start();
            let stats = self.feedback(slot, qa_m, embs_m, &caps, &assignment, &served.outcomes)?;
            self.emit(&SlotEvent::Feedback { slot, stats, elapsed_s: t.secs() });
            (assignment, served, stats)
        };

        // freshly served answers warm the answer cache for future slots
        if self.answer_cache_active {
            for (&i, out) in miss_pos.iter().zip(&served.outcomes) {
                if out.dropped {
                    continue;
                }
                answer_evictions += self.answer_cache.insert(
                    keys[i].clone(),
                    CacheEntry {
                        tag: EntryTag {
                            node: out.node,
                            domain: self.ds.qa_pairs[out.qa_id].domain,
                        },
                        guard: guards[i],
                        payload: CachePayload::Answer(CachedAnswer {
                            node: out.node,
                            model_idx: out.model_idx,
                            rel: out.rel,
                            scores: out.scores,
                            feedback: out.feedback,
                        }),
                    },
                );
            }
        }

        let cache = self.slot_cache_stats(
            (served.cache_hits, served.cache_misses, served.cache_evictions),
            (answer_hits, answer_misses, answer_evictions),
        );

        // aggregate, cached answers merged back in slot order
        let ServedSlot {
            outcomes: served_out, latency_s, size_queries, size_mem, node_search_s, ..
        } = served;
        // answer hits complete at the coordinator after the lookup, so
        // the slot makespan is at least that (matters when every query
        // hit and no node ran); exactly the node makespan when cache off
        let latency_s =
            if answer_hits > 0 { latency_s.max(ANSWER_HIT_LATENCY_S) } else { latency_s };
        let mut served_iter = served_out.into_iter();
        let outcomes: Vec<QueryOutcome> = cached_out
            .into_iter()
            .map(|c| match c {
                Some(o) => o,
                None => served_iter.next().expect("served outcome"),
            })
            .collect();
        let drop_rate = outcomes.iter().filter(|o| o.dropped).count() as f64 / b.max(1) as f64;
        let all_scores: Vec<QualityScores> = outcomes.iter().map(|o| o.scores).collect();
        let total_q: usize = size_queries.iter().sum();
        let total_m: f64 = size_mem.iter().sum();
        let mut node_counts = vec![0usize; n_nodes];
        for &a in &assignment.node_of {
            node_counts[a] += 1;
        }
        let proportions =
            node_counts.iter().map(|&q| q as f64 / b.max(1) as f64).collect();
        let report = SlotReport {
            queries: b,
            mean_scores: QualityScores::mean(&all_scores),
            drop_rate,
            latency_s,
            proportions,
            node_search_s,
            size_query_share: std::array::from_fn(|i| {
                if total_q == 0 { 0.0 } else { size_queries[i] as f64 / total_q as f64 }
            }),
            size_mem_share: std::array::from_fn(|i| {
                if total_m == 0.0 { 0.0 } else { size_mem[i] / total_m }
            }),
            outcomes,
            feedback: stats,
            ppo_updates: stats.updates,
            active: self.active.clone(),
            slo_s: self.cfg.slo_s,
            cache,
            index_kinds: self.slot_index_kinds(),
            migrations: self.slot_migrations(),
        };
        self.emit(&SlotEvent::SlotEnd { slot, report: &report });
        self.tick_migrations()?;
        Ok(report)
    }

    /// Run `slots` slots of `queries_per_slot`, returning all reports.
    /// (Static load; use [`ScenarioRunner`](crate::scenario::ScenarioRunner)
    /// for trace-driven fluctuating load and mid-run cluster dynamics.)
    pub fn run(&mut self, slots: usize) -> Result<Vec<SlotReport>> {
        let mut reports = Vec::with_capacity(slots);
        for _ in 0..slots {
            let qids = self.sample_queries(self.cfg.queries_per_slot)?;
            reports.push(self.run_slot(&qids)?);
        }
        Ok(reports)
    }

    /// Mean scores over the last `k` reports (post-warmup evaluation).
    pub fn tail_mean(reports: &[SlotReport], k: usize) -> QualityScores {
        let tail: Vec<QualityScores> =
            reports.iter().rev().take(k).map(|r| r.mean_scores).collect();
        QualityScores::mean(&tail)
    }
}

impl Coordinator {
    /// Swap the intra-node strategy on all nodes (Table III benches).
    pub fn set_intra_strategy(&mut self, s: IntraStrategy) {
        self.cfg.intra = s.clone();
        for n in self.nodes.iter_mut() {
            n.strategy = s.clone();
        }
    }

    /// Change the per-slot latency SLO L^t.
    pub fn set_slo(&mut self, slo_s: f64) {
        self.cfg.slo_s = slo_s;
    }
}
