//! The global coordinator (paper Fig. 4): per slot it ① encodes queries
//! and computes matching probabilities via the online identifier,
//! routes them with the inter-node scheduler, ② lets nodes retrieve and
//! ③ serve with their intra-node plans, then ④ feeds quality metrics back
//! into the PPO policy — the full closed loop.
//!
//! [`baselines`] hosts the alternative allocators (Random / Domain /
//! Oracle / MAB) used across the paper's comparisons.

pub mod baselines;

use std::sync::Arc;

use crate::cluster::node::{EdgeNode, QueryOutcome};
use crate::config::{AllocatorKind, DatasetKind, ExperimentConfig, IntraStrategy};
use crate::corpus::partition::{gold_locations, partition_corpus, NodeCorpusSpec};
use crate::corpus::synth::SyntheticDataset;
use crate::corpus::{build_dataset, domainqa_spec, ppc_spec};
use crate::metrics::{Evaluator, QualityScores};
use crate::policy::ppo::{Backend, OnlinePolicy, PpoConfig};
use crate::router::capacity::{profile_capacity, CapacityModel};
use crate::router::inter::inter_node_schedule;
use crate::text::embed::{Embedder, EMBED_DIM};
use crate::util::rng::Rng;
use crate::workload::trace::{domain_mix, sample_slot_queries};
use crate::Result;
use baselines::BaselineAllocator;

/// Aggregated result of one slot.
#[derive(Clone, Debug, Default)]
pub struct SlotReport {
    pub queries: usize,
    pub mean_scores: QualityScores,
    pub drop_rate: f64,
    /// Makespan across nodes (max node completion time, Eq. 4 LHS).
    pub latency_s: f64,
    /// p_j^t per node.
    pub proportions: Vec<f64>,
    /// Per model-size (small/mid/large): query share and memory share.
    pub size_query_share: [f64; 3],
    pub size_mem_share: [f64; 3],
    /// All individual outcomes (for fine-grained analysis).
    pub outcomes: Vec<QueryOutcome>,
    /// PPO update stats if an update ran this slot.
    pub ppo_updates: usize,
}

/// The CoEdge-RAG coordinator.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    pub ds: SyntheticDataset,
    pub nodes: Vec<EdgeNode>,
    pub capacities: Vec<CapacityModel>,
    pub embedder: Embedder,
    pub evaluator: Evaluator,
    /// Gold-doc locations per QA id (Oracle + diagnostics).
    pub gold_locs: Vec<Vec<usize>>,
    pub policy: Option<OnlinePolicy>,
    pub baseline: Option<BaselineAllocator>,
    rng: Rng,
    slot_idx: usize,
}

impl Coordinator {
    /// Build the full system from a config: dataset, partition, nodes,
    /// capacity profiles, and the selected allocator.
    pub fn build(cfg: ExperimentConfig, backend: Backend) -> Result<Coordinator> {
        let spec = match cfg.dataset {
            DatasetKind::DomainQa => domainqa_spec(cfg.qa_per_domain, cfg.docs_per_domain),
            DatasetKind::Ppc => ppc_spec(cfg.qa_per_domain, cfg.docs_per_domain),
        };
        let ds = build_dataset(&spec, cfg.seed);
        let embedder = Embedder::default();
        let evaluator = Evaluator::default();
        let nd = ds.num_domains();

        // partition corpora (dual-distribution, paper §V-A)
        let specs: Vec<NodeCorpusSpec> = cfg
            .nodes
            .iter()
            .map(|n| NodeCorpusSpec::dual(n.corpus_docs, nd, &n.primary_domains, cfg.s_iid))
            .collect();
        let parts = partition_corpus(&ds, &specs, cfg.overlap, cfg.seed ^ 0x9A87);
        let gold_locs = gold_locations(&ds, &parts);

        // embed all documents once (shared cache)
        let doc_embs: Arc<Vec<Vec<f32>>> = Arc::new(
            ds.documents.iter().map(|d| embedder.embed(&d.text())).collect(),
        );

        let mut nodes: Vec<EdgeNode> = cfg
            .nodes
            .iter()
            .enumerate()
            .map(|(i, ncfg)| {
                EdgeNode::build(
                    i,
                    ncfg,
                    &ds,
                    parts[i].clone(),
                    Arc::clone(&doc_embs),
                    &evaluator,
                    cfg.intra.clone(),
                    cfg.top_k,
                    cfg.seed ^ 0x0D0E ^ i as u64,
                )
            })
            .collect();

        // capacity profiling (initialization phase, §IV-B)
        let capacities: Vec<CapacityModel> = nodes
            .iter()
            .map(|n| profile_capacity(|q, l| n.dry_run_drop_rate(q, l), 0.01))
            .collect();

        // allocator
        let mut policy = None;
        let mut baseline = None;
        match cfg.allocator {
            AllocatorKind::Ppo => {
                let pcfg = PpoConfig {
                    buffer_threshold: cfg.ppo_buffer,
                    epochs: cfg.ppo_epochs,
                    seed: cfg.seed ^ 0x9090,
                    ..Default::default()
                };
                policy = Some(OnlinePolicy::new(cfg.num_nodes(), pcfg, backend));
            }
            kind => {
                baseline = Some(BaselineAllocator::new(kind, &cfg, &gold_locs, cfg.seed ^ 0xBA5E));
            }
        }
        // nudge node rngs apart
        for n in nodes.iter_mut() {
            let _ = n.corpus_size();
        }
        Ok(Coordinator {
            rng: Rng::new(cfg.seed ^ 0xC00D),
            cfg,
            ds,
            nodes,
            capacities,
            embedder,
            evaluator,
            gold_locs,
            policy,
            baseline,
            slot_idx: 0,
        })
    }

    /// Sample one slot's queries per the configured skew pattern.
    pub fn sample_queries(&mut self, count: usize) -> Vec<usize> {
        let mix = domain_mix(&self.cfg.skew, self.ds.num_domains(), &mut self.rng);
        sample_slot_queries(&self.ds, &mix, count, &mut self.rng)
    }

    /// Run one complete slot for the given QA ids.
    pub fn run_slot(&mut self, qa_ids: &[usize]) -> Result<SlotReport> {
        let slo = self.cfg.slo_s;
        let n_nodes = self.nodes.len();
        let b = qa_ids.len();
        self.slot_idx += 1;

        // ① encode queries
        let embs: Vec<Vec<f32>> = qa_ids
            .iter()
            .map(|&q| self.embedder.embed(&self.ds.qa_pairs[q].query))
            .collect();

        // identification + inter-node routing
        let caps: Vec<f64> = self.capacities.iter().map(|c| c.eval(slo)).collect();
        let (assignment, old_logps, probs_flat) = match (&mut self.policy, &mut self.baseline) {
            (Some(policy), _) => {
                let mut flat = Vec::with_capacity(b * EMBED_DIM);
                for e in &embs {
                    flat.extend_from_slice(e);
                }
                let probs = policy.probs(&flat, b)?;
                if self.cfg.inter_enabled {
                    let res = inter_node_schedule(&probs, n_nodes, &caps, &mut self.rng);
                    // behavior logp for PPO: probability of the final node
                    let logps: Vec<f32> = res
                        .assignment
                        .iter()
                        .enumerate()
                        .map(|(i, &a)| probs[i * n_nodes + a].max(1e-12).ln())
                        .collect();
                    (res.assignment, logps, probs)
                } else {
                    // ablation: pure probability sampling, no capacity check
                    let mut assignment = Vec::with_capacity(b);
                    let mut logps = Vec::with_capacity(b);
                    for i in 0..b {
                        let row = &probs[i * n_nodes..(i + 1) * n_nodes];
                        let (a, lp) = policy.sample_action(row);
                        assignment.push(a);
                        logps.push(lp);
                    }
                    (assignment, logps, probs)
                }
            }
            (None, Some(base)) => {
                let assignment = base.assign(
                    &self.ds,
                    qa_ids,
                    &embs,
                    &caps,
                    self.cfg.inter_enabled,
                    &mut self.rng,
                );
                (assignment, Vec::new(), Vec::new())
            }
            _ => unreachable!("coordinator without allocator"),
        };
        let _ = probs_flat;

        // dispatch per node (preserving query order within node)
        let mut per_node: Vec<Vec<usize>> = vec![Vec::new(); n_nodes]; // indices into qa_ids
        for (i, &a) in assignment.iter().enumerate() {
            per_node[a].push(i);
        }

        // ②③ serve at each node — nodes are independent, so they serve
        // in parallel on scoped threads (§Perf: ~2.5× on the 4-node slot)
        let inputs: Vec<(Vec<usize>, Vec<Vec<f32>>)> = per_node
            .iter()
            .map(|idxs| {
                (
                    idxs.iter().map(|&i| qa_ids[i]).collect(),
                    idxs.iter().map(|&i| embs[i].clone()).collect(),
                )
            })
            .collect();
        let node_reports: Vec<crate::cluster::node::NodeSlotReport> = {
            let ds = &self.ds;
            let ev = &self.evaluator;
            let em = &self.embedder;
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .nodes
                    .iter_mut()
                    .zip(&inputs)
                    .map(|(node, (qids, nembs))| {
                        scope.spawn(move || {
                            node.serve_slot(ds, ev, em, Some(nembs), qids, slo)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("node thread")).collect()
            })
        };
        let mut outcomes_by_pos: Vec<Option<QueryOutcome>> = vec![None; b];
        let mut latency_s = 0.0f64;
        let mut size_queries = [0usize; 3];
        let mut size_mem = [0.0f64; 3];
        for (nid, (idxs, report)) in per_node.iter().zip(node_reports).enumerate() {
            latency_s = latency_s.max(report.makespan_s);
            for (mi, m) in self.nodes[nid].pool.iter().enumerate() {
                let si = m.size as usize;
                size_queries[si] += report.per_model_queries[mi];
                size_mem[si] += report.per_model_mem[mi];
            }
            for (pos_in_node, out) in report.outcomes.into_iter().enumerate() {
                let orig = idxs[pos_in_node];
                outcomes_by_pos[orig] = Some(out);
            }
        }
        let outcomes: Vec<QueryOutcome> =
            outcomes_by_pos.into_iter().map(|o| o.expect("outcome")).collect();

        // ④ feedback
        let mut ppo_updates = 0;
        if let Some(policy) = &mut self.policy {
            for (i, out) in outcomes.iter().enumerate() {
                let fb = out.feedback;
                if policy
                    .record(&embs[i], assignment[i], old_logps[i], fb)?
                    .is_some()
                {
                    ppo_updates += 1;
                }
            }
        }
        if let Some(base) = &mut self.baseline {
            base.observe(&embs, &assignment, &outcomes);
        }

        // aggregate
        let drop_rate =
            outcomes.iter().filter(|o| o.dropped).count() as f64 / b.max(1) as f64;
        let all_scores: Vec<QualityScores> = outcomes.iter().map(|o| o.scores).collect();
        let total_q: usize = size_queries.iter().sum();
        let total_m: f64 = size_mem.iter().sum();
        let proportions = (0..n_nodes)
            .map(|nid| per_node[nid].len() as f64 / b.max(1) as f64)
            .collect();
        Ok(SlotReport {
            queries: b,
            mean_scores: QualityScores::mean(&all_scores),
            drop_rate,
            latency_s,
            proportions,
            size_query_share: std::array::from_fn(|i| {
                if total_q == 0 { 0.0 } else { size_queries[i] as f64 / total_q as f64 }
            }),
            size_mem_share: std::array::from_fn(|i| {
                if total_m == 0.0 { 0.0 } else { size_mem[i] / total_m }
            }),
            outcomes,
            ppo_updates,
        })
    }

    /// Run `slots` slots of `queries_per_slot`, returning all reports.
    pub fn run(&mut self, slots: usize) -> Result<Vec<SlotReport>> {
        let mut reports = Vec::with_capacity(slots);
        for _ in 0..slots {
            let qids = self.sample_queries(self.cfg.queries_per_slot);
            reports.push(self.run_slot(&qids)?);
        }
        Ok(reports)
    }

    /// Mean scores over the last `k` reports (post-warmup evaluation).
    pub fn tail_mean(reports: &[SlotReport], k: usize) -> QualityScores {
        let tail: Vec<QualityScores> = reports
            .iter()
            .rev()
            .take(k)
            .map(|r| r.mean_scores)
            .collect();
        QualityScores::mean(&tail)
    }
}

/// Swap the intra-node strategy on all nodes (used by Table III benches).
impl Coordinator {
    pub fn set_intra_strategy(&mut self, s: IntraStrategy) {
        self.cfg.intra = s.clone();
        for n in self.nodes.iter_mut() {
            n.strategy = s.clone();
        }
    }
    pub fn set_slo(&mut self, slo_s: f64) {
        self.cfg.slo_s = slo_s;
    }
}
