//! The unified query-allocation interface (paper §IV-A / Table II rows).
//!
//! Every routing policy — the PPO identifier and all baselines — implements
//! [`Allocator`]: `assign` maps one slot's queries to nodes, `observe`
//! feeds the served outcomes back into the policy. The coordinator holds
//! exactly one `Box<dyn Allocator>`; it never branches on the policy kind.
//!
//! New policies plug in through [`AllocatorRegistry`]: register a factory
//! under a string key and select it with
//! [`CoordinatorBuilder::allocator_kind`](crate::coordinator::CoordinatorBuilder::allocator_kind)
//! — no coordinator changes required.

use std::collections::BTreeMap;

use crate::cluster::node::QueryOutcome;
use crate::config::{AllocatorKind, ExperimentConfig};
use crate::corpus::synth::SyntheticDataset;
use crate::policy::ppo::{Backend, OnlinePolicy, PpoConfig};
use crate::router::inter::inter_node_schedule_masked;
use crate::text::embed::EMBED_DIM;
use crate::util::rng::Rng;
use crate::Result;

/// Everything an allocator may consult when routing one slot.
pub struct SlotContext<'a> {
    /// Monotone slot counter (0-based).
    pub slot_idx: usize,
    /// QA ids of this slot's queries.
    pub qa_ids: &'a [usize],
    /// Query embeddings, one per QA id.
    pub embs: &'a [Vec<f32>],
    /// The shared dataset (domains, gold docs, …).
    pub ds: &'a SyntheticDataset,
    /// Effective per-node capacities C_n(L) for this slot's SLO. A down
    /// node's capacity is exactly 0.
    pub capacities: &'a [f64],
    /// Per-node availability (scenario NodeDown/NodeUp). A down node MUST
    /// receive no queries — `Coordinator::route` rejects assignments that
    /// touch one. The coordinator guarantees at least one live node (an
    /// all-down slot is shed before the allocator runs).
    pub active: &'a [bool],
    /// The slot latency SLO (seconds).
    pub slo_s: f64,
    /// Whether Algorithm-1 capacity-aware reassignment is enabled.
    pub inter_enabled: bool,
}

impl SlotContext<'_> {
    /// Number of nodes in the cluster.
    pub fn n_nodes(&self) -> usize {
        self.capacities.len()
    }

    /// Number of queries in the slot.
    pub fn batch(&self) -> usize {
        self.qa_ids.len()
    }

    /// Whether node `j` is live (out of range counts as down).
    pub fn is_active(&self, j: usize) -> bool {
        self.active.get(j).copied().unwrap_or(false)
    }

    /// Indices of the live nodes.
    pub fn active_nodes(&self) -> impl Iterator<Item = usize> + '_ {
        self.active.iter().enumerate().filter(|(_, &up)| up).map(|(j, _)| j)
    }
}

/// One slot's routing decision.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    /// Node index per query (`node_of.len() == batch`).
    pub node_of: Vec<usize>,
    /// Behavior log-probabilities per query (policy allocators only;
    /// empty otherwise).
    pub logps: Vec<f32>,
    /// Row-major `[batch × n_nodes]` matching probabilities `s_i^t`, when
    /// the allocator computes them (surfaced to `SlotObserver`s; empty
    /// otherwise).
    pub probs: Vec<f32>,
}

impl Assignment {
    /// An assignment from bare node choices (no policy metadata).
    pub fn from_nodes(node_of: Vec<usize>) -> Self {
        Assignment { node_of, logps: Vec::new(), probs: Vec::new() }
    }

    /// Route every query of a `batch`-sized slot to one node.
    pub fn all_to(batch: usize, node: usize) -> Self {
        Assignment::from_nodes(vec![node; batch])
    }
}

/// What `observe` learned from one slot's outcomes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeedbackStats {
    /// Outcomes consumed as learning signal.
    pub observed: usize,
    /// Parameter-update rounds triggered this slot.
    pub updates: usize,
}

/// A pluggable query-allocation policy.
///
/// `assign` is called exactly once per slot, before serving; `observe`
/// exactly once per slot, after serving, with the same context plus the
/// outcomes. Stateless allocators only need `assign`.
pub trait Allocator: Send {
    /// Short stable identifier (registry key for built-ins).
    fn name(&self) -> &str;

    /// Route each query in `ctx` to a node.
    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment>;

    /// Consume the slot's outcomes as a learning signal.
    fn observe(
        &mut self,
        ctx: &SlotContext,
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        let _ = (ctx, assignment, outcomes);
        Ok(FeedbackStats::default())
    }

    /// Stop learning (measurement sweeps freeze training progress).
    fn freeze(&mut self) {}

    /// Whether learning is permanently or currently off. The coordinator
    /// skips the feedback phase entirely for frozen allocators — no
    /// `observe` call, no [`FeedbackStats`] drift — so frozen replays of
    /// the same fixture are byte-identical across runs.
    fn is_frozen(&self) -> bool {
        false
    }
}

/// Inputs available to allocator factories at build time.
pub struct AllocatorBuildCtx<'a> {
    /// The full experiment configuration.
    pub cfg: &'a ExperimentConfig,
    /// The shared dataset (domains, gold docs, …).
    pub ds: &'a SyntheticDataset,
    /// Per QA id, the nodes holding its gold document.
    pub gold_locs: &'a [Vec<usize>],
    /// Policy-network execution backend.
    pub backend: &'a Backend,
    /// Base seed for allocator-private RNG streams.
    pub seed: u64,
}

/// Factory producing an allocator from the build context.
pub type AllocatorFactory =
    Box<dyn Fn(&AllocatorBuildCtx) -> Result<Box<dyn Allocator>> + Send + Sync>;

/// String-keyed allocator registry: built-ins plus custom registrations.
pub struct AllocatorRegistry {
    factories: BTreeMap<String, AllocatorFactory>,
}

impl Default for AllocatorRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl AllocatorRegistry {
    /// A registry with no factories at all (tests).
    pub fn empty() -> Self {
        AllocatorRegistry { factories: BTreeMap::new() }
    }

    /// A registry holding every built-in kind under its
    /// [`AllocatorKind::as_str`] key.
    pub fn with_builtins() -> Self {
        use crate::coordinator::baselines::{
            DomainAllocator, MabAllocator, OracleAllocator, RandomAllocator,
        };
        let mut r = AllocatorRegistry::empty();
        r.register(AllocatorKind::Ppo.as_str(), |ctx| {
            Ok(Box::new(PpoAllocator::from_build_ctx(ctx)))
        });
        r.register(AllocatorKind::Random.as_str(), |ctx| {
            Ok(Box::new(RandomAllocator::new(ctx.seed ^ 0xBA5E)))
        });
        r.register(AllocatorKind::Domain.as_str(), |ctx| {
            Ok(Box::new(DomainAllocator::new(ctx.cfg, ctx.ds)))
        });
        r.register(AllocatorKind::Oracle.as_str(), |ctx| {
            Ok(Box::new(OracleAllocator::new(ctx.gold_locs)))
        });
        r.register(AllocatorKind::Mab.as_str(), |ctx| {
            Ok(Box::new(MabAllocator::new(ctx.cfg.num_nodes(), ctx.seed ^ 0xBA5E)))
        });
        r.register(crate::config::PPO_PRETRAINED_KEY, |ctx| {
            let path = ctx.cfg.checkpoint.as_deref().ok_or_else(|| {
                anyhow::anyhow!(
                    "allocator {:?} needs a policy checkpoint: pass --checkpoint FILE \
                     (or TOML `checkpoint = \"...\"`)",
                    crate::config::PPO_PRETRAINED_KEY
                )
            })?;
            Ok(Box::new(crate::train::PretrainedPpoAllocator::load(
                path,
                ctx.cfg.num_nodes(),
                ctx.ds.num_domains(),
                ctx.seed ^ 0x707E,
            )?))
        });
        r
    }

    /// Register (or replace) a factory under `kind`.
    pub fn register(
        &mut self,
        kind: &str,
        factory: impl Fn(&AllocatorBuildCtx) -> Result<Box<dyn Allocator>> + Send + Sync + 'static,
    ) {
        self.factories.insert(kind.to_string(), Box::new(factory));
    }

    /// All registered kind keys, sorted.
    pub fn kinds(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Build the allocator registered under `kind`; unknown kinds error
    /// with the list of valid ones.
    pub fn build(&self, kind: &str, ctx: &AllocatorBuildCtx) -> Result<Box<dyn Allocator>> {
        match self.factories.get(kind) {
            Some(f) => f(ctx),
            None => Err(anyhow::anyhow!(
                "unknown allocator kind {kind:?}; valid kinds: {}",
                self.kinds().join(", ")
            )),
        }
    }
}

/// Build a built-in allocator directly from its [`AllocatorKind`].
pub fn from_kind(kind: AllocatorKind, ctx: &AllocatorBuildCtx) -> Result<Box<dyn Allocator>> {
    AllocatorRegistry::with_builtins().build(kind.as_str(), ctx)
}

/// The paper's allocator: PPO online query identification (§IV-A) feeding
/// Algorithm-1 inter-node scheduling, with per-outcome feedback learning.
pub struct PpoAllocator {
    /// The online PPO policy (exposed for diagnostics and benches).
    pub policy: OnlinePolicy,
    /// Private routing-noise stream (Algorithm 1 samples from `s_i^t`).
    rng: Rng,
    frozen: bool,
}

impl PpoAllocator {
    /// Build from explicit PPO configuration and execution backend.
    pub fn new(n_nodes: usize, pcfg: PpoConfig, backend: Backend, route_seed: u64) -> Self {
        PpoAllocator {
            policy: OnlinePolicy::new(n_nodes, pcfg, backend),
            rng: Rng::new(route_seed),
            frozen: false,
        }
    }

    fn from_build_ctx(ctx: &AllocatorBuildCtx) -> Self {
        let pcfg = PpoConfig {
            buffer_threshold: ctx.cfg.ppo_buffer,
            epochs: ctx.cfg.ppo_epochs,
            seed: ctx.seed ^ 0x9090,
            ..Default::default()
        };
        PpoAllocator::new(ctx.cfg.num_nodes(), pcfg, ctx.backend.clone(), ctx.seed ^ 0x707E)
    }
}

impl Allocator for PpoAllocator {
    fn name(&self) -> &str {
        AllocatorKind::Ppo.as_str()
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        let (b, n_nodes) = (ctx.batch(), ctx.n_nodes());
        let mut flat = Vec::with_capacity(b * EMBED_DIM);
        for e in ctx.embs {
            flat.extend_from_slice(e);
        }
        let mut probs = self.policy.probs(&flat, b)?;
        // Down nodes must receive no queries: zero their matching
        // probabilities s_i^t and renormalize each row over live nodes
        // (the behavior distribution PPO learns from is the masked one).
        if ctx.active.iter().any(|&up| !up) {
            for row in probs.chunks_mut(n_nodes) {
                let mut live = 0.0f32;
                for (j, p) in row.iter_mut().enumerate() {
                    if ctx.is_active(j) {
                        live += *p;
                    } else {
                        *p = 0.0;
                    }
                }
                if live > 0.0 {
                    for p in row.iter_mut() {
                        *p /= live;
                    }
                } else {
                    // the policy put all mass on down nodes: uniform over
                    // the live ones
                    let n_live = ctx.active_nodes().count().max(1);
                    for (j, p) in row.iter_mut().enumerate() {
                        *p = if ctx.is_active(j) { 1.0 / n_live as f32 } else { 0.0 };
                    }
                }
            }
        }
        if ctx.inter_enabled {
            let res = inter_node_schedule_masked(
                &probs,
                n_nodes,
                ctx.capacities,
                ctx.active,
                &mut self.rng,
            );
            // behavior logp for PPO: probability of the final node
            let logps: Vec<f32> = res
                .assignment
                .iter()
                .enumerate()
                .map(|(i, &a)| probs[i * n_nodes + a].max(1e-12).ln())
                .collect();
            Ok(Assignment { node_of: res.assignment, logps, probs })
        } else {
            // ablation: pure probability sampling, no capacity check
            let mut node_of = Vec::with_capacity(b);
            let mut logps = Vec::with_capacity(b);
            for i in 0..b {
                let row = &probs[i * n_nodes..(i + 1) * n_nodes];
                let (mut a, mut lp) = self.policy.sample_action(row);
                if !ctx.is_active(a) {
                    // numerically-degenerate sample off the masked
                    // support: take the most probable live node instead
                    let mut best = a;
                    let mut best_p = f32::NEG_INFINITY;
                    for (j, &p) in row.iter().enumerate() {
                        if ctx.is_active(j) && p > best_p {
                            best_p = p;
                            best = j;
                        }
                    }
                    a = best;
                    lp = row[a].max(1e-12).ln();
                }
                node_of.push(a);
                logps.push(lp);
            }
            Ok(Assignment { node_of, logps, probs })
        }
    }

    fn observe(
        &mut self,
        ctx: &SlotContext,
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        let mut stats = FeedbackStats::default();
        if self.frozen {
            return Ok(stats); // frozen: no buffering, no updates
        }
        if assignment.logps.len() != outcomes.len() {
            return Ok(stats); // replayed/foreign assignment: nothing to learn from
        }
        for (i, out) in outcomes.iter().enumerate() {
            if self
                .policy
                .record(&ctx.embs[i], assignment.node_of[i], assignment.logps[i], out.feedback)?
                .is_some()
            {
                stats.updates += 1;
            }
            stats.observed += 1;
        }
        Ok(stats)
    }

    fn freeze(&mut self) {
        self.frozen = true;
    }

    fn is_frozen(&self) -> bool {
        self.frozen
    }
}
