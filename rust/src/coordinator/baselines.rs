//! Baseline query allocators (paper §V-B):
//! Random, Domain (static domain→node routing), Oracle (perfect knowledge
//! of gold-document locations), and MAB (LinUCB).

use crate::bandit::LinUcb;
use crate::cluster::node::QueryOutcome;
use crate::config::{AllocatorKind, ExperimentConfig};
use crate::corpus::synth::SyntheticDataset;
use crate::util::rng::Rng;

/// A non-PPO allocator.
pub struct BaselineAllocator {
    pub kind: AllocatorKind,
    /// domain -> preferred node (for Domain allocation).
    domain_to_node: Vec<usize>,
    /// QA id -> nodes holding its gold doc (for Oracle).
    gold_locs: Vec<Vec<usize>>,
    mab: Option<LinUcb>,
    n_nodes: usize,
}

impl BaselineAllocator {
    pub fn new(
        kind: AllocatorKind,
        cfg: &ExperimentConfig,
        gold_locs: &[Vec<usize>],
        seed: u64,
    ) -> Self {
        // Domain routing table: a domain goes to the first node listing it
        // as primary (ties broken by order, like a static registry).
        let nd = 6;
        let mut domain_to_node = vec![0usize; nd];
        for d in 0..nd {
            domain_to_node[d] = cfg
                .nodes
                .iter()
                .position(|n| n.primary_domains.contains(&d))
                .unwrap_or(d % cfg.nodes.len());
        }
        let mab = if kind == AllocatorKind::Mab {
            Some(LinUcb::new(cfg.num_nodes(), 0.6, seed))
        } else {
            None
        };
        BaselineAllocator {
            kind,
            domain_to_node,
            gold_locs: gold_locs.to_vec(),
            mab,
            n_nodes: cfg.num_nodes(),
        }
    }

    /// Assign each query to a node.
    pub fn assign(
        &mut self,
        ds: &SyntheticDataset,
        qa_ids: &[usize],
        embs: &[Vec<f32>],
        capacities: &[f64],
        capacity_aware: bool,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_nodes];
        // overload scaling as in Algorithm 1 for fairness
        let total_cap: f64 = capacities.iter().sum();
        let caps: Vec<f64> = if (qa_ids.len() as f64) > total_cap && total_cap > 0.0 {
            let excess = qa_ids.len() as f64 - total_cap;
            capacities.iter().map(|&c| c + c / total_cap * excess).collect()
        } else if total_cap <= 0.0 {
            vec![f64::INFINITY; self.n_nodes]
        } else {
            capacities.to_vec()
        };
        qa_ids
            .iter()
            .enumerate()
            .map(|(i, &q)| {
                let prefer = match self.kind {
                    AllocatorKind::Random => rng.below(self.n_nodes),
                    AllocatorKind::Domain => self.domain_to_node[ds.qa_pairs[q].domain],
                    AllocatorKind::Oracle => {
                        // least-loaded node (relative to capacity) holding
                        // the gold doc; falls back to global least-loaded
                        let locs = &self.gold_locs[q];
                        let pick_least = |cands: &[usize], counts: &[usize]| {
                            *cands
                                .iter()
                                .min_by(|&&a, &&b| {
                                    let la = counts[a] as f64 / caps[a].max(1.0);
                                    let lb = counts[b] as f64 / caps[b].max(1.0);
                                    la.partial_cmp(&lb).unwrap()
                                })
                                .unwrap()
                        };
                        if locs.is_empty() {
                            let all: Vec<usize> = (0..self.n_nodes).collect();
                            pick_least(&all, &counts)
                        } else {
                            pick_least(locs, &counts)
                        }
                    }
                    AllocatorKind::Mab => self.mab.as_ref().unwrap().choose(&embs[i]),
                    AllocatorKind::Ppo => unreachable!(),
                };
                let a = if capacity_aware && (counts[prefer] as f64) >= caps[prefer] {
                    // spill to the least-loaded node with residual capacity
                    (0..self.n_nodes)
                        .filter(|&j| (counts[j] as f64) < caps[j])
                        .min_by(|&a, &b| {
                            let la = counts[a] as f64 / caps[a].max(1.0);
                            let lb = counts[b] as f64 / caps[b].max(1.0);
                            la.partial_cmp(&lb).unwrap()
                        })
                        .unwrap_or(prefer)
                } else {
                    prefer
                };
                counts[a] += 1;
                a
            })
            .collect()
    }

    /// Post-slot learning signal (MAB only).
    pub fn observe(&mut self, embs: &[Vec<f32>], assignment: &[usize], outcomes: &[QueryOutcome]) {
        if let Some(mab) = &mut self.mab {
            for ((emb, &a), out) in embs.iter().zip(assignment).zip(outcomes) {
                mab.update(emb, a, out.feedback);
            }
        }
    }
}
