//! Baseline query allocators (paper §V-B): [`RandomAllocator`],
//! [`DomainAllocator`] (static domain→node routing), [`OracleAllocator`]
//! (perfect knowledge of gold-document locations), and [`MabAllocator`]
//! (LinUCB). All implement [`Allocator`], so they are interchangeable with
//! the PPO identifier at the coordinator.

use crate::bandit::LinUcb;
use crate::cluster::node::QueryOutcome;
use crate::config::{AllocatorKind, ExperimentConfig};
use crate::coordinator::allocator::{Allocator, Assignment, FeedbackStats, SlotContext};
use crate::corpus::synth::SyntheticDataset;
use crate::util::rng::Rng;
use crate::Result;

/// Overload scaling as in Algorithm 1 lines 5–8, for fairness with the
/// capacity-aware PPO path. Down nodes are pinned to capacity 0 — even in
/// the degenerate no-capacity case only live nodes open up.
fn effective_caps(batch: usize, capacities: &[f64], active: &[bool]) -> Vec<f64> {
    let caps: Vec<f64> = capacities
        .iter()
        .zip(active)
        .map(|(&c, &up)| if up { c } else { 0.0 })
        .collect();
    let total_cap: f64 = caps.iter().sum();
    if (batch as f64) > total_cap && total_cap > 0.0 {
        let excess = batch as f64 - total_cap;
        caps.iter().map(|&c| c + c / total_cap * excess).collect()
    } else if total_cap <= 0.0 {
        active.iter().map(|&up| if up { f64::INFINITY } else { 0.0 }).collect()
    } else {
        caps
    }
}

/// Least-loaded node (relative to capacity) among `cands`.
fn least_loaded(cands: impl Iterator<Item = usize>, counts: &[usize], caps: &[f64]) -> Option<usize> {
    cands.min_by(|&a, &b| {
        let la = counts[a] as f64 / caps[a].max(1.0);
        let lb = counts[b] as f64 / caps[b].max(1.0);
        la.partial_cmp(&lb).unwrap()
    })
}

/// Shared assignment loop: each query names a preferred node via
/// `prefer(query_pos, qa_id, counts, caps)`. A down preference is always
/// diverted to the least-loaded live node (capacity-aware or not — down
/// nodes never receive queries); when capacity-aware routing is on and
/// the preference is saturated, the query spills to the least-loaded live
/// node with residual capacity.
fn assign_with_spill(
    ctx: &SlotContext,
    mut prefer: impl FnMut(usize, usize, &[usize], &[f64]) -> usize,
) -> Assignment {
    let n_nodes = ctx.n_nodes();
    let caps = effective_caps(ctx.batch(), ctx.capacities, ctx.active);
    let mut counts = vec![0usize; n_nodes];
    let node_of = ctx
        .qa_ids
        .iter()
        .enumerate()
        .map(|(i, &q)| {
            let mut p = prefer(i, q, &counts, &caps);
            if !ctx.is_active(p) {
                p = least_loaded(ctx.active_nodes(), &counts, &caps).unwrap_or(p);
            }
            let a = if ctx.inter_enabled && (counts[p] as f64) >= caps[p] {
                least_loaded(
                    ctx.active_nodes().filter(|&j| (counts[j] as f64) < caps[j]),
                    &counts,
                    &caps,
                )
                .unwrap_or(p)
            } else {
                p
            };
            counts[a] += 1;
            a
        })
        .collect();
    Assignment::from_nodes(node_of)
}

/// Uniform-random routing.
pub struct RandomAllocator {
    rng: Rng,
}

impl RandomAllocator {
    /// Seeded uniform-random router.
    pub fn new(seed: u64) -> Self {
        RandomAllocator { rng: Rng::new(seed) }
    }
}

impl Allocator for RandomAllocator {
    fn name(&self) -> &str {
        AllocatorKind::Random.as_str()
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        let n = ctx.n_nodes();
        let rng = &mut self.rng;
        Ok(assign_with_spill(ctx, |_, _, _, _| rng.below(n)))
    }
}

/// Static domain→node routing: a domain goes to the first node listing it
/// as primary (ties broken by order, like a static registry).
pub struct DomainAllocator {
    domain_to_node: Vec<usize>,
}

impl DomainAllocator {
    /// The domain count comes from the dataset, so routing works for any
    /// corpus, not just the paper's 6-domain testbed.
    pub fn new(cfg: &ExperimentConfig, ds: &SyntheticDataset) -> Self {
        let nd = ds.num_domains();
        let domain_to_node = (0..nd)
            .map(|d| {
                cfg.nodes
                    .iter()
                    .position(|n| n.primary_domains.contains(&d))
                    .unwrap_or(d % cfg.nodes.len())
            })
            .collect();
        DomainAllocator { domain_to_node }
    }
}

impl Allocator for DomainAllocator {
    fn name(&self) -> &str {
        AllocatorKind::Domain.as_str()
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        let table = &self.domain_to_node;
        Ok(assign_with_spill(ctx, |_, q, _, _| table[ctx.ds.qa_pairs[q].domain]))
    }
}

/// Perfect-knowledge routing: the least-loaded node holding the query's
/// gold document, falling back to the global least-loaded node.
pub struct OracleAllocator {
    /// QA id -> nodes holding its gold doc.
    gold_locs: Vec<Vec<usize>>,
}

impl OracleAllocator {
    /// Snapshot the per-QA gold-document locations.
    pub fn new(gold_locs: &[Vec<usize>]) -> Self {
        OracleAllocator { gold_locs: gold_locs.to_vec() }
    }
}

impl Allocator for OracleAllocator {
    fn name(&self) -> &str {
        AllocatorKind::Oracle.as_str()
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        let n_nodes = ctx.n_nodes();
        let gold = &self.gold_locs;
        Ok(assign_with_spill(ctx, |_, q, counts, caps| {
            // prefer a *live* gold-holder (a down replica would otherwise
            // always win least-loaded at load 0 and forfeit the gold doc
            // to an arbitrary divert); fall back to the overall
            // least-loaded node when no live replica exists
            let locs = &gold[q];
            least_loaded(locs.iter().copied().filter(|&j| ctx.is_active(j)), counts, caps)
                .or_else(|| least_loaded(0..n_nodes, counts, caps))
                .unwrap()
        }))
    }
}

/// LinUCB contextual bandit over query embeddings.
pub struct MabAllocator {
    mab: LinUcb,
    frozen: bool,
}

impl MabAllocator {
    /// Seeded LinUCB bandit over `n_nodes` arms.
    pub fn new(n_nodes: usize, seed: u64) -> Self {
        MabAllocator { mab: LinUcb::new(n_nodes, 0.6, seed), frozen: false }
    }
}

impl Allocator for MabAllocator {
    fn name(&self) -> &str {
        AllocatorKind::Mab.as_str()
    }

    fn assign(&mut self, ctx: &SlotContext) -> Result<Assignment> {
        let mab = &self.mab;
        Ok(assign_with_spill(ctx, |i, _, _, _| mab.choose(&ctx.embs[i])))
    }

    fn observe(
        &mut self,
        ctx: &SlotContext,
        assignment: &Assignment,
        outcomes: &[QueryOutcome],
    ) -> Result<FeedbackStats> {
        let mut stats = FeedbackStats::default();
        if self.frozen {
            return Ok(stats);
        }
        for ((emb, &a), out) in ctx.embs.iter().zip(&assignment.node_of).zip(outcomes) {
            self.mab.update(emb, a, out.feedback);
            stats.observed += 1;
        }
        stats.updates = usize::from(stats.observed > 0);
        Ok(stats)
    }

    fn freeze(&mut self) {
        self.frozen = true;
    }

    fn is_frozen(&self) -> bool {
        self.frozen
    }
}
