//! Structured per-slot events (paper Fig. 4 phases) for live metrics.
//!
//! The coordinator emits one event after each of the four `run_slot`
//! phases — encode, route, serve, feedback — plus a closing `SlotEnd`
//! carrying the aggregated [`SlotReport`]. Attach a [`SlotObserver`] via
//! [`CoordinatorBuilder::observer`](crate::coordinator::CoordinatorBuilder::observer)
//! (or [`Coordinator::set_observer`](crate::coordinator::Coordinator::set_observer))
//! to stream metrics instead of scraping reports after the fact; the
//! bench harness's `PhaseBreakdown` and the serving front-end both do.

use crate::cluster::node::QueryOutcome;
use crate::coordinator::allocator::{Assignment, FeedbackStats};
use crate::coordinator::SlotReport;

/// One coordinator lifecycle event. All payloads borrow from the running
/// slot; copy out whatever must outlive the callback.
#[derive(Debug)]
pub enum SlotEvent<'a> {
    /// Phase ① done: queries embedded.
    Encoded { slot: usize, queries: usize, elapsed_s: f64 },
    /// Identification + inter-node routing done. `assignment.probs`
    /// carries the matching probabilities `s_i^t` when the allocator
    /// computes them.
    Routed { slot: usize, assignment: &'a Assignment, elapsed_s: f64 },
    /// Phases ②③ done: retrieval + generation at every node.
    Served { slot: usize, outcomes: &'a [QueryOutcome], makespan_s: f64, elapsed_s: f64 },
    /// Phase ④ done: outcomes fed back into the allocator.
    Feedback { slot: usize, stats: FeedbackStats, elapsed_s: f64 },
    /// Slot fully aggregated.
    SlotEnd { slot: usize, report: &'a SlotReport },
}

/// Receiver for [`SlotEvent`]s. Runs synchronously on the coordinator's
/// thread — keep callbacks cheap (counters, channels).
pub trait SlotObserver: Send {
    /// Called after every phase of every slot, in phase order.
    fn on_event(&mut self, event: &SlotEvent);
}

/// Forward events to a closure (the smallest possible observer).
pub struct FnObserver<F: FnMut(&SlotEvent) + Send>(
    /// The wrapped callback.
    pub F,
);

impl<F: FnMut(&SlotEvent) + Send> SlotObserver for FnObserver<F> {
    fn on_event(&mut self, event: &SlotEvent) {
        (self.0)(event)
    }
}
