//! An edge node: local corpus, vector index, GPUs, model pool, fitted
//! latency surrogates, static quality scores, and the per-slot serving
//! path (retrieve → generate → score), including drop accounting.

use std::collections::BTreeMap;

use crate::cache::{
    embedding_guard, quantize_embedding, CacheBuildCtx, CacheEntry, CachePayload, CacheRegistry,
    EntryTag, QueryCache,
};
use crate::config::{IntraStrategy, NodeConfig};
use crate::corpus::synth::SyntheticDataset;
use crate::intranode::latfit::{LatencyFit, LatencyProfiler};
use crate::intranode::quality::quality_table;
use crate::intranode::solver::{solve_node, NodePlan, SolverInput};
use crate::llmsim::gen::generate;
use crate::llmsim::gpu::GpuState;
use crate::llmsim::latency::{LatencyGroundTruth, SearchTimeModel};
use crate::llmsim::model::{pool_of, ModelSpec};
use crate::metrics::{Evaluator, QualityScores};
use crate::text::embed::{cosine, Embedder};
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use crate::vecdb::{
    Hit, IndexBuildCtx, IndexKind, IndexMigration, IndexRegistry, IndexSpec, VectorIndex,
};
use crate::Result;
use std::sync::Arc;

/// Per-query serving outcome.
#[derive(Clone, Debug)]
pub struct QueryOutcome {
    pub qa_id: usize,
    /// Serving node. `usize::MAX` means "never routed": the coordinator
    /// shed the query because every node was down (always `dropped`).
    pub node: usize,
    /// Model size label index into the node pool; None if dropped before
    /// being served.
    pub model_idx: Option<usize>,
    pub dropped: bool,
    /// Retrieval relevance achieved.
    pub rel: f64,
    /// Quality metrics (zeros when dropped — "invalid" per the paper).
    pub scores: QualityScores,
    /// Composite feedback f_i (Eq. 9); 0 when dropped.
    pub feedback: f64,
    /// Simulated completion latency (s, within the slot).
    pub latency_s: f64,
    /// Served from the cluster answer cache — the query never reached a
    /// node this slot; `node`/`scores` are the original serve's.
    pub cached: bool,
}

/// Slot-level summary for one node.
#[derive(Clone, Debug, Default)]
pub struct NodeSlotReport {
    pub outcomes: Vec<QueryOutcome>,
    /// TS_n^t — *modeled* vector search time (drives the slot budget and
    /// makespan, keeping simulations deterministic).
    pub search_time_s: f64,
    /// Measured wall-clock of the slot's batched index search, recorded
    /// alongside the model so the solver can be driven by either (e.g. via
    /// `SearchTimeModel::calibrate`).
    pub measured_search_s: f64,
    /// Max model completion time incl. reloads (Eq. 4 LHS).
    pub makespan_s: f64,
    /// Queries per model idx.
    pub per_model_queries: Vec<usize>,
    /// Memory fraction per model idx (summed over GPUs).
    pub per_model_mem: Vec<f64>,
    /// Retrieval-cache hits this slot (index search skipped).
    pub cache_hits: usize,
    /// Retrieval-cache misses this slot (searched, then inserted).
    pub cache_misses: usize,
    /// Entries evicted from the retrieval cache this slot.
    pub cache_evictions: usize,
    /// In-flight reindex migration state (`from->to:slots_remaining`);
    /// `None` when no migration is building. Stamped at serve time, so
    /// the slot that swaps still shows the old index serving with `:1`.
    pub migration: Option<String>,
}

/// An edge node.
pub struct EdgeNode {
    pub id: usize,
    pub name: String,
    /// Sorted doc ids stored locally.
    pub doc_ids: Vec<usize>,
    /// Pluggable retrieval index (kind chosen per node via
    /// `NodeConfig.index`; exact flat by default).
    pub index: Box<dyn VectorIndex>,
    /// Registry key the index was built from (diagnostics / CLI tables).
    pub index_kind: String,
    /// The index parameterization currently serving (updated at reindex
    /// swap so chained migrations inherit the latest overrides).
    index_spec: IndexSpec,
    /// Deterministic index-build seed (`node seed ^ 0x1D5EED`) — reused by
    /// reindex migrations so a same-kind rebuild reproduces the serving
    /// index bit-for-bit.
    build_seed: u64,
    /// In-flight reindex migration, if any (old index keeps serving).
    migration: Option<IndexMigration>,
    /// Per-node retrieval cache (quantized-query-embedding key → top-k
    /// hits). `NoneCache` by default — zero overhead, zero behavior drift.
    pub cache: Box<dyn QueryCache>,
    /// Registry key the cache was built from.
    pub cache_kind: String,
    /// Whether the cache participates in the serve path at all (false for
    /// the `none` kind — keeps the pre-cache hot path byte-identical).
    cache_active: bool,
    /// Modeled node memory (bytes) the cache footprint is charged against
    /// when computing the solver's generation-memory cap.
    node_mem_bytes: usize,
    pub pool: Vec<ModelSpec>,
    pub gpus: Vec<GpuState>,
    /// Ground-truth latency per GPU (the "hardware").
    pub gts: Vec<LatencyGroundTruth>,
    /// Fitted surrogate per (model idx, gpu idx).
    pub fits: Vec<Vec<LatencyFit>>,
    /// Static open-book quality Q_mn per model idx.
    pub quality: Vec<f64>,
    pub search_model: SearchTimeModel,
    pub strategy: IntraStrategy,
    pub top_k: usize,
    /// Shared cache of document embeddings (indexed by doc id), built once
    /// by the coordinator.
    pub doc_embs: Arc<Vec<Vec<f32>>>,
    rng: Rng,
}

impl EdgeNode {
    /// Build a node: embed + index its corpus (index kind from
    /// `cfg.index` through `registry`), profile latency surrogates,
    /// compute Q_mn from local QA pairs ("node-specific data").
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        id: usize,
        cfg: &NodeConfig,
        ds: &SyntheticDataset,
        doc_ids: Vec<usize>,
        doc_embs: Arc<Vec<Vec<f32>>>,
        ev: &Evaluator,
        strategy: IntraStrategy,
        top_k: usize,
        seed: u64,
        registry: &IndexRegistry,
        cache_registry: &CacheRegistry,
    ) -> Result<Self> {
        let ctx = IndexBuildCtx {
            dim: crate::text::embed::EMBED_DIM,
            seed: seed ^ 0x1D5EED,
            spec: &cfg.index,
        };
        let mut index = registry.build(&cfg.index.kind, &ctx)?;
        let cache = cache_registry.build(&cfg.cache.kind, &CacheBuildCtx { spec: &cfg.cache })?;
        for &d in &doc_ids {
            index.add(d, &doc_embs[d]);
        }
        index.finalize(seed ^ 0x1D5EED);
        let pool = pool_of(&cfg.pool);
        let gpus: Vec<GpuState> = cfg.gpu_speeds.iter().map(|&s| GpuState::new(s)).collect();
        let gts: Vec<LatencyGroundTruth> =
            cfg.gpu_speeds.iter().map(|&s| LatencyGroundTruth::new(s)).collect();
        let prof = LatencyProfiler::default();
        let mut rng = Rng::new(seed ^ (id as u64).wrapping_mul(0x1234567));
        let fits: Vec<Vec<LatencyFit>> = pool
            .iter()
            .map(|m| {
                gts.iter()
                    .map(|gt| {
                        let s = rng.next_u64();
                        let mut prng = Rng::new(s);
                        let samples = prof.collect(gt, m, &mut prng);
                        prof.fit(crate::intranode::latfit::FitFamily::Quadratic, &samples)
                            .expect("quadratic fit")
                    })
                    .collect()
            })
            .collect();
        // Q_mn from QA pairs whose gold doc is local (node-specific data).
        let local: std::collections::HashSet<usize> = doc_ids.iter().copied().collect();
        let qa_sample: Vec<usize> = ds
            .qa_pairs
            .iter()
            .filter(|qa| local.contains(&qa.gold_doc))
            .map(|qa| qa.id)
            .take(60)
            .collect();
        let quality = quality_table(ds, &qa_sample, &pool, ev, seed ^ 0xAB5);
        Ok(EdgeNode {
            id,
            name: cfg.name.clone(),
            doc_ids,
            index,
            index_kind: cfg.index.kind.clone(),
            index_spec: cfg.index.clone(),
            build_seed: seed ^ 0x1D5EED,
            migration: None,
            cache,
            cache_kind: cfg.cache.kind.clone(),
            cache_active: cfg.cache.enabled(),
            node_mem_bytes: cfg.cache.node_mem_bytes(),
            pool,
            gpus,
            gts,
            fits,
            quality,
            search_model: SearchTimeModel::default(),
            strategy,
            top_k,
            doc_embs,
            rng,
        })
    }

    /// Corpus size in chunks.
    pub fn corpus_size(&self) -> usize {
        self.doc_ids.len()
    }

    /// Live corpus update (scenario CorpusIngest): add documents to the
    /// *running* index via `VectorIndex::add` — no rebuild, no
    /// re-finalize. Post-train IVF routes new vectors online to the
    /// nearest centroid and HNSW builds incrementally, so the documents
    /// are searchable in the very next slot. Callers pass ids not yet
    /// held by this node (the coordinator filters duplicates).
    pub fn ingest_docs(&mut self, doc_ids: &[usize]) {
        for &d in doc_ids {
            self.index.add(d, &self.doc_embs[d]);
            self.doc_ids.push(d);
        }
        self.doc_ids.sort_unstable();
        // mid-migration adds also go to the write-log so the new index
        // picks them up before the swap — searchable now in the old
        // index, present in the new one from its first serving slot
        if let Some(m) = &mut self.migration {
            m.log_ingest(doc_ids);
        }
    }

    /// Start a live reindex migration toward `to` (scenario `reindex`
    /// event): snapshot the corpus, kick off the background build, and
    /// keep serving from the current index. `build_slots` is the modeled
    /// swap countdown (see [`crate::vecdb::modeled_build_slots`]). A
    /// second reindex while one is in flight *replaces* it — the
    /// abandoned build's worker joins on drop and its write-log is
    /// discarded (the fresh snapshot already contains those rows).
    pub fn begin_reindex(
        &mut self,
        to: IndexKind,
        shards: Option<usize>,
        rescore_factor: Option<usize>,
        registry: Arc<IndexRegistry>,
        build_slots: usize,
    ) {
        let mut spec = IndexSpec { kind: to.as_str().into(), ..self.index_spec.clone() };
        if let Some(s) = shards {
            spec.shards = s;
        }
        if let Some(rf) = rescore_factor {
            spec.rescore_factor = rf;
        }
        self.migration = Some(IndexMigration::start(
            registry,
            spec,
            to,
            &self.index_kind,
            crate::text::embed::EMBED_DIM,
            self.build_seed,
            self.doc_ids.clone(),
            Arc::clone(&self.doc_embs),
            build_slots,
        ));
    }

    /// Whether a reindex migration is in flight.
    pub fn migrating(&self) -> bool {
        self.migration.is_some()
    }

    /// Transcript label of the in-flight migration, if any.
    pub fn migration_label(&self) -> Option<String> {
        self.migration.as_ref().map(|m| m.label())
    }

    /// Advance the migration countdown by one slot boundary (coordinator
    /// calls this after every slot's report is recorded). When the
    /// countdown reaches zero: await the background build, drain the
    /// write-log into it, and atomically swap the serving index. Returns
    /// `true` iff the swap happened at this boundary — the caller must
    /// then flush retrieval/answer caches for this node (a different
    /// index may rank ties differently).
    pub fn tick_migration(&mut self) -> Result<bool> {
        match &mut self.migration {
            Some(m) if m.tick() => {}
            _ => return Ok(false),
        }
        let mig = self.migration.take().expect("migration checked above");
        let to = mig.target();
        let spec = mig.spec().clone();
        self.index = mig.finish(&self.doc_embs)?;
        self.index_kind = to.as_str().to_string();
        self.index_spec = spec;
        Ok(true)
    }

    /// Fraction of GPU memory left for generation models after charging
    /// the retrieval cache's modeled footprint against the node's memory
    /// budget (§IV-C widened: cache competes with generation memory).
    /// Exactly 1.0 whenever the cache is off or empty.
    pub fn gen_mem_cap(&self) -> f64 {
        if self.node_mem_bytes == 0 {
            return 1.0;
        }
        (1.0 - self.cache.bytes() as f64 / self.node_mem_bytes as f64).clamp(0.0, 1.0)
    }

    /// Flush the retrieval cache (corpus changed: any cached top-k may now
    /// be wrong — new vectors can enter *any* query's top-k, so the whole
    /// node cache is conservatively dropped). Returns entries dropped.
    pub fn invalidate_cache(&mut self) -> usize {
        self.cache.clear()
    }

    /// Compute the slot plan for `n_queries` within `budget_s`
    /// (Solver strategy runs Eq. 25–29; Fixed splits evenly).
    pub fn plan_slot(&self, n_queries: usize, budget_s: f64) -> NodePlan {
        match &self.strategy {
            IntraStrategy::Solver => solve_node(&SolverInput {
                pool: &self.pool,
                gpus: &self.gpus,
                fits: &self.fits,
                quality: &self.quality,
                queries: n_queries,
                budget_s,
                mem_cap: self.gen_mem_cap(),
            }),
            IntraStrategy::Fixed(plans) => self.fixed_plan(plans, n_queries, budget_s),
        }
    }

    fn fixed_plan(
        &self,
        plans: &[Vec<(crate::llmsim::model::ModelSize, f64)>],
        n_queries: usize,
        budget_s: f64,
    ) -> NodePlan {
        use crate::intranode::solver::{GpuPlan, ModelAssignment};
        // resolve (size -> pool idx), count deployed slots
        let mut slots: Vec<(usize, usize, f64)> = Vec::new(); // (gpu, model_idx, mem)
        for (k, plan) in plans.iter().enumerate().take(self.gpus.len()) {
            for &(size, mem) in plan {
                if let Some(mi) = self.pool.iter().position(|m| m.size == size) {
                    slots.push((k, mi, mem));
                }
            }
        }
        let per = if slots.is_empty() { 0 } else { n_queries / slots.len() };
        let mut rem = n_queries.saturating_sub(per * slots.len());
        let mut gpus: Vec<GpuPlan> = (0..self.gpus.len()).map(|_| GpuPlan::default()).collect();
        for &(k, mi, mem) in &slots {
            let extra = if rem > 0 {
                rem -= 1;
                1
            } else {
                0
            };
            gpus[k].assignments.push(ModelAssignment {
                model_idx: mi,
                mem,
                queries: per + extra,
            });
        }
        // reload accounting for fixed plans too
        for (k, g) in gpus.iter_mut().enumerate() {
            let target: BTreeMap<String, f64> = g
                .assignments
                .iter()
                .map(|a| (self.pool[a.model_idx].name.clone(), a.mem))
                .collect();
            g.reload_s = self.gpus[k].reconfig_time(&target, &|name| {
                self.pool
                    .iter()
                    .find(|m| m.name == name)
                    .map(|m| m.load_time_s)
                    .unwrap_or(0.0)
            });
        }
        let _ = budget_s;
        NodePlan { gpus, objective: 0.0, overflow: 0 }
    }

    /// Latency/drop-only dry run (used by capacity profiling). Returns the
    /// drop rate for `n_queries` within SLO `l_s`.
    pub fn dry_run_drop_rate(&self, n_queries: usize, l_s: f64) -> f64 {
        if n_queries == 0 {
            return 0.0;
        }
        let ts = self.search_model.search_time(n_queries, self.corpus_size());
        let budget = l_s - ts;
        if budget <= 0.0 {
            return 1.0;
        }
        let plan = self.plan_slot(n_queries, budget);
        let mut dropped = plan.overflow;
        let mut served_counted = 0usize;
        for (k, g) in plan.gpus.iter().enumerate() {
            for a in &g.assignments {
                if a.queries == 0 {
                    continue;
                }
                served_counted += a.queries;
                let m = &self.pool[a.model_idx];
                let lat = self.gts[k].latency(m, a.queries as f64, a.mem);
                let total = g.reload_s + lat;
                if total > budget {
                    // queries complete uniformly across the batch; the tail
                    // beyond the budget is dropped
                    let frac_ok = ((budget - g.reload_s).max(0.0) / lat).min(1.0);
                    dropped += a.queries - (a.queries as f64 * frac_ok).floor() as usize;
                }
            }
        }
        let total = served_counted + plan.overflow;
        if total == 0 {
            return 1.0;
        }
        dropped as f64 / total as f64
    }

    /// Serve one slot: the full retrieve → generate → score path.
    ///
    /// `queries` are QA ids routed to this node; `slo_s` is L^t.
    pub fn serve_slot(
        &mut self,
        ds: &SyntheticDataset,
        ev: &Evaluator,
        embedder: &Embedder,
        query_embs: Option<&[Vec<f32>]>,
        queries: &[usize],
        slo_s: f64,
    ) -> NodeSlotReport {
        let n = queries.len();
        let mut report = NodeSlotReport {
            per_model_queries: vec![0; self.pool.len()],
            per_model_mem: vec![0.0; self.pool.len()],
            migration: self.migration_label(),
            ..Default::default()
        };
        if n == 0 {
            return report;
        }

        // resolve embeddings up front (the coordinator always passes them;
        // the retrieval cache keys on them)
        let emb_storage: Vec<Vec<f32>>;
        let embs: &[Vec<f32>] = match query_embs {
            Some(embs) => embs,
            None => {
                emb_storage = queries
                    .iter()
                    .map(|&q| embedder.embed(&ds.qa_pairs[q].query))
                    .collect();
                &emb_storage
            }
        };

        // retrieval-cache lookups (cache off ⇒ every query misses, no
        // calls): hits skip the index search AND shrink the modeled
        // TS_n^t below — cached retrieval buys back latency budget. A key
        // hit whose full-precision guard differs (quantization collision)
        // is treated as a miss, never served.
        let mut hits_by_pos: Vec<Option<Vec<Hit>>> = vec![None; n];
        let mut keys: Vec<Vec<i8>> = Vec::new();
        let mut guards: Vec<u64> = Vec::new();
        let miss_pos: Vec<usize> = if !self.cache_active {
            (0..n).collect()
        } else {
            keys = embs.iter().map(|e| quantize_embedding(e)).collect();
            guards = embs.iter().map(|e| embedding_guard(e)).collect();
            let mut misses = Vec::new();
            for (i, key) in keys.iter().enumerate() {
                match self.cache.get(key) {
                    Some(CacheEntry { guard, payload: CachePayload::Hits(h), .. })
                        if guard == guards[i] =>
                    {
                        report.cache_hits += 1;
                        hits_by_pos[i] = Some(h);
                    }
                    _ => {
                        report.cache_misses += 1;
                        misses.push(i);
                    }
                }
            }
            misses
        };

        // modeled search time TS_n^t covers only the queries actually
        // searched (== all of them whenever the cache is off)
        let ts = self.search_model.search_time(miss_pos.len(), self.corpus_size());
        let budget = slo_s - ts;
        report.search_time_s = ts;
        if budget <= 0.0 {
            // everything is dropped before inference — skip the search
            // entirely (measured_search_s stays 0: no search ran)
            for &q in queries {
                report.outcomes.push(QueryOutcome {
                    qa_id: q,
                    node: self.id,
                    model_idx: None,
                    dropped: true,
                    rel: 0.0,
                    scores: QualityScores::zeros(),
                    feedback: 0.0,
                    latency_s: slo_s,
                    cached: false,
                });
            }
            return report;
        }

        // one batched search per slot over the cache misses, results
        // stitched back in query order (cache off: all queries, the
        // pre-cache hot path bit for bit)
        let searched: Vec<Vec<Hit>> = if miss_pos.len() == n {
            let timer = Timer::start();
            let hits = self.index.search_batch(embs, self.top_k);
            report.measured_search_s = timer.secs();
            hits
        } else {
            let miss_embs: Vec<Vec<f32>> = miss_pos.iter().map(|&i| embs[i].clone()).collect();
            let timer = Timer::start();
            let hits = self.index.search_batch(&miss_embs, self.top_k);
            report.measured_search_s = timer.secs();
            hits
        };
        for (&i, found) in miss_pos.iter().zip(searched) {
            if self.cache_active {
                let qa = &ds.qa_pairs[queries[i]];
                report.cache_evictions += self.cache.insert(
                    keys[i].clone(),
                    CacheEntry {
                        tag: EntryTag { node: self.id, domain: qa.domain },
                        guard: guards[i],
                        payload: CachePayload::Hits(found.clone()),
                    },
                );
            }
            hits_by_pos[i] = Some(found);
        }
        let slot_hits: Vec<Vec<Hit>> =
            hits_by_pos.into_iter().map(|h| h.expect("hit or searched")).collect();

        let plan = self.plan_slot(n, budget);
        // apply deployments
        let targets = plan.target_maps(&self.pool);
        for (gpu, target) in self.gpus.iter_mut().zip(targets) {
            gpu.apply(target);
        }
        for g in &plan.gpus {
            for a in &g.assignments {
                report.per_model_queries[a.model_idx] += a.queries;
                report.per_model_mem[a.model_idx] += a.mem;
            }
        }

        // assign query list positions to (gpu, assignment) in plan order
        let mut cursor = 0usize;
        for (k, g) in plan.gpus.iter().enumerate() {
            for a in &g.assignments {
                if a.queries == 0 {
                    continue;
                }
                let m = &self.pool[a.model_idx];
                let lat = self.gts[k].measure(m, a.queries as f64, a.mem, &mut self.rng);
                let makespan = g.reload_s + lat;
                report.makespan_s = report.makespan_s.max(makespan + ts);
                let take = a.queries.min(n - cursor);
                for j in 0..take {
                    let qa_id = queries[cursor + j];
                    let qa = &ds.qa_pairs[qa_id];
                    // completion of the j-th query in this batch
                    let done = g.reload_s + lat * (j + 1) as f64 / a.queries as f64;
                    if done > budget {
                        report.outcomes.push(QueryOutcome {
                            qa_id,
                            node: self.id,
                            model_idx: Some(a.model_idx),
                            dropped: true,
                            rel: 0.0,
                            scores: QualityScores::zeros(),
                            feedback: 0.0,
                            latency_s: slo_s,
                            cached: false,
                        });
                        continue;
                    }
                    // retrieval result from the slot's batched search
                    let rel = self.relevance_from_hits(&slot_hits[cursor + j], qa.gold_doc);
                    let mut qrng = self.rng.fork(qa_id as u64);
                    let gen = generate(ds, qa, m, rel, &mut qrng);
                    let scores = ev.score_tokens(&gen, &qa.answer_tokens);
                    let feedback = ev.feedback(&gen, &qa.answer_tokens, 1.0, 0.5);
                    report.outcomes.push(QueryOutcome {
                        qa_id,
                        node: self.id,
                        model_idx: Some(a.model_idx),
                        dropped: false,
                        rel,
                        scores,
                        feedback,
                        latency_s: ts + done,
                        cached: false,
                    });
                }
                cursor += take;
            }
        }
        // overflow beyond plan capacity: dropped
        while cursor < n {
            report.outcomes.push(QueryOutcome {
                qa_id: queries[cursor],
                node: self.id,
                model_idx: None,
                dropped: true,
                rel: 0.0,
                scores: QualityScores::zeros(),
                feedback: 0.0,
                latency_s: slo_s,
                cached: false,
            });
            cursor += 1;
        }
        report
    }

    /// Top-k retrieval relevance for a query embedding against the gold
    /// document (convenience wrapper issuing a single search; the serve
    /// path batches instead and scores via
    /// [`relevance_from_hits`](Self::relevance_from_hits)).
    pub fn retrieval_relevance(&self, query_emb: &[f32], gold_doc: usize) -> f64 {
        self.relevance_from_hits(&self.index.search(query_emb, self.top_k), gold_doc)
    }

    /// Relevance of retrieved hits to the gold document: 1.0 when the gold
    /// chunk is retrieved, otherwise partial credit proportional to the
    /// best retrieved chunk's similarity to the gold chunk (cross-domain
    /// documents still help a little).
    pub fn relevance_from_hits(&self, hits: &[Hit], gold_doc: usize) -> f64 {
        if hits.iter().any(|h| h.id == gold_doc) {
            return 1.0;
        }
        // partial credit: similarity of best retrieved doc to the gold doc
        let gold_emb = &self.doc_embs[gold_doc];
        let best = hits
            .iter()
            .map(|h| cosine(&self.doc_embs[h.id], gold_emb) as f64)
            .fold(0.0, f64::max);
        (0.55 * best.clamp(0.0, 1.0)).clamp(0.0, 0.95)
    }
}
