//! Edge cluster: nodes (corpus + vector index + GPUs + model pool +
//! fitted predictors) and per-slot serving simulation.

pub mod node;

pub use node::{EdgeNode, NodeSlotReport, QueryOutcome};
