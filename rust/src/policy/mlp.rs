//! Pure-Rust reference forward pass of the policy network.
//!
//! Bit-faithful re-implementation of python/compile/model.py::_forward
//! (dense+ReLU → residual → layer norm → dense+ReLU ×2 → dense → softmax).
//! Used to (a) cross-check the AOT HLO numerics in integration tests and
//! (b) serve as a no-artifact fallback for unit tests and CLI tooling.

use super::params::{PolicyParams, EMBED_DIM, HIDDEN};

const LN_EPS: f32 = 1e-5;

/// `y[rows×n] = relu?(x[rows×k] @ w[k×n] + b[n])`
fn dense(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    b: &[f32],
    n: usize,
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(rows * n, 0.0);
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.copy_from_slice(&b[..n]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * n..(i + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
}

fn layer_norm(x: &mut [f32], rows: usize, d: usize, gamma: &[f32], beta: &[f32]) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = gamma[i] * (*v - mean) * inv + beta[i];
        }
    }
}

fn softmax_rows(x: &mut [f32], rows: usize, n: usize) {
    for r in 0..rows {
        let row = &mut x[r * n..(r + 1) * n];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Forward pass: `x` is row-major `[rows × EMBED_DIM]`; returns row-major
/// `[rows × n_actions]` probabilities.
pub fn forward(params: &PolicyParams, x: &[f32], rows: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * EMBED_DIM);
    let [h1, h2, h3] = HIDDEN;
    let n = params.n_actions;
    let t = &params.tensors;
    let (w1, b1, ln_g, ln_b) = (&t[0], &t[1], &t[2], &t[3]);
    let (w2, b2, w3, b3, w4, b4) = (&t[4], &t[5], &t[6], &t[7], &t[8], &t[9]);

    let mut buf1 = Vec::new();
    dense(x, rows, EMBED_DIM, w1, b1, h1, true, &mut buf1);
    // residual (EMBED_DIM == h1)
    for (o, &xv) in buf1.iter_mut().zip(x) {
        *o += xv;
    }
    layer_norm(&mut buf1, rows, h1, ln_g, ln_b);

    let mut buf2 = Vec::new();
    dense(&buf1, rows, h1, w2, b2, h2, true, &mut buf2);
    dense(&buf2, rows, h2, w3, b3, h3, true, &mut buf1);
    dense(&buf1, rows, h3, w4, b4, n, false, &mut buf2);
    softmax_rows(&mut buf2, rows, n);
    buf2
}

/// Convenience: probabilities for a single embedding.
pub fn forward_one(params: &PolicyParams, x: &[f32]) -> Vec<f32> {
    forward(params, x, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_x(rng: &mut Rng, rows: usize) -> Vec<f32> {
        (0..rows * EMBED_DIM).map(|_| rng.normal() as f32 * 0.3).collect()
    }

    #[test]
    fn output_is_simplex() {
        let p = PolicyParams::init(5, 3);
        let mut rng = Rng::new(4);
        let x = rand_x(&mut rng, 7);
        let probs = forward(&p, &x, 7);
        assert_eq!(probs.len(), 7 * 5);
        for r in 0..7 {
            let row = &probs[r * 5..(r + 1) * 5];
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
            assert!(row.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn batch_equals_single() {
        let p = PolicyParams::init(4, 5);
        let mut rng = Rng::new(6);
        let x = rand_x(&mut rng, 3);
        let batch = forward(&p, &x, 3);
        for r in 0..3 {
            let single = forward_one(&p, &x[r * EMBED_DIM..(r + 1) * EMBED_DIM]);
            for (a, b) in batch[r * 4..(r + 1) * 4].iter().zip(&single) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn input_sensitivity() {
        let p = PolicyParams::init(4, 7);
        let mut rng = Rng::new(8);
        let x1 = rand_x(&mut rng, 1);
        let x2 = rand_x(&mut rng, 1);
        let p1 = forward_one(&p, &x1);
        let p2 = forward_one(&p, &x2);
        let diff: f32 = p1.iter().zip(&p2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4, "diff={diff}");
    }

    #[test]
    fn layer_norm_stats() {
        let mut x: Vec<f32> = (0..512).map(|i| i as f32).collect();
        let g = vec![1.0; 256];
        let b = vec![0.0; 256];
        layer_norm(&mut x, 2, 256, &g, &b);
        for r in 0..2 {
            let row = &x[r * 256..(r + 1) * 256];
            let mean: f32 = row.iter().sum::<f32>() / 256.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 256.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }
}
