//! Online query identification (paper §IV-A).
//!
//! The policy network maps a 256-d query embedding to a probability vector
//! over edge nodes (the matching degrees `s_i^t`). Training is policy-only
//! PPO with batch-standardized feedback (Eq. 9–11), executed through the
//! AOT-compiled `ppo_update` artifact; inference through `policy_fwd`.
//!
//! - [`params`]: host-side parameter/Adam state (Rust owns the weights),
//! - [`mlp`]: pure-Rust reference forward (numerics cross-check + tests),
//! - [`ppo`]: the online learner — feedback buffer, reward
//!   standardization, update triggering.

pub mod params;
pub mod mlp;
pub mod ppo;
pub mod grad;

pub use params::PolicyParams;
pub use ppo::{OnlinePolicy, PpoConfig, Transition};
