//! Pure-Rust PPO backward pass + Adam — the host-side mirror of the AOT
//! `ppo_update` artifact (python/compile/model.py::ppo_update).
//!
//! Exists for three reasons: (1) a no-artifact fallback backend so unit
//! tests and tools run without `make artifacts`; (2) an independent
//! numerical cross-check of the HLO update (rust/tests/runtime_bridge.rs);
//! (3) finite-difference-validated gradients (see tests below), which
//! transitively validate the JAX graph through (2).
//!
//! The math must match model.py exactly: same loss (clipped policy-only
//! surrogate + entropy bonus, Eq. 11), same LayerNorm/residual forward,
//! same Adam update and hyper-parameters.

use super::params::{PolicyParams, EMBED_DIM, HIDDEN, NUM_TENSORS};
use crate::runtime::{UpdateBatch, UpdateStats};

// Hyper-parameters — keep in sync with python/compile/model.py.
/// Adam learning rate.
pub const LEARNING_RATE: f32 = 3e-4;
/// PPO surrogate clip range ε (Eq. 11).
pub const CLIP_EPS: f32 = 0.02;
/// Entropy-bonus coefficient β (Eq. 11).
pub const ENTROPY_BETA: f32 = 0.01;
/// Adam first-moment decay β₁.
pub const ADAM_B1: f32 = 0.9;
/// Adam second-moment decay β₂.
pub const ADAM_B2: f32 = 0.999;
/// Adam denominator stabilizer.
pub const ADAM_EPS: f32 = 1e-8;
/// LayerNorm variance stabilizer.
pub const LN_EPS: f32 = 1e-5;

/// Dense forward into `out`, returning pre-activation copy if `relu`.
fn dense_fwd(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    b: &[f32],
    n: usize,
    relu: bool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(rows * n, 0.0);
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        orow.copy_from_slice(&b[..n]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * n..(i + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
}

/// Backward through `y = relu?(x @ w + b)`.
/// `y_post` is the post-activation output (for the ReLU mask).
/// Accumulates dW, dB; writes dX.
#[allow(clippy::too_many_arguments)]
fn dense_bwd(
    x: &[f32],
    y_post: &[f32],
    dy: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    w: &[f32],
    relu: bool,
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
) {
    if relu {
        for (g, &y) in dy.iter_mut().zip(y_post) {
            if y <= 0.0 {
                *g = 0.0;
            }
        }
    }
    dx.iter_mut().for_each(|v| *v = 0.0);
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let dyrow = &dy[r * n..(r + 1) * n];
        let dxrow = &mut dx[r * k..(r + 1) * k];
        for (j, &g) in dyrow.iter().enumerate() {
            db[j] += g;
        }
        for i in 0..k {
            let wrow = &w[i * n..(i + 1) * n];
            let dwrow = &mut dw[i * n..(i + 1) * n];
            let xv = xrow[i];
            let mut acc = 0.0f32;
            for j in 0..n {
                dwrow[j] += xv * dyrow[j];
                acc += wrow[j] * dyrow[j];
            }
            dxrow[i] = acc;
        }
    }
}

/// Backward for the input layer: accumulates dW/dB only (no dX needed —
/// the layer's input is the query embedding, not a parameter).
#[allow(clippy::too_many_arguments)]
fn dense_bwd_params_only(
    x: &[f32],
    y_post: &[f32],
    dy: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
    db: &mut [f32],
) {
    for (g, &y) in dy.iter_mut().zip(y_post) {
        if y <= 0.0 {
            *g = 0.0;
        }
    }
    for r in 0..rows {
        let xrow = &x[r * k..(r + 1) * k];
        let dyrow = &dy[r * n..(r + 1) * n];
        for (j, &g) in dyrow.iter().enumerate() {
            db[j] += g;
        }
        for i in 0..k {
            let xv = xrow[i];
            if xv == 0.0 {
                continue;
            }
            let dwrow = &mut dw[i * n..(i + 1) * n];
            for j in 0..n {
                dwrow[j] += xv * dyrow[j];
            }
        }
    }
}

/// PPO loss + gradients for a (already masked/padded-free) batch.
/// Returns (grads in PARAM_NAMES order, loss, mean entropy).
pub fn ppo_grads(
    params: &PolicyParams,
    batch: &UpdateBatch,
) -> (Vec<Vec<f32>>, f32, f32) {
    let rows = batch.rows();
    let [h1, h2, h3] = HIDDEN;
    let n = params.n_actions;
    let t = &params.tensors;
    let (w1, b1, ln_g, ln_b) = (&t[0], &t[1], &t[2], &t[3]);
    let (w2, b2, w3, b3, w4, b4) = (&t[4], &t[5], &t[6], &t[7], &t[8], &t[9]);
    let x = &batch.x;

    // ---- forward with caches ----
    let mut a1 = Vec::new(); // relu(x@w1+b1)
    dense_fwd(x, rows, EMBED_DIM, w1, b1, h1, true, &mut a1);
    // residual
    let mut res = a1.clone();
    for (o, &xv) in res.iter_mut().zip(x.iter()) {
        *o += xv;
    }
    // layer norm caches
    let mut xhat = vec![0f32; rows * h1];
    let mut inv_std = vec![0f32; rows];
    let mut ln_out = vec![0f32; rows * h1];
    for r in 0..rows {
        let row = &res[r * h1..(r + 1) * h1];
        let mean = row.iter().sum::<f32>() / h1 as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / h1 as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        inv_std[r] = inv;
        for i in 0..h1 {
            let xh = (row[i] - mean) * inv;
            xhat[r * h1 + i] = xh;
            ln_out[r * h1 + i] = ln_g[i] * xh + ln_b[i];
        }
    }
    let mut a2 = Vec::new();
    dense_fwd(&ln_out, rows, h1, w2, b2, h2, true, &mut a2);
    let mut a3 = Vec::new();
    dense_fwd(&a2, rows, h2, w3, b3, h3, true, &mut a3);
    let mut logits = Vec::new();
    dense_fwd(&a3, rows, h3, w4, b4, n, false, &mut logits);

    // log-softmax, probs
    let mut logp = vec![0f32; rows * n];
    let mut probs = vec![0f32; rows * n];
    for r in 0..rows {
        let lrow = &logits[r * n..(r + 1) * n];
        let m = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + lrow.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
        for i in 0..n {
            let lp = lrow[i] - lse;
            logp[r * n + i] = lp;
            probs[r * n + i] = lp.exp();
        }
    }

    // ---- loss + dJ/dlogits ----
    let denom = rows as f32;
    let mut dlogits = vec![0f32; rows * n];
    let mut loss_sum = 0.0f32;
    let mut ent_sum = 0.0f32;
    for r in 0..rows {
        let a = batch.actions[r];
        let rwd = batch.rewards[r];
        let chosen_logp = logp[r * n + a];
        let ratio = (chosen_logp - batch.old_logp[r]).exp();
        let clipped = ratio.clamp(1.0 - CLIP_EPS, 1.0 + CLIP_EPS);
        let s1 = ratio * rwd;
        let s2 = clipped * rwd;
        let surr = s1.min(s2);
        // subgradient of min: branch-1 active (or tie) -> d(surr)/d(ratio)=rwd;
        // branch-2 active -> rwd inside the clip band, else 0.
        let g_ratio = if s1 <= s2 {
            rwd
        } else if (1.0 - CLIP_EPS..=1.0 + CLIP_EPS).contains(&ratio) {
            rwd
        } else {
            0.0
        };
        let h: f32 = -(0..n).map(|i| probs[r * n + i] * logp[r * n + i]).sum::<f32>();
        loss_sum += surr + ENTROPY_BETA * h;
        ent_sum += h;
        // dJ/dz = g_ratio*ratio*(onehot - p) + beta * (-p ⊙ (logp + H))
        for i in 0..n {
            let onehot = if i == a { 1.0 } else { 0.0 };
            let p = probs[r * n + i];
            let dsurr = g_ratio * ratio * (onehot - p);
            let dent = -p * (logp[r * n + i] + h);
            // loss = -J  ->  dloss/dz = -(dsurr + beta*dent)/denom
            dlogits[r * n + i] = -(dsurr + ENTROPY_BETA * dent) / denom;
        }
    }
    let loss = -loss_sum / denom;
    let entropy = ent_sum / denom;

    // ---- backward ----
    let shapes = params.shapes();
    let mut grads: Vec<Vec<f32>> = shapes.iter().map(|&(r, c)| vec![0f32; r * c]).collect();
    let mut d_a3 = vec![0f32; rows * h3];
    {
        let (gw4, gb4) = (8usize, 9usize);
        let mut dw = std::mem::take(&mut grads[gw4]);
        let mut db = std::mem::take(&mut grads[gb4]);
        dense_bwd(&a3, &logits, &mut dlogits, rows, h3, n, w4, false, &mut dw, &mut db, &mut d_a3);
        grads[gw4] = dw;
        grads[gb4] = db;
    }
    let mut d_a2 = vec![0f32; rows * h2];
    {
        let mut dw = std::mem::take(&mut grads[6]);
        let mut db = std::mem::take(&mut grads[7]);
        dense_bwd(&a2, &a3, &mut d_a3, rows, h2, h3, w3, true, &mut dw, &mut db, &mut d_a2);
        grads[6] = dw;
        grads[7] = db;
    }
    let mut d_ln_out = vec![0f32; rows * h1];
    {
        let mut dw = std::mem::take(&mut grads[4]);
        let mut db = std::mem::take(&mut grads[5]);
        dense_bwd(&ln_out, &a2, &mut d_a2, rows, h1, h2, w2, true, &mut dw, &mut db, &mut d_ln_out);
        grads[4] = dw;
        grads[5] = db;
    }
    // layernorm backward -> d_res; accumulate d gamma/beta
    let mut d_res = vec![0f32; rows * h1];
    for r in 0..rows {
        let dy = &d_ln_out[r * h1..(r + 1) * h1];
        let xh = &xhat[r * h1..(r + 1) * h1];
        let inv = inv_std[r];
        let mut sum_dxhat = 0.0f32;
        let mut sum_dxhat_xhat = 0.0f32;
        for i in 0..h1 {
            grads[2][i] += dy[i] * xh[i]; // d gamma
            grads[3][i] += dy[i]; // d beta
            let dxh = dy[i] * ln_g[i];
            sum_dxhat += dxh;
            sum_dxhat_xhat += dxh * xh[i];
        }
        let dcount = h1 as f32;
        for i in 0..h1 {
            let dxh = dy[i] * ln_g[i];
            d_res[r * h1 + i] =
                inv * (dxh - sum_dxhat / dcount - xh[i] * sum_dxhat_xhat / dcount);
        }
    }
    // residual: d_a1 = d_res (x-branch gradient stops at the input, so dX
    // is not needed — skipping it saves a rows·256·256 pass, §Perf)
    {
        let mut dw = std::mem::take(&mut grads[0]);
        let mut db = std::mem::take(&mut grads[1]);
        dense_bwd_params_only(x, &a1, &mut d_res, rows, EMBED_DIM, h1, &mut dw, &mut db);
        grads[0] = dw;
        grads[1] = db;
    }
    (grads, loss, entropy)
}

/// In-place Adam step (mirrors model.py::ppo_update's optimizer).
pub fn adam_apply(params: &mut PolicyParams, grads: &[Vec<f32>]) {
    params.step += 1;
    let t = params.step as f32;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for i in 0..NUM_TENSORS {
        let (p, g, m, v) = (
            &mut params.tensors[i],
            &grads[i],
            &mut params.adam_m[i],
            &mut params.adam_v[i],
        );
        for j in 0..p.len() {
            m[j] = ADAM_B1 * m[j] + (1.0 - ADAM_B1) * g[j];
            v[j] = ADAM_B2 * v[j] + (1.0 - ADAM_B2) * g[j] * g[j];
            let mhat = m[j] / bc1;
            let vhat = v[j] / bc2;
            p[j] -= LEARNING_RATE * mhat / (vhat.sqrt() + ADAM_EPS);
        }
    }
}

/// Full host-side PPO update — the reference twin of
/// [`crate::runtime::PolicyRuntime::update`].
pub fn update_host(params: &mut PolicyParams, batch: &UpdateBatch) -> UpdateStats {
    let (grads, loss, entropy) = ppo_grads(params, batch);
    adam_apply(params, &grads);
    UpdateStats { loss, entropy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::mlp;
    use crate::util::rng::Rng;

    fn make_batch(params: &PolicyParams, rows: usize, seed: u64) -> UpdateBatch {
        let mut rng = Rng::new(seed);
        let n = params.n_actions;
        let x: Vec<f32> = (0..rows * EMBED_DIM).map(|_| rng.normal() as f32 * 0.4).collect();
        let probs = mlp::forward(params, &x, rows);
        let mut actions = Vec::new();
        let mut old_logp = Vec::new();
        let mut rewards = Vec::new();
        for r in 0..rows {
            let row: Vec<f64> = probs[r * n..(r + 1) * n].iter().map(|&p| p as f64).collect();
            let a = rng.sample_weighted(&row);
            actions.push(a);
            old_logp.push((probs[r * n + a].max(1e-12)).ln());
            rewards.push(rng.normal() as f32);
        }
        UpdateBatch { x, actions, rewards, old_logp }
    }

    /// Recompute the loss only (for finite differences).
    fn loss_of(params: &PolicyParams, batch: &UpdateBatch) -> f32 {
        let (_, loss, _) = ppo_grads(params, batch);
        loss
    }

    #[test]
    fn finite_difference_gradcheck() {
        let mut params = PolicyParams::init(4, 11);
        let batch = make_batch(&params, 6, 12);
        let (grads, _, _) = ppo_grads(&params, &batch);
        let mut rng = Rng::new(13);
        let mut checked = 0;
        let mut max_rel = 0.0f64;
        for ti in 0..NUM_TENSORS {
            for _ in 0..4 {
                let j = rng.below(params.tensors[ti].len());
                let h = 2e-3f32;
                let orig = params.tensors[ti][j];
                params.tensors[ti][j] = orig + h;
                let lp = loss_of(&params, &batch);
                params.tensors[ti][j] = orig - h;
                let lm = loss_of(&params, &batch);
                params.tensors[ti][j] = orig;
                let num = ((lp - lm) / (2.0 * h)) as f64;
                let ana = grads[ti][j] as f64;
                let denom = num.abs().max(ana.abs());
                if denom > 5e-3 {
                    let rel = (num - ana).abs() / denom;
                    max_rel = max_rel.max(rel);
                    assert!(rel < 0.08, "tensor {ti} idx {j}: num={num:.5} ana={ana:.5}");
                    checked += 1;
                }
            }
        }
        assert!(checked >= 8, "too few informative gradcheck points ({checked})");
        assert!(max_rel < 0.08);
    }

    #[test]
    fn update_moves_toward_rewarded_action() {
        let mut params = PolicyParams::init(3, 21);
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..4 * EMBED_DIM).map(|_| rng.normal() as f32 * 0.4).collect();
        let probs0 = mlp::forward(&params, &x, 4);
        let p_before: f32 = (0..4).map(|r| probs0[r * 3]).sum::<f32>() / 4.0;
        // always reward action 0 with +1 (standardized reward)
        for step in 0..80 {
            let probs = mlp::forward(&params, &x, 4);
            let batch = UpdateBatch {
                x: x.clone(),
                actions: vec![0; 4],
                rewards: vec![1.0; 4],
                old_logp: (0..4).map(|r| probs[r * 3].max(1e-12).ln()).collect(),
            };
            let stats = update_host(&mut params, &batch);
            assert!(stats.loss.is_finite(), "step {step}");
        }
        let probs1 = mlp::forward(&params, &x, 4);
        let p_after: f32 = (0..4).map(|r| probs1[r * 3]).sum::<f32>() / 4.0;
        assert!(
            p_after > p_before + 0.05,
            "before={p_before:.4} after={p_after:.4}"
        );
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // with zero adam state and gradient g, first step ≈ -lr * sign(g)
        let mut params = PolicyParams::init(3, 31);
        let g0 = 0.01f32;
        let mut grads: Vec<Vec<f32>> = params
            .tensors
            .iter()
            .map(|t| vec![0.0; t.len()])
            .collect();
        grads[0][0] = g0;
        let before = params.tensors[0][0];
        adam_apply(&mut params, &grads);
        let delta = params.tensors[0][0] - before;
        assert!(
            (delta + LEARNING_RATE).abs() < LEARNING_RATE * 0.01,
            "delta={delta}"
        );
        // untouched coords unchanged
        assert_eq!(params.tensors[1][0], 0.0);
    }

    #[test]
    fn entropy_positive_and_bounded() {
        let params = PolicyParams::init(5, 41);
        let batch = make_batch(&params, 8, 42);
        let (_, _, entropy) = ppo_grads(&params, &batch);
        assert!(entropy > 0.0);
        assert!(entropy <= (5.0f32).ln() + 1e-4);
    }
}
