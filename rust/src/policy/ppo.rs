//! The online query-identification learner (paper §IV-A).
//!
//! Wraps the policy parameters with: action sampling from the probability
//! vector `s_i^t`, a feedback memory buffer, batch-standardized rewards
//! (Eq. 10), and threshold-triggered PPO updates (the paper's
//! "memory buffer … triggers batched policy updates only when the
//! accumulated queries exceed a predetermined threshold").
//!
//! Two interchangeable backends:
//! - [`Backend::Pjrt`] — executes the AOT HLO artifacts via PJRT
//!   (the production path; Python never runs here),
//! - [`Backend::Reference`] — the pure-Rust twin (tests / no artifacts).

use std::sync::Arc;

use crate::policy::grad;
use crate::policy::mlp;
use crate::policy::params::{PolicyParams, EMBED_DIM};
use crate::runtime::{PolicyRuntime, UpdateBatch, UpdateStats};
use crate::util::rng::Rng;
use crate::util::stats::standardize;
use crate::Result;

/// Which engine executes forward/update.
#[derive(Clone)]
pub enum Backend {
    /// AOT HLO artifacts through PJRT.
    Pjrt(Arc<PolicyRuntime>),
    /// Pure-Rust mirror implementation.
    Reference,
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Pjrt(_) => write!(f, "Backend::Pjrt"),
            Backend::Reference => write!(f, "Backend::Reference"),
        }
    }
}

/// PPO learner configuration.
#[derive(Clone, Debug)]
pub struct PpoConfig {
    /// Buffer size that triggers an update (the paper's threshold).
    pub buffer_threshold: usize,
    /// Optimization epochs per triggered batch (re-uses the batch with
    /// fixed behavior policy — standard PPO batch reuse).
    pub epochs: usize,
    /// Feedback weight α₁ (ROUGE/LCS term), Eq. 9.
    pub alpha1: f64,
    /// Feedback weight α₂ (BERTScore term), Eq. 9.
    pub alpha2: f64,
    /// Exploration floor: actions are sampled from
    /// `(1−ε)·π + ε·uniform` to guarantee continued data collection.
    pub explore_eps: f64,
    /// Seed for parameter init and the action-sampling RNG stream.
    pub seed: u64,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            buffer_threshold: 256,
            epochs: 8,
            alpha1: 1.0,
            alpha2: 0.5,
            explore_eps: 0.05,
            seed: 0xC0ED6E,
        }
    }
}

/// One `(state, action, reward)` sample: the unit both the online buffer
/// and the offline rollout farm (`crate::train`) feed to PPO updates.
#[derive(Clone, Debug)]
pub struct Transition {
    /// Query embedding (`EMBED_DIM` floats).
    pub x: Vec<f32>,
    /// Node the query was routed to.
    pub action: usize,
    /// Behavior log-probability of `action` at decision time.
    pub old_logp: f32,
    /// Composite feedback score (Eq. 9) the evaluator assigned.
    pub feedback: f64,
}

/// The online policy: parameters + buffer + backend.
pub struct OnlinePolicy {
    /// Policy-network parameters + Adam state (host-owned).
    pub params: PolicyParams,
    /// Learner configuration.
    pub cfg: PpoConfig,
    backend: Backend,
    buffer: Vec<Transition>,
    rng: Rng,
    /// Number of completed update rounds (each = cfg.epochs PPO steps).
    pub updates: usize,
    /// Last update's stats, if any.
    pub last_stats: Option<UpdateStats>,
}

impl OnlinePolicy {
    /// Fresh policy: parameters seeded from `cfg.seed`, empty buffer.
    pub fn new(n_actions: usize, cfg: PpoConfig, backend: Backend) -> Self {
        let params = PolicyParams::init(n_actions, cfg.seed ^ 0x9E37);
        Self::with_params(params, cfg, backend)
    }

    /// Wrap existing parameters (checkpoint restore, rollout snapshots)
    /// without re-initializing the weights; only the RNG stream and the
    /// empty buffer are fresh.
    pub fn with_params(params: PolicyParams, cfg: PpoConfig, backend: Backend) -> Self {
        let rng = Rng::new(cfg.seed);
        OnlinePolicy {
            params,
            cfg,
            backend,
            buffer: Vec::new(),
            rng,
            updates: 0,
            last_stats: None,
        }
    }

    /// Number of routing actions (= cluster nodes) the network outputs.
    pub fn n_actions(&self) -> usize {
        self.params.n_actions
    }

    /// Probability vectors `s_i^t` for a batch of embeddings
    /// (row-major `[rows × EMBED_DIM]` → `[rows × n_actions]`).
    pub fn probs(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Pjrt(rt) => rt.forward(&self.params, x, rows),
            Backend::Reference => Ok(mlp::forward(&self.params, x, rows)),
        }
    }

    /// Sample an action from a probability row with the exploration floor;
    /// returns (action, log π_behavior(action)).
    pub fn sample_action(&mut self, prob_row: &[f32]) -> (usize, f32) {
        let n = prob_row.len();
        let eps = self.cfg.explore_eps;
        let mixed: Vec<f64> = prob_row
            .iter()
            .map(|&p| (1.0 - eps) * p as f64 + eps / n as f64)
            .collect();
        let a = self.rng.sample_weighted(&mixed);
        // old_logp is the *policy* logp (importance ratios are computed
        // against π_θ_old, which is what the update graph recomputes).
        let logp = (prob_row[a].max(1e-12)).ln();
        (a, logp)
    }

    /// Record feedback for one served query (Eq. 9 composite score is
    /// computed by the caller via `metrics::Evaluator::feedback`).
    /// Triggers an update when the buffer reaches the threshold.
    pub fn record(
        &mut self,
        x: &[f32],
        action: usize,
        old_logp: f32,
        feedback: f64,
    ) -> Result<Option<UpdateStats>> {
        debug_assert_eq!(x.len(), EMBED_DIM);
        self.buffer.push(Transition { x: x.to_vec(), action, old_logp, feedback });
        if self.buffer.len() >= self.cfg.buffer_threshold {
            let stats = self.flush()?;
            return Ok(stats);
        }
        Ok(None)
    }

    /// Force an update on whatever is buffered (e.g. at slot end).
    pub fn flush(&mut self) -> Result<Option<UpdateStats>> {
        if self.buffer.len() < 2 {
            return Ok(None);
        }
        let exps = std::mem::take(&mut self.buffer);
        self.update_on(&exps)
    }

    /// Run one update round (`cfg.epochs` PPO steps) on an explicit batch
    /// of transitions, bypassing the online buffer — the rollout farm
    /// (`crate::train`) merges replica transitions and steps the shared
    /// learner through this. Applies the same Eq. 10 batch
    /// standardization as the buffered path; batches of fewer than two
    /// transitions are skipped (`None`) because the reward std is
    /// undefined.
    pub fn update_on(&mut self, transitions: &[Transition]) -> Result<Option<UpdateStats>> {
        if transitions.len() < 2 {
            return Ok(None);
        }
        // Eq. 10: batch standardization of the feedback signal.
        let raw: Vec<f64> = transitions.iter().map(|e| e.feedback).collect();
        let std_rewards = standardize(&raw);
        let rows = transitions.len();
        let mut batch = UpdateBatch {
            x: Vec::with_capacity(rows * EMBED_DIM),
            actions: Vec::with_capacity(rows),
            rewards: std_rewards.iter().map(|&r| r as f32).collect(),
            old_logp: transitions.iter().map(|e| e.old_logp).collect(),
        };
        for e in transitions {
            batch.x.extend_from_slice(&e.x);
            batch.actions.push(e.action);
        }
        let mut last = UpdateStats { loss: 0.0, entropy: 0.0 };
        for _ in 0..self.cfg.epochs {
            last = match &self.backend {
                Backend::Pjrt(rt) => rt.update(&mut self.params, &batch)?,
                Backend::Reference => grad::update_host(&mut self.params, &batch),
            };
        }
        self.updates += 1;
        self.last_stats = Some(last);
        Ok(Some(last))
    }

    /// Buffered-but-unflushed experience count.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an embedding that is a one-hot-ish cluster marker: queries of
    /// "domain d" share a direction, so a linear policy can separate them.
    fn cluster_embedding(rng: &mut Rng, cluster: usize, n_clusters: usize) -> Vec<f32> {
        let mut x = vec![0f32; EMBED_DIM];
        let span = EMBED_DIM / n_clusters;
        for i in 0..span {
            x[cluster * span + i] = 1.0 + 0.1 * rng.normal() as f32;
        }
        for v in x.iter_mut() {
            *v += 0.05 * rng.normal() as f32;
        }
        crate::text::embed::l2_normalize(&mut x);
        x
    }

    #[test]
    fn learns_cluster_to_node_mapping() {
        // 3 clusters, 3 nodes; reward +1 when action == cluster else -1.
        let n = 3;
        let cfg = PpoConfig {
            buffer_threshold: 64,
            epochs: 6,
            explore_eps: 0.1,
            ..Default::default()
        };
        let mut pol = OnlinePolicy::new(n, cfg, Backend::Reference);
        let mut rng = Rng::new(99);
        let mut correct_recent = 0usize;
        let mut total_recent = 0usize;
        for step in 0..3000 {
            let c = rng.below(n);
            let x = cluster_embedding(&mut rng, c, n);
            let probs = pol.probs(&x, 1).unwrap();
            let (a, logp) = pol.sample_action(&probs);
            let fb = if a == c { 1.0 } else { -1.0 };
            pol.record(&x, a, logp, fb).unwrap();
            if step >= 2500 {
                total_recent += 1;
                if a == c {
                    correct_recent += 1;
                }
            }
        }
        assert!(pol.updates >= 10, "updates={}", pol.updates);
        let acc = correct_recent as f64 / total_recent as f64;
        assert!(acc > 0.6, "final routing accuracy={acc:.3}");
    }

    #[test]
    fn buffer_threshold_triggers_update() {
        let cfg = PpoConfig { buffer_threshold: 8, epochs: 1, ..Default::default() };
        let mut pol = OnlinePolicy::new(3, cfg, Backend::Reference);
        let mut rng = Rng::new(5);
        for i in 0..7 {
            let x = cluster_embedding(&mut rng, i % 3, 3);
            let out = pol.record(&x, 0, -1.0, 0.5).unwrap();
            assert!(out.is_none());
        }
        assert_eq!(pol.buffered(), 7);
        let x = cluster_embedding(&mut rng, 0, 3);
        let out = pol.record(&x, 0, -1.0, 0.5).unwrap();
        assert!(out.is_some());
        assert_eq!(pol.buffered(), 0);
        assert_eq!(pol.updates, 1);
    }

    #[test]
    fn flush_on_tiny_buffer_is_noop() {
        let mut pol = OnlinePolicy::new(3, PpoConfig::default(), Backend::Reference);
        assert!(pol.flush().unwrap().is_none());
        let mut rng = Rng::new(1);
        let x = cluster_embedding(&mut rng, 0, 3);
        pol.record(&x, 0, -1.0, 0.1).unwrap();
        assert!(pol.flush().unwrap().is_none()); // 1 sample: skip (std undefined)
    }

    #[test]
    fn sampling_respects_distribution() {
        let mut pol = OnlinePolicy::new(3, PpoConfig { explore_eps: 0.0, ..Default::default() }, Backend::Reference);
        let probs = [0.8f32, 0.15, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            let (a, logp) = pol.sample_action(&probs);
            counts[a] += 1;
            assert!((logp - probs[a].ln()).abs() < 1e-6);
        }
        let f0 = counts[0] as f64 / 5000.0;
        assert!((f0 - 0.8).abs() < 0.05, "f0={f0}");
    }
}
