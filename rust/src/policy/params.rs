//! Host-side policy parameters + Adam state.
//!
//! Rust owns the weights: the AOT graphs are pure functions, so parameters
//! live here as flat `Vec<f32>` tensors (in the `PARAM_NAMES` order shared
//! with python/compile/model.py) and are shipped to PJRT per call.

use crate::util::rng::Rng;

/// Hidden layer widths — must match python/compile/model.py::HIDDEN.
pub const HIDDEN: [usize; 3] = [256, 128, 64];
/// Embedding dim — must match model.py::EMBED_DIM and text::embed::EMBED_DIM.
pub const EMBED_DIM: usize = 256;
/// Number of parameter tensors (w1,b1,ln_g,ln_b,w2,b2,w3,b3,w4,b4).
pub const NUM_TENSORS: usize = 10;

/// Parameter tensor shapes for `n_actions`, in PARAM_NAMES order.
pub fn param_shapes(n_actions: usize) -> [(usize, usize); NUM_TENSORS] {
    let [h1, h2, h3] = HIDDEN;
    [
        (EMBED_DIM, h1),
        (1, h1),
        (1, h1),
        (1, h1),
        (h1, h2),
        (1, h2),
        (h2, h3),
        (1, h3),
        (h3, n_actions),
        (1, n_actions),
    ]
}

/// Policy parameters + Adam optimizer state.
#[derive(Clone, Debug)]
pub struct PolicyParams {
    /// Output width of the final layer (= number of cluster nodes).
    pub n_actions: usize,
    /// Flat tensors in PARAM_NAMES order (row-major).
    pub tensors: Vec<Vec<f32>>,
    /// Adam first-moment state, one entry per tensor.
    pub adam_m: Vec<Vec<f32>>,
    /// Adam second-moment state, one entry per tensor.
    pub adam_v: Vec<Vec<f32>>,
    /// 1-based Adam timestep (incremented per update call).
    pub step: u64,
}

impl PolicyParams {
    /// He-uniform init for weights, zeros for biases, ones for ln gamma —
    /// mirrors model.py::init_params (different RNG, same distribution).
    pub fn init(n_actions: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let shapes = param_shapes(n_actions);
        let names = [
            "w1", "b1", "ln_g", "ln_b", "w2", "b2", "w3", "b3", "w4", "b4",
        ];
        let tensors = names
            .iter()
            .zip(shapes.iter())
            .map(|(name, &(r, c))| {
                let len = r * c;
                match *name {
                    n if n.starts_with('w') => {
                        let lim = (6.0 / r as f64).sqrt();
                        (0..len).map(|_| rng.range_f64(-lim, lim) as f32).collect()
                    }
                    "ln_g" => vec![1.0; len],
                    _ => vec![0.0; len],
                }
            })
            .collect::<Vec<_>>();
        let adam_m = tensors.iter().map(|t| vec![0.0; t.len()]).collect();
        let adam_v = tensors.iter().map(|t| vec![0.0; t.len()]).collect();
        PolicyParams { n_actions, tensors, adam_m, adam_v, step: 0 }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Shapes as (rows, cols) pairs.
    pub fn shapes(&self) -> [(usize, usize); NUM_TENSORS] {
        param_shapes(self.n_actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let p = PolicyParams::init(4, 1);
        let shapes = p.shapes();
        assert_eq!(p.tensors.len(), NUM_TENSORS);
        for (t, &(r, c)) in p.tensors.iter().zip(shapes.iter()) {
            assert_eq!(t.len(), r * c);
        }
        // 256*256 + 256*3 + 256*128 + 128 + 128*64 + 64 + 64*4 + 4
        let expect: usize = 256 * 256
            + 3 * 256
            + 256 * 128
            + 128
            + 128 * 64
            + 64
            + 64 * 4
            + 4;
        assert_eq!(p.num_params(), expect);
    }

    #[test]
    fn init_distributions() {
        let p = PolicyParams::init(3, 2);
        // ln_g all ones, biases zero
        assert!(p.tensors[2].iter().all(|&x| x == 1.0));
        assert!(p.tensors[1].iter().all(|&x| x == 0.0));
        assert!(p.tensors[9].iter().all(|&x| x == 0.0));
        // w1 within He-uniform bounds and not all zero
        let lim = (6.0 / 256.0f64).sqrt() as f32;
        assert!(p.tensors[0].iter().all(|&x| x.abs() <= lim));
        assert!(p.tensors[0].iter().any(|&x| x.abs() > 1e-4));
        // adam state zeroed
        assert!(p.adam_m[0].iter().all(|&x| x == 0.0));
        assert_eq!(p.step, 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = PolicyParams::init(4, 9);
        let b = PolicyParams::init(4, 9);
        let c = PolicyParams::init(4, 10);
        assert_eq!(a.tensors[0], b.tensors[0]);
        assert_ne!(a.tensors[0], c.tensors[0]);
    }
}
