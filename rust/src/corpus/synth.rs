//! Synthetic corpus + QA generation.
//!
//! Vocabulary is built from seeded syllable compositions so tokens look
//! word-like and are unique per domain; documents are topic-weighted token
//! sequences; QA pairs are grounded: the query samples salient tokens of a
//! gold document and the reference answer is an extractive span of it.

use crate::util::rng::Rng;

/// Specification for a synthetic dataset.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Dataset display name (e.g. "DomainQA", "PPC").
    pub name: String,
    /// One name per topical domain; the count fixes the domain count.
    pub domain_names: Vec<String>,
    /// Documents generated per domain.
    pub docs_per_domain: usize,
    /// Tokens per document (fixed-length chunks, as the paper assumes).
    pub doc_len: usize,
    /// QA pairs generated per domain.
    pub qa_per_domain: usize,
    /// Tokens per query (incl. the two leading question words).
    pub query_len: usize,
    /// Tokens in the extractive reference answer span.
    pub answer_len: usize,
    /// Domain-specific vocabulary size.
    pub vocab_size: usize,
    /// Shared cross-domain vocabulary size.
    pub common_vocab_size: usize,
    /// Fraction of document tokens drawn from the domain vocabulary
    /// (the rest from the common vocabulary).
    pub domain_token_frac: f64,
}

/// A fixed-length document chunk.
#[derive(Clone, Debug)]
pub struct Document {
    /// Global document id (dense, equals the index into the dataset).
    pub id: usize,
    /// Owning domain index.
    pub domain: usize,
    /// Token sequence of length [`DatasetSpec::doc_len`].
    pub tokens: Vec<String>,
}

impl Document {
    /// The document as a space-joined string (embedder / metric input).
    pub fn text(&self) -> String {
        self.tokens.join(" ")
    }
}

/// A grounded question–answer pair.
#[derive(Clone, Debug)]
pub struct QaPair {
    /// Global QA id (dense, equals the index into the dataset).
    pub id: usize,
    /// Domain of the gold document (and hence of the query).
    pub domain: usize,
    /// The single gold document this query is answerable from.
    pub gold_doc: usize,
    /// Query text: question words + salient gold-document tokens.
    pub query: String,
    /// Extractive reference answer (the "REF" in the paper's feedback).
    pub answer_tokens: Vec<String>,
}

impl QaPair {
    /// The reference answer as a space-joined string.
    pub fn answer_text(&self) -> String {
        self.answer_tokens.join(" ")
    }
}

/// A complete synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticDataset {
    /// Dataset display name (copied from the spec).
    pub name: String,
    /// One name per topical domain.
    pub domain_names: Vec<String>,
    /// Per-domain topical vocabularies.
    pub domain_vocab: Vec<Vec<String>>,
    /// Vocabulary shared across all domains.
    pub common_vocab: Vec<String>,
    /// All documents, indexable by [`Document::id`].
    pub documents: Vec<Document>,
    /// All QA pairs, indexable by [`QaPair::id`].
    pub qa_pairs: Vec<QaPair>,
}

impl SyntheticDataset {
    /// Number of topical domains.
    pub fn num_domains(&self) -> usize {
        self.domain_names.len()
    }

    /// Document ids belonging to a domain.
    pub fn docs_of_domain(&self, domain: usize) -> Vec<usize> {
        self.documents
            .iter()
            .filter(|d| d.domain == domain)
            .map(|d| d.id)
            .collect()
    }

    /// QA ids belonging to a domain.
    pub fn qa_of_domain(&self, domain: usize) -> Vec<usize> {
        self.qa_pairs
            .iter()
            .filter(|q| q.domain == domain)
            .map(|q| q.id)
            .collect()
    }
}

const SYLLABLES: [&str; 24] = [
    "ba", "co", "di", "fu", "ga", "he", "ji", "ka", "lo", "mi", "nu", "pa", "qo", "ri", "sa",
    "te", "ul", "va", "wi", "xo", "ya", "zu", "or", "en",
];

/// Question-词 common to all queries (domain-neutral).
const QUESTION_WORDS: [&str; 8] = [
    "what", "how", "why", "describe", "explain", "when", "which", "does",
];

/// Generate a pseudo-word from 2–4 syllables with a domain prefix so
/// vocabularies never collide across domains.
fn make_word(rng: &mut Rng, prefix: &str) -> String {
    let n = 2 + rng.below(3);
    let mut w = String::from(prefix);
    for _ in 0..n {
        w.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
    }
    w
}

fn build_vocab(rng: &mut Rng, size: usize, prefix: &str) -> Vec<String> {
    let mut vocab = Vec::with_capacity(size);
    let mut seen = std::collections::HashSet::new();
    while vocab.len() < size {
        let w = make_word(rng, prefix);
        if seen.insert(w.clone()) {
            vocab.push(w);
        }
    }
    vocab
}

/// Zipf-ish weights: rank r gets weight 1/(r+2)^0.8 — a few very common
/// topical words per domain plus a long tail, like real text.
fn zipf_weights(n: usize) -> Vec<f64> {
    (0..n).map(|r| 1.0 / (r as f64 + 2.0).powf(0.8)).collect()
}

/// Build a complete synthetic dataset (deterministic per seed).
pub fn build_dataset(spec: &DatasetSpec, seed: u64) -> SyntheticDataset {
    let mut rng = Rng::new(seed);
    let nd = spec.domain_names.len();

    let common_vocab = build_vocab(&mut rng, spec.common_vocab_size, "c");
    let domain_vocab: Vec<Vec<String>> = (0..nd)
        .map(|d| build_vocab(&mut rng.fork(d as u64 + 1), spec.vocab_size, &format!("d{d}")))
        .collect();

    let dweights = zipf_weights(spec.vocab_size);
    let cweights = zipf_weights(spec.common_vocab_size);

    // Documents.
    let mut documents = Vec::with_capacity(nd * spec.docs_per_domain);
    for d in 0..nd {
        // Each document has a *topic focus*: a small subset of the domain
        // vocabulary it over-samples, so documents within a domain are
        // distinguishable (retrieval has something to find).
        for _ in 0..spec.docs_per_domain {
            let id = documents.len();
            let focus: Vec<usize> = (0..12).map(|_| rng.below(spec.vocab_size)).collect();
            let mut tokens = Vec::with_capacity(spec.doc_len);
            for _ in 0..spec.doc_len {
                if rng.chance(spec.domain_token_frac) {
                    // 55% of domain tokens come from the focus subset.
                    let idx = if rng.chance(0.55) {
                        focus[rng.below(focus.len())]
                    } else {
                        rng.sample_weighted(&dweights)
                    };
                    tokens.push(domain_vocab[d][idx].clone());
                } else {
                    tokens.push(common_vocab[rng.sample_weighted(&cweights)].clone());
                }
            }
            documents.push(Document { id, domain: d, tokens });
        }
    }

    // QA pairs.
    let docs_per = spec.docs_per_domain;
    let mut qa_pairs = Vec::with_capacity(nd * spec.qa_per_domain);
    for d in 0..nd {
        for _ in 0..spec.qa_per_domain {
            let id = qa_pairs.len();
            let gold_local = rng.below(docs_per);
            let gold_doc = d * docs_per + gold_local;
            let doc = &documents[gold_doc];

            // Query: 2 question words + salient doc tokens (prefer domain
            // vocabulary tokens — users ask about topical content).
            let mut qtokens: Vec<String> = Vec::with_capacity(spec.query_len);
            qtokens.push(QUESTION_WORDS[rng.below(QUESTION_WORDS.len())].to_string());
            qtokens.push(QUESTION_WORDS[rng.below(QUESTION_WORDS.len())].to_string());
            let domain_toks: Vec<&String> = doc
                .tokens
                .iter()
                .filter(|t| t.starts_with(&format!("d{d}")))
                .collect();
            while qtokens.len() < spec.query_len {
                let t = if !domain_toks.is_empty() && rng.chance(0.85) {
                    (*domain_toks[rng.below(domain_toks.len())]).clone()
                } else {
                    doc.tokens[rng.below(doc.tokens.len())].clone()
                };
                qtokens.push(t);
            }

            // Answer: extractive contiguous span.
            let alen = spec.answer_len.min(doc.tokens.len());
            let start = rng.below(doc.tokens.len() - alen + 1);
            let answer_tokens = doc.tokens[start..start + alen].to_vec();

            qa_pairs.push(QaPair {
                id,
                domain: d,
                gold_doc,
                query: qtokens.join(" "),
                answer_tokens,
            });
        }
    }

    SyntheticDataset {
        name: spec.name.clone(),
        domain_names: spec.domain_names.clone(),
        domain_vocab,
        common_vocab,
        documents,
        qa_pairs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::domainqa_spec;
    use crate::text::embed::{cosine, Embedder};

    fn small() -> SyntheticDataset {
        build_dataset(&domainqa_spec(20, 30), 7)
    }

    #[test]
    fn dataset_shapes() {
        let ds = small();
        assert_eq!(ds.num_domains(), 6);
        assert_eq!(ds.documents.len(), 6 * 30);
        assert_eq!(ds.qa_pairs.len(), 6 * 20);
        for (i, d) in ds.documents.iter().enumerate() {
            assert_eq!(d.id, i);
            assert_eq!(d.tokens.len(), 96);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = small();
        let b = small();
        assert_eq!(a.documents[5].tokens, b.documents[5].tokens);
        assert_eq!(a.qa_pairs[11].query, b.qa_pairs[11].query);
        let c = build_dataset(&domainqa_spec(20, 30), 8);
        assert_ne!(a.documents[5].tokens, c.documents[5].tokens);
    }

    #[test]
    fn gold_doc_domain_consistent() {
        let ds = small();
        for qa in &ds.qa_pairs {
            assert_eq!(ds.documents[qa.gold_doc].domain, qa.domain);
        }
    }

    #[test]
    fn answers_are_extractive() {
        let ds = small();
        for qa in ds.qa_pairs.iter().take(30) {
            let doc_text = ds.documents[qa.gold_doc].text();
            assert!(doc_text.contains(&qa.answer_text()));
        }
    }

    #[test]
    fn vocabularies_disjoint_across_domains() {
        let ds = small();
        for d1 in 0..6 {
            for d2 in d1 + 1..6 {
                for w in &ds.domain_vocab[d1] {
                    assert!(!ds.domain_vocab[d2].contains(w));
                }
            }
        }
    }

    #[test]
    fn same_domain_queries_embed_closer() {
        let ds = small();
        let e = Embedder::default();
        // average within-domain vs cross-domain query similarity
        let qa: Vec<_> = ds.qa_pairs.iter().take(60).collect();
        let embs: Vec<Vec<f32>> = qa.iter().map(|q| e.embed(&q.query)).collect();
        let mut within = Vec::new();
        let mut cross = Vec::new();
        for i in 0..qa.len() {
            for j in i + 1..qa.len() {
                let s = cosine(&embs[i], &embs[j]) as f64;
                if qa[i].domain == qa[j].domain {
                    within.push(s);
                } else {
                    cross.push(s);
                }
            }
        }
        let mw = crate::util::stats::mean(&within);
        let mc = crate::util::stats::mean(&cross);
        // Short queries share few tokens even within a domain, so raw
        // cosine gaps are modest; what matters is that within-domain
        // similarity clearly dominates cross-domain (domain words hash to
        // domain-specific buckets -> linear separability for the policy).
        assert!(mw > 1.5 * mc, "within={mw:.3} cross={mc:.3}");
    }

    #[test]
    fn query_matches_gold_doc_better_than_random_doc() {
        let ds = small();
        let e = Embedder::default();
        let mut hits = 0;
        let total = 40;
        for qa in ds.qa_pairs.iter().take(total) {
            let q = e.embed(&qa.query);
            let gold = e.embed(&ds.documents[qa.gold_doc].text());
            // compare to a random same-domain other doc
            let other_id = ds
                .docs_of_domain(qa.domain)
                .into_iter()
                .find(|&d| d != qa.gold_doc)
                .unwrap();
            let other = e.embed(&ds.documents[other_id].text());
            if cosine(&q, &gold) > cosine(&q, &other) {
                hits += 1;
            }
        }
        assert!(hits as f64 / total as f64 > 0.8, "hits={hits}/{total}");
    }
}
