//! Synthetic domain corpora, QA synthesis and edge-data partitioning.
//!
//! Substitutes the paper's datasets (BAAI industry corpora with
//! DeepSeek-V3-generated QA pairs — "DomainQA" — and the
//! Personalized-Proactive-Conversations dataset) with seeded synthetic
//! equivalents that preserve what the scheduler actually observes:
//! - six topical domains with distinct vocabularies (and a shared common
//!   vocabulary), so same-domain texts embed near each other;
//! - every query grounded in exactly one *gold document* (single-document
//!   queries, paper §III), with an extractive reference answer — giving an
//!   exact Oracle and real ROUGE/BLEU/METEOR/BERTScore feedback;
//! - the paper's dual-distribution edge partition: s% i.i.d. across all
//!   domains + (100−s)% from each node's primary domains, scaled by an
//!   overlap factor (§V-A "Edge-data Partition").

pub mod synth;
pub mod partition;

pub use partition::{partition_corpus, NodeCorpusSpec};
pub use synth::{build_dataset, DatasetSpec, Document, QaPair, SyntheticDataset};

/// The six DomainQA domains used throughout the paper.
pub const DOMAINQA_DOMAINS: [&str; 6] = [
    "biomedicine",
    "finance",
    "law",
    "sports",
    "technology",
    "travel",
];

/// The six PPC persona profiles.
pub const PPC_PERSONAS: [&str; 6] = ["student", "teacher", "parent", "engineer", "chef", "writer"];

/// Standard DomainQA-like dataset spec (scaled down from the paper's
/// 3000 QA/domain to keep CI-speed runs; benches scale up via config).
pub fn domainqa_spec(qa_per_domain: usize, docs_per_domain: usize) -> DatasetSpec {
    DatasetSpec {
        name: "DomainQA".into(),
        domain_names: DOMAINQA_DOMAINS.iter().map(|s| s.to_string()).collect(),
        docs_per_domain,
        doc_len: 96,
        qa_per_domain,
        query_len: 12,
        answer_len: 24,
        vocab_size: 320,
        common_vocab_size: 160,
        domain_token_frac: 0.72,
    }
}

/// Standard PPC-like dataset spec: shorter, more conversational texts.
pub fn ppc_spec(qa_per_domain: usize, docs_per_domain: usize) -> DatasetSpec {
    DatasetSpec {
        name: "PPC".into(),
        domain_names: PPC_PERSONAS.iter().map(|s| s.to_string()).collect(),
        docs_per_domain,
        doc_len: 64,
        qa_per_domain,
        query_len: 10,
        answer_len: 16,
        vocab_size: 240,
        common_vocab_size: 200,
        domain_token_frac: 0.6,
    }
}
