//! Edge-data partitioning (paper §V-A "Edge-data Partition").
//!
//! Dual-distribution paradigm: s% of each node's corpus is i.i.d. across
//! all domains, the remaining (100−s)% comes from the node's primary
//! domains; an overlap factor scales both portions, creating controlled
//! dataset intersections between nodes (cross-node knowledge sharing).

use super::synth::SyntheticDataset;
use crate::util::rng::Rng;

/// Per-node corpus specification.
#[derive(Clone, Debug)]
pub struct NodeCorpusSpec {
    /// Number of documents the node stores (before overlap scaling).
    pub docs: usize,
    /// Mixture weights over domains (need not be normalized).
    pub domain_weights: Vec<f64>,
}

impl NodeCorpusSpec {
    /// The paper's dual-distribution mixture: `s_iid` uniform over all
    /// domains + (1−s_iid) uniform over `primaries`.
    pub fn dual(docs: usize, num_domains: usize, primaries: &[usize], s_iid: f64) -> Self {
        let mut w = vec![s_iid / num_domains as f64; num_domains];
        for &p in primaries {
            w[p] += (1.0 - s_iid) / primaries.len() as f64;
        }
        NodeCorpusSpec { docs, domain_weights: w }
    }

    /// Motivation-style mixture (§II): one primary domain with fraction
    /// `primary_frac`, remainder split evenly over the others.
    pub fn motivation(docs: usize, num_domains: usize, primary: usize, primary_frac: f64) -> Self {
        let rest = (1.0 - primary_frac) / (num_domains - 1) as f64;
        let mut w = vec![rest; num_domains];
        w[primary] = primary_frac;
        NodeCorpusSpec { docs, domain_weights: w }
    }
}

/// Assign documents to nodes. Returns, per node, the list of document ids
/// it stores. `overlap` ∈ [0, 1] scales every node's corpus size by
/// (1 + overlap), increasing cross-node intersections.
///
/// Sampling is without replacement *within* a node and independent across
/// nodes, so intersections arise naturally and grow with `overlap`.
pub fn partition_corpus(
    ds: &SyntheticDataset,
    specs: &[NodeCorpusSpec],
    overlap: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    let nd = ds.num_domains();
    let by_domain: Vec<Vec<usize>> = (0..nd).map(|d| ds.docs_of_domain(d)).collect();

    let mut result = Vec::with_capacity(specs.len());
    for (ni, spec) in specs.iter().enumerate() {
        let mut node_rng = rng.fork(ni as u64 + 101);
        let budget = ((spec.docs as f64) * (1.0 + overlap)).round() as usize;
        let wsum: f64 = spec.domain_weights.iter().sum();
        let mut docs: Vec<usize> = Vec::with_capacity(budget);
        for d in 0..nd {
            let share = spec.domain_weights[d] / wsum;
            let want = ((budget as f64) * share).round() as usize;
            let pool = &by_domain[d];
            if pool.is_empty() || want == 0 {
                continue;
            }
            // sample `want` distinct docs (or the whole pool if smaller)
            let take = want.min(pool.len());
            let mut idx: Vec<usize> = (0..pool.len()).collect();
            node_rng.shuffle(&mut idx);
            docs.extend(idx[..take].iter().map(|&i| pool[i]));
        }
        docs.sort_unstable();
        docs.dedup();
        result.push(docs);
    }
    result
}

/// For each QA pair, the set of nodes whose corpus contains its gold doc.
/// (Used by the Oracle allocator and by tests.)
pub fn gold_locations(ds: &SyntheticDataset, node_docs: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let mut membership: Vec<Vec<bool>> = node_docs
        .iter()
        .map(|docs| {
            let mut m = vec![false; ds.documents.len()];
            for &d in docs {
                m[d] = true;
            }
            m
        })
        .collect();
    // (avoid realloc in loop)
    let out = ds
        .qa_pairs
        .iter()
        .map(|qa| {
            (0..node_docs.len())
                .filter(|&n| membership[n][qa.gold_doc])
                .collect()
        })
        .collect();
    membership.clear();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{build_dataset, domainqa_spec};

    fn dataset() -> SyntheticDataset {
        build_dataset(&domainqa_spec(30, 60), 3)
    }

    #[test]
    fn dual_weights_sum_to_one() {
        let s = NodeCorpusSpec::dual(100, 6, &[0, 1, 2], 0.3);
        let sum: f64 = s.domain_weights.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        // primaries get the non-iid share
        assert!(s.domain_weights[0] > s.domain_weights[5]);
        assert!((s.domain_weights[0] - (0.05 + 0.7 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn motivation_weights() {
        let s = NodeCorpusSpec::motivation(100, 3, 1, 0.6);
        assert!((s.domain_weights[1] - 0.6).abs() < 1e-9);
        assert!((s.domain_weights[0] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn partition_respects_mixture() {
        let ds = dataset();
        let specs = vec![
            NodeCorpusSpec::dual(200, 6, &[0, 1, 2], 0.2),
            NodeCorpusSpec::dual(200, 6, &[3, 4, 5], 0.2),
        ];
        let parts = partition_corpus(&ds, &specs, 0.0, 11);
        assert_eq!(parts.len(), 2);
        // node 0 should hold many more docs from domains 0-2 than 3-5
        let count = |docs: &[usize], lo: usize, hi: usize| {
            docs.iter()
                .filter(|&&d| {
                    let dom = ds.documents[d].domain;
                    dom >= lo && dom <= hi
                })
                .count()
        };
        assert!(count(&parts[0], 0, 2) > 3 * count(&parts[0], 3, 5));
        assert!(count(&parts[1], 3, 5) > 3 * count(&parts[1], 0, 2));
    }

    #[test]
    fn overlap_increases_intersection() {
        let ds = dataset();
        let specs = vec![
            NodeCorpusSpec::dual(150, 6, &[0, 1, 2], 0.4),
            NodeCorpusSpec::dual(150, 6, &[0, 1, 2], 0.4),
        ];
        let inter = |parts: &[Vec<usize>]| {
            parts[0]
                .iter()
                .filter(|d| parts[1].binary_search(d).is_ok())
                .count()
        };
        let lo = inter(&partition_corpus(&ds, &specs, 0.0, 13));
        let hi = inter(&partition_corpus(&ds, &specs, 0.8, 13));
        assert!(hi > lo, "overlap 0.8 ({hi}) should exceed 0.0 ({lo})");
    }

    #[test]
    fn gold_locations_correct() {
        let ds = dataset();
        let specs = vec![
            NodeCorpusSpec::dual(250, 6, &[0, 1, 2], 0.3),
            NodeCorpusSpec::dual(250, 6, &[3, 4, 5], 0.3),
        ];
        let parts = partition_corpus(&ds, &specs, 0.2, 17);
        let locs = gold_locations(&ds, &parts);
        assert_eq!(locs.len(), ds.qa_pairs.len());
        for (qa, nodes) in ds.qa_pairs.iter().zip(&locs) {
            for &n in nodes {
                assert!(parts[n].binary_search(&qa.gold_doc).is_ok());
            }
        }
        // most gold docs of domains 0-2 should live on node 0
        let d0_hits = ds
            .qa_pairs
            .iter()
            .zip(&locs)
            .filter(|(qa, nodes)| qa.domain < 3 && nodes.contains(&0))
            .count();
        let d0_total = ds.qa_pairs.iter().filter(|qa| qa.domain < 3).count();
        assert!(d0_hits as f64 / d0_total as f64 > 0.5);
    }

    #[test]
    fn no_duplicate_docs_within_node() {
        let ds = dataset();
        let specs = vec![NodeCorpusSpec::dual(300, 6, &[0, 1, 2], 0.5)];
        let parts = partition_corpus(&ds, &specs, 0.5, 19);
        let mut seen = std::collections::HashSet::new();
        for &d in &parts[0] {
            assert!(seen.insert(d), "duplicate doc {d}");
        }
    }
}
