//! Statistics and regression utilities.
//!
//! Provides the numeric machinery the schedulers rely on:
//! - descriptive stats (mean/std/percentiles) for latency reporting,
//! - ordinary least squares (capacity function `C_n(L) = k_n·L + b_n`,
//!   paper Eq. 12),
//! - multivariate linear least squares via normal equations + Gaussian
//!   elimination (latency surrogate fitting, paper Eq. 13 / Table I),
//! - RMSE / NRMSE model-selection criteria.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for empty input.
pub fn std(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile in [0, 100] by linear interpolation (like numpy's default).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Root mean square error between predictions and targets.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    (pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t).powi(2))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

/// RMSE normalized by the target range (the paper reports NRMSE %).
pub fn nrmse(pred: &[f64], target: &[f64]) -> f64 {
    let lo = target.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = target.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if hi <= lo {
        return 0.0;
    }
    rmse(pred, target) / (hi - lo)
}

/// Simple linear regression `y = k·x + b`; returns (k, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx < 1e-12 * n {
        return (0.0, my);
    }
    let k = sxy / sxx;
    (k, my - k * mx)
}

/// Solve the square linear system `A·x = b` in place by Gaussian
/// elimination with partial pivoting. Returns None if singular.
pub fn solve_linear(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    assert!(a.len() == n && a.iter().all(|r| r.len() == n));
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[piv][col].abs() {
                piv = row;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        // eliminate
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // back-substitute
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = b[row];
        for k in row + 1..n {
            s -= a[row][k] * x[k];
        }
        x[row] = s / a[row][row];
    }
    Some(x)
}

/// Linear least squares: find `w` minimizing ||X·w − y||² via the normal
/// equations `XᵀX·w = Xᵀy` with a small ridge term for conditioning.
///
/// `rows` are the feature vectors (one per sample).
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = rows.len();
    assert_eq!(n, y.len());
    if n == 0 {
        return None;
    }
    let d = rows[0].len();
    let mut xtx = vec![vec![0.0; d]; d];
    let mut xty = vec![0.0; d];
    for (row, &yi) in rows.iter().zip(y) {
        assert_eq!(row.len(), d);
        for i in 0..d {
            xty[i] += row[i] * yi;
            for j in i..d {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            xtx[i][j] = xtx[j][i];
        }
        xtx[i][i] += 1e-9; // ridge
    }
    solve_linear(&mut xtx, &mut xty)
}

/// Evaluate a fitted linear model on a feature row.
pub fn predict_linear(w: &[f64], row: &[f64]) -> f64 {
    w.iter().zip(row).map(|(a, b)| a * b).sum()
}

/// Exponential-moving-average smoother.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// A fresh smoother with weight `alpha` on each new observation.
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    /// Fold in an observation and return the updated average (the first
    /// observation seeds the average directly).
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }
    /// Current average, `None` before the first [`update`](Ema::update).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Online batch standardizer: `(x − μ)/(σ + c)` (paper Eq. 10).
pub fn standardize(xs: &[f64]) -> Vec<f64> {
    let m = mean(xs);
    let s = std(xs);
    let c = 1e-8;
    xs.iter().map(|x| (x - m) / (s + c)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.5 * x - 2.0).collect();
        let (k, b) = linreg(&xs, &ys);
        assert!((k - 3.5).abs() < 1e-9);
        assert!((b + 2.0).abs() < 1e-7);
    }

    #[test]
    fn linreg_noisy() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..500).map(|i| i as f64 / 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0 + r.normal() * 0.1).collect();
        let (k, b) = linreg(&xs, &ys);
        assert!((k - 2.0).abs() < 0.01, "k={k}");
        assert!((b - 1.0).abs() < 0.05, "b={b}");
    }

    #[test]
    fn solve_linear_3x3() {
        let mut a = vec![
            vec![2.0, 1.0, -1.0],
            vec![-3.0, -1.0, 2.0],
            vec![-2.0, 1.0, 2.0],
        ];
        let mut b = vec![8.0, -11.0, -3.0];
        let x = solve_linear(&mut a, &mut b).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
        assert!((x[2] + 1.0).abs() < 1e-9);
    }

    #[test]
    fn solve_linear_singular() {
        let mut a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        let mut b = vec![1.0, 2.0];
        assert!(solve_linear(&mut a, &mut b).is_none());
    }

    #[test]
    fn least_squares_recovers_quadratic() {
        let mut r = Rng::new(5);
        // y = 1.5 x^2 - 2 x + 0.5 with noise
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..300 {
            let x = r.range_f64(0.0, 5.0);
            rows.push(vec![x * x, x, 1.0]);
            ys.push(1.5 * x * x - 2.0 * x + 0.5 + 0.01 * r.normal());
        }
        let w = least_squares(&rows, &ys).unwrap();
        assert!((w[0] - 1.5).abs() < 0.01, "{w:?}");
        assert!((w[1] + 2.0).abs() < 0.05, "{w:?}");
        assert!((w[2] - 0.5).abs() < 0.05, "{w:?}");
    }

    #[test]
    fn rmse_nrmse() {
        let p = [1.0, 2.0, 3.0];
        let t = [1.0, 2.0, 5.0];
        assert!((rmse(&p, &t) - (4.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((nrmse(&p, &t) - (4.0f64 / 3.0).sqrt() / 4.0).abs() < 1e-12);
    }

    #[test]
    fn standardize_unit_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 10.0];
        let z = standardize(&xs);
        assert!(mean(&z).abs() < 1e-9);
        assert!((std(&z) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..40 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
