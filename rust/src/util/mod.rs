//! Hand-rolled substrates: RNG, statistics, JSON, TOML-subset config
//! parsing, logging, thread pool and timing utilities.
//!
//! The build environment is fully offline (only `xla` + `anyhow` are
//! vendored), so everything a production serving stack would normally pull
//! from crates.io lives here instead.

pub mod rng;
pub mod stats;
pub mod json;
pub mod toml;
pub mod logging;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
