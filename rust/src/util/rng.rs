//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — fast, high quality, and fully
//! reproducible across runs/platforms. Every stochastic component in the
//! system (corpus synthesis, workload generation, the serving simulator,
//! the schedulers' sampling steps) draws from an explicitly-seeded `Rng`
//! so experiments are replayable bit-for-bit.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per query / per node).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    ///
    /// `n` must be > 0: in release builds `below(0)` returns 0, which is
    /// **out of range** for an empty collection — a caller that indexes
    /// with the result panics (`pool[0]` on an empty slice). Debug builds
    /// assert so the misuse is caught in tests; release callers must
    /// guard emptiness themselves (see `workload::sample_slot_queries`).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "Rng::below(0): empty range has no elements to sample");
        // Lemire's multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Gamma(shape k, scale 1) — Marsaglia–Tsang for k >= 1, boost for k < 1.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet sample with concentration vector `alpha`.
    ///
    /// Used to synthesize skewed per-slot query-domain distributions
    /// (paper §V-A "Dirichlet sampling").
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a).max(1e-12)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly. `xs` must be non-empty: an empty
    /// slice panics (via the index) — debug builds assert first with a
    /// clearer message.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        debug_assert!(!xs.is_empty(), "Rng::choose on an empty slice");
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_simplex() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let p = r.dirichlet(&[0.3, 0.3, 0.3, 0.3]);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_skew_increases_with_small_alpha() {
        let mut r = Rng::new(17);
        // small alpha -> spiky distributions (max component near 1)
        let spiky: f64 = (0..200)
            .map(|_| {
                let p = r.dirichlet(&[0.05; 6]);
                p.iter().cloned().fold(0.0, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        let flat: f64 = (0..200)
            .map(|_| {
                let p = r.dirichlet(&[50.0; 6]);
                p.iter().cloned().fold(0.0, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        assert!(spiky > 0.8, "spiky={spiky}");
        assert!(flat < 0.3, "flat={flat}");
    }

    #[test]
    fn weighted_sampling_distribution() {
        let mut r = Rng::new(19);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        let f1 = counts[1] as f64 / 30_000.0;
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f1 - 0.3).abs() < 0.02);
        assert!((f2 - 0.6).abs() < 0.02);
    }

    #[test]
    fn gamma_positive_mean() {
        let mut r = Rng::new(23);
        let k = 2.5;
        let n = 20_000;
        let m = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
        assert!((m - k).abs() < 0.1, "mean={m} want≈{k}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(5);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
