//! TOML-subset parser for the config system.
//!
//! Supports the subset used by `configs/*.toml`: top-level key/values,
//! `[table]` and `[[array-of-tables]]` headers, sub-tables of array
//! elements (`[nodes.index]` attaches to the most recent `[[nodes]]`
//! entry, its keys stored dot-prefixed as `index.key`), strings, integers,
//! floats, booleans, and homogeneous inline arrays (including arrays of
//! strings).
//! Comments (`#`) and blank lines are ignored. This intentionally mirrors
//! the config style of frameworks like MaxText/vLLM without an external
//! dependency (offline build).

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A homogeneous inline array.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// The string, if this is a [`TomlValue::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric value as f64 (floats directly, ints widened).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    /// The integer, if this is a [`TomlValue::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    /// The integer cast to usize, if this is a [`TomlValue::Int`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().map(|x| x as usize)
    }
    /// The boolean, if this is a [`TomlValue::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is a [`TomlValue::Arr`].
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Array coerced element-wise to f64 (non-numeric elements dropped).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }
    /// Array coerced element-wise to strings (non-strings dropped).
    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr().map(|a| {
            a.iter()
                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                .collect()
        })
    }
}

/// One table: key → value.
pub type Table = BTreeMap<String, TomlValue>;

/// A parsed TOML document.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// Top-level (header-less) keys.
    pub root: Table,
    /// `[name]` tables.
    pub tables: BTreeMap<String, Table>,
    /// `[[name]]` arrays of tables, in file order.
    pub arrays: BTreeMap<String, Vec<Table>>,
}

impl TomlDoc {
    /// Parse a TOML-subset document (see module docs for the grammar).
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        // Where new key/values currently land.
        enum Cursor {
            Root,
            Table(String),
            Array(String),
            // sub-table of the last element of array .0; keys are
            // inserted with prefix .1 (e.g. "index.")
            ArraySub(String, String),
        }
        let mut cur = Cursor::Root;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.arrays.entry(name.clone()).or_default().push(Table::new());
                cur = Cursor::Array(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                match name.split_once('.') {
                    // `[arr.sub]` after a `[[arr]]`: sub-table of that entry
                    Some((head, rest)) if doc.arrays.contains_key(head) && !rest.is_empty() => {
                        cur = Cursor::ArraySub(head.to_string(), format!("{rest}."));
                    }
                    // any other dotted header keeps the old permissive
                    // behavior: a plain table literally named "a.b"
                    _ => {
                        doc.tables.entry(name.clone()).or_default();
                        cur = Cursor::Table(name);
                    }
                }
            } else if let Some(eq) = find_top_level_eq(&line) {
                let key = line[..eq].trim().to_string();
                let val = parse_value(line[eq + 1..].trim())
                    .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                let (table, key) = match &cur {
                    Cursor::Root => (&mut doc.root, key),
                    Cursor::Table(name) => (doc.tables.get_mut(name).unwrap(), key),
                    Cursor::Array(name) => {
                        (doc.arrays.get_mut(name).unwrap().last_mut().unwrap(), key)
                    }
                    Cursor::ArraySub(name, prefix) => (
                        doc.arrays.get_mut(name).unwrap().last_mut().unwrap(),
                        format!("{prefix}{key}"),
                    ),
                };
                table.insert(key, val);
            } else {
                return Err(format!("line {}: cannot parse '{line}'", lineno + 1));
            }
        }
        Ok(doc)
    }

    /// `table.key` lookup with root fallback.
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        if table.is_empty() {
            self.root.get(key)
        } else {
            self.tables.get(table).and_then(|t| t.get(key))
        }
    }

    /// `[[name]]` array-of-tables lookup; an absent array reads as empty.
    /// Dotted headers like `[[scenario.events]]` are stored under their
    /// literal name (`"scenario.events"`).
    pub fn array(&self, name: &str) -> &[Table] {
        match self.arrays.get(name) {
            Some(v) => v,
            None => &[],
        }
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    // number: int if it parses as i64 and has no '.', 'e'
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster config
seed = 42
name = "edge-cluster"  # inline comment
latency_slo_s = 15.0

[workload]
queries_per_slot = 2000
domains = ["sports", "law", "finance"]
dirichlet_alpha = 0.3

[[nodes]]
name = "node-a"
gpus = 1
primary_domains = [0, 1, 2]

[[nodes]]
name = "node-b"
gpus = 2
primary_domains = [3, 4, 5]
"#;

    #[test]
    fn parse_full_document() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.root["seed"].as_i64(), Some(42));
        assert_eq!(doc.root["name"].as_str(), Some("edge-cluster"));
        assert_eq!(doc.root["latency_slo_s"].as_f64(), Some(15.0));
        assert_eq!(doc.get("workload", "queries_per_slot").unwrap().as_usize(), Some(2000));
        assert_eq!(
            doc.get("workload", "domains").unwrap().as_str_vec().unwrap(),
            vec!["sports", "law", "finance"]
        );
        let nodes = &doc.arrays["nodes"];
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0]["name"].as_str(), Some("node-a"));
        assert_eq!(nodes[1]["gpus"].as_i64(), Some(2));
        assert_eq!(
            nodes[1]["primary_domains"].as_f64_vec().unwrap(),
            vec![3.0, 4.0, 5.0]
        );
    }

    #[test]
    fn ints_vs_floats() {
        let doc = TomlDoc::parse("a = 3\nb = 3.5\nc = 1e-2\n").unwrap();
        assert_eq!(doc.root["a"], TomlValue::Int(3));
        assert_eq!(doc.root["b"], TomlValue::Float(3.5));
        assert_eq!(doc.root["c"], TomlValue::Float(0.01));
    }

    #[test]
    fn string_with_hash_and_escape() {
        let doc = TomlDoc::parse(r#"s = "a # not comment \n b""#).unwrap();
        assert_eq!(doc.root["s"].as_str(), Some("a # not comment \n b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("this is not toml").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
    }

    #[test]
    fn array_sub_tables_attach_to_last_entry() {
        let text = r#"
[[nodes]]
name = "a"

[nodes.index]
kind = "ivf"
nlist = 32

[[nodes]]
name = "b"

[nodes.index]
kind = "sharded-flat"
shards = 8
"#;
        let doc = TomlDoc::parse(text).unwrap();
        let nodes = &doc.arrays["nodes"];
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0]["index.kind"].as_str(), Some("ivf"));
        assert_eq!(nodes[0]["index.nlist"].as_usize(), Some(32));
        assert_eq!(nodes[1]["index.kind"].as_str(), Some("sharded-flat"));
        assert_eq!(nodes[1]["index.shards"].as_usize(), Some(8));
        assert!(!nodes[1].contains_key("index.nlist"));
    }

    #[test]
    fn dotted_header_without_array_stays_a_plain_table() {
        // backward compat: dotted headers with no matching [[array]] parse
        // as a table literally named "a.b" (harmlessly ignored downstream)
        let doc = TomlDoc::parse("[nodes.index]\nkind = \"flat\"\n").unwrap();
        assert_eq!(doc.get("nodes.index", "kind").unwrap().as_str(), Some("flat"));
        assert!(doc.arrays.is_empty());
    }

    /// The scenario layer's schema: a `[scenario]` table, a
    /// `[scenario.trace]` sub-table (plain table under its literal dotted
    /// name), and `[[scenario.events]]` dotted array-of-tables headers.
    #[test]
    fn dotted_array_of_tables_headers() {
        let text = r#"
[scenario]
name = "churn"

[scenario.trace]
base = 50

[[scenario.events]]
slot = 2
kind = "node-down"
node = 1

[[scenario.events]]
slot = 5
kind = "node-up"
node = 1
"#;
        let doc = TomlDoc::parse(text).unwrap();
        assert_eq!(doc.get("scenario", "name").unwrap().as_str(), Some("churn"));
        assert_eq!(doc.get("scenario.trace", "base").unwrap().as_usize(), Some(50));
        let events = doc.array("scenario.events");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0]["kind"].as_str(), Some("node-down"));
        assert_eq!(events[1]["slot"].as_usize(), Some(5));
        // absent arrays read as empty, not None
        assert!(doc.array("nodes").is_empty());
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = doc.root["m"].as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_f64_vec().unwrap(), vec![3.0, 4.0]);
    }
}
