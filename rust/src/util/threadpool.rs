//! Fixed-size thread pool with a scoped parallel-for helper.
//!
//! tokio is unavailable offline, so the serving front-end and the parallel
//! per-node retrieval/generation paths run on this pool: a classic
//! channel-of-boxed-closures design with panic isolation per job.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn `size` workers (clamped to ≥1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("coedge-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // Panic isolation: a panicking job must not
                                // take the worker down.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for each `i in 0..n` across `threads` scoped threads and
/// collect results in index order. Uses `std::thread::scope`, so `f` may
/// borrow from the caller.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        pool.execute(|| panic!("boom"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(1000, 8, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_borrows() {
        let data: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let out = parallel_map(64, 4, |i| data[i] * 2.0);
        assert_eq!(out[63], 126.0);
    }
}
