//! Wall-clock timing helpers for benches and the perf pass.

use std::time::Instant;

/// A simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
    /// Elapsed microseconds.
    pub fn us(&self) -> f64 {
        self.secs() * 1e6
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
        assert!(t.ms() >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
