//! Minimal JSON value model, serializer and recursive-descent parser.
//!
//! Used for: the AOT artifact manifest (`artifacts/manifest.json`), bench
//! result dumps, the TCP serving protocol (`server/`), and persisted
//! policy checkpoints. Supports the full JSON grammar we emit (objects,
//! arrays, strings with escapes, f64 numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object; keys sorted (BTreeMap) for deterministic serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Numeric array from an f64 slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    /// String array from a string slice.
    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    /// The number, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// The number truncated to usize, if this is a [`Json::Num`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// The string, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key→value map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            o.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::Str("coedge".into())),
            ("n", Json::Num(4.0)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::arr_f64(&[1.0, 2.5, -3.0])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[{"b":1e-3},{"c":"x\ny"}],"d":{"e":[]}}"#).unwrap();
        assert!((v.get("a").unwrap().as_arr().unwrap()[0]
            .get("b")
            .unwrap()
            .as_f64()
            .unwrap()
            - 1e-3)
            .abs()
            < 1e-12);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("c").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" back\\ nl\n tab\t unicode ✓";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
