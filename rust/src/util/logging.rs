//! Leveled logger with wall-clock timestamps relative to process start.
//!
//! A tiny `log`-crate-free logger: level filtering via `COEDGE_LOG`
//! (error|warn|info|debug|trace) or programmatic `set_level`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered most- to least-severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or surprising failures.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Per-step diagnostic detail.
    Debug = 3,
    /// Fire-hose tracing.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Initialize from the `COEDGE_LOG` env var (call once at startup).
pub fn init_from_env() {
    let lvl = match std::env::var("COEDGE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
    let _ = start();
}

/// Set the global log level (process-wide).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether messages at `l` currently pass the level filter.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one line to stderr (no-op when `l` is filtered out). Prefer the
/// [`log_info!`](macro@crate::log_info)-family macros, which fill in the
/// module path.
pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

/// Log a `format!`-style message at [`Level::Info`].
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}
/// Log a `format!`-style message at [`Level::Warn`].
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}
/// Log a `format!`-style message at [`Level::Error`].
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}
/// Log a `format!`-style message at [`Level::Debug`].
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
