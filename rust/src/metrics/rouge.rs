//! ROUGE-N and ROUGE-L (Lin, 2004).
//!
//! ROUGE-N here is the F1 variant over clipped n-gram counts (the common
//! modern convention, e.g. google-research rouge_scorer). ROUGE-L is the
//! LCS-based F-measure.

use std::collections::HashMap;

/// Clipped n-gram overlap F1 between candidate and reference.
pub fn rouge_n(gen: &[String], refr: &[String], n: usize) -> f64 {
    if gen.len() < n || refr.len() < n || n == 0 {
        return 0.0;
    }
    fn count<'a>(toks: &'a [String], n: usize) -> HashMap<&'a [String], usize> {
        let mut m: HashMap<&[String], usize> = HashMap::new();
        for i in 0..=toks.len() - n {
            *m.entry(&toks[i..i + n]).or_insert(0) += 1;
        }
        m
    }
    let gc = count(gen, n);
    let rc = count(refr, n);
    let overlap: usize = gc
        .iter()
        .map(|(k, &v)| v.min(rc.get(k).copied().unwrap_or(0)))
        .sum();
    let gen_total = gen.len() - n + 1;
    let ref_total = refr.len() - n + 1;
    let p = overlap as f64 / gen_total as f64;
    let r = overlap as f64 / ref_total as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Longest common subsequence length, O(|a|·|b|) time, O(min) memory
/// (rolling single row — hot path for both ROUGE-L and the PPO feedback).
pub fn lcs_len(a: &[String], b: &[String]) -> usize {
    let (short, long) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if short.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; short.len() + 1];
    let mut cur = vec![0usize; short.len() + 1];
    for lt in long {
        for (j, st) in short.iter().enumerate() {
            cur[j + 1] = if lt == st {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[short.len()]
}

/// ROUGE-L F-measure (β=1): `2PR/(P+R)` with `P = LCS/|gen|`,
/// `R = LCS/|ref|`.
pub fn rouge_l(gen: &[String], refr: &[String]) -> f64 {
    if gen.is_empty() || refr.is_empty() {
        return 0.0;
    }
    let l = lcs_len(gen, refr) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / gen.len() as f64;
    let r = l / refr.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::tokenize;

    fn t(s: &str) -> Vec<String> {
        tokenize(s)
    }

    #[test]
    fn lcs_known_values() {
        assert_eq!(lcs_len(&t("a b c d"), &t("a c d")), 3);
        assert_eq!(lcs_len(&t("a b c"), &t("x y z")), 0);
        assert_eq!(lcs_len(&t("a b c"), &t("a b c")), 3);
        assert_eq!(lcs_len(&t(""), &t("a")), 0);
        // classic: ABCBDAB vs BDCABA -> 4 (BDAB / BCAB / BCBA)
        let a: Vec<String> = "A B C B D A B".split(' ').map(|s| s.into()).collect();
        let b: Vec<String> = "B D C A B A".split(' ').map(|s| s.into()).collect();
        assert_eq!(lcs_len(&a, &b), 4);
    }

    #[test]
    fn lcs_symmetric() {
        let a = t("p q r s t u");
        let b = t("q s u w");
        assert_eq!(lcs_len(&a, &b), lcs_len(&b, &a));
    }

    #[test]
    fn rouge1_hand_computed() {
        // gen: [a b c], ref: [a b d]; overlap 2, P=2/3, R=2/3 -> F1=2/3
        let f = rouge_n(&t("a b c"), &t("a b d"), 1);
        assert!((f - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn rouge2_hand_computed() {
        // gen bigrams: [a b, b c]; ref: [a b, b d] -> overlap 1, P=R=1/2
        let f = rouge_n(&t("a b c"), &t("a b d"), 2);
        assert!((f - 0.5).abs() < 1e-9);
    }

    #[test]
    fn rouge_n_clipping() {
        // "a a a" vs "a": unclipped would give overlap 3; clipped = 1
        let f = rouge_n(&t("a a a"), &t("a"), 1);
        let p = 1.0 / 3.0;
        let r = 1.0;
        assert!((f - 2.0 * p * r / (p + r)).abs() < 1e-9);
    }

    #[test]
    fn rouge_l_hand_computed() {
        // gen: [the cat sat], ref: [the cat on the mat]; LCS=2
        // P=2/3, R=2/5 -> F=2*P*R/(P+R)=0.5
        let f = rouge_l(&t("the cat sat"), &t("the cat on the mat"));
        assert!((f - 0.5) < 1e-9);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(rouge_n(&t(""), &t("a b"), 1), 0.0);
        assert_eq!(rouge_l(&t(""), &t("a b")), 0.0);
        assert_eq!(rouge_n(&t("a"), &t("a b"), 2), 0.0); // too short for bigrams
    }
}
