//! Generation-quality metrics, implemented from scratch:
//! ROUGE-1/2/L, BLEU-4, METEOR (unigram variant), and BERTScore over the
//! deterministic contextual token embeddings from [`crate::text::embed`].
//!
//! Also provides the paper's composite feedback signal
//! `f_i = α₁·f_R + α₂·f_B` (Eq. 9) with the paper's LCS-based lexical term
//! `f_R = LCS(REF,GEN)/max(|REF|,|GEN|)`.

pub mod rouge;
pub mod bleu;
pub mod meteor;
pub mod bertscore;

pub use bertscore::bert_score;
pub use bleu::bleu4;
pub use meteor::meteor;
pub use rouge::{lcs_len, rouge_l, rouge_n};

use crate::text::embed::Embedder;
use crate::text::tokenizer::tokenize;

/// All six quality metrics for one (generated, reference) pair.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QualityScores {
    pub rouge1: f64,
    pub rouge2: f64,
    pub rouge_l: f64,
    pub bleu4: f64,
    pub meteor: f64,
    pub bert_score: f64,
}

impl QualityScores {
    pub fn zeros() -> Self {
        Self::default()
    }

    /// Component-wise mean over a set of scores (drops nothing; dropped
    /// queries should be included as zeros per the paper's "invalid"
    /// handling).
    pub fn mean(scores: &[QualityScores]) -> QualityScores {
        if scores.is_empty() {
            return QualityScores::default();
        }
        let n = scores.len() as f64;
        let mut acc = QualityScores::default();
        for s in scores {
            acc.rouge1 += s.rouge1;
            acc.rouge2 += s.rouge2;
            acc.rouge_l += s.rouge_l;
            acc.bleu4 += s.bleu4;
            acc.meteor += s.meteor;
            acc.bert_score += s.bert_score;
        }
        QualityScores {
            rouge1: acc.rouge1 / n,
            rouge2: acc.rouge2 / n,
            rouge_l: acc.rouge_l / n,
            bleu4: acc.bleu4 / n,
            meteor: acc.meteor / n,
            bert_score: acc.bert_score / n,
        }
    }
}

/// Metric evaluator bundling the shared tokenizer + embedder.
#[derive(Clone, Debug, Default)]
pub struct Evaluator {
    embedder: Embedder,
}

impl Evaluator {
    pub fn new(embedder: Embedder) -> Self {
        Evaluator { embedder }
    }

    /// Score a generated text against a reference (both raw strings).
    pub fn score(&self, generated: &str, reference: &str) -> QualityScores {
        let gen = tokenize(generated);
        let refr = tokenize(reference);
        self.score_tokens(&gen, &refr)
    }

    /// Score pre-tokenized texts.
    pub fn score_tokens(&self, gen: &[String], refr: &[String]) -> QualityScores {
        QualityScores {
            rouge1: rouge_n(gen, refr, 1),
            rouge2: rouge_n(gen, refr, 2),
            rouge_l: rouge_l(gen, refr),
            bleu4: bleu4(gen, refr),
            meteor: meteor(gen, refr),
            bert_score: bert_score(&self.embedder, gen, refr),
        }
    }

    /// The paper's composite feedback (Eq. 9):
    /// `f = α₁·LCS/max(|REF|,|GEN|) + α₂·BERTScore`, with the paper's
    /// weights α₁=1, α₂=0.5 by default.
    pub fn feedback(&self, gen: &[String], refr: &[String], a1: f64, a2: f64) -> f64 {
        let f_r = if gen.is_empty() || refr.is_empty() {
            0.0
        } else {
            lcs_len(gen, refr) as f64 / gen.len().max(refr.len()) as f64
        };
        let f_b = bert_score(&self.embedder, gen, refr);
        a1 * f_r + a2 * f_b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        tokenize(s)
    }

    #[test]
    fn perfect_match_scores_high() {
        let ev = Evaluator::default();
        let s = ev.score("alpha beta gamma delta", "alpha beta gamma delta");
        assert!((s.rouge1 - 1.0).abs() < 1e-9);
        assert!((s.rouge2 - 1.0).abs() < 1e-9);
        assert!((s.rouge_l - 1.0).abs() < 1e-9);
        assert!(s.bleu4 > 0.99);
        assert!(s.meteor > 0.99);
        assert!(s.bert_score > 0.99);
    }

    #[test]
    fn disjoint_scores_low() {
        let ev = Evaluator::default();
        let s = ev.score("aaa bbb ccc ddd", "www xxx yyy zzz");
        assert!(s.rouge1 < 1e-9);
        assert!(s.rouge_l < 1e-9);
        assert!(s.bleu4 < 0.05);
        assert!(s.meteor < 1e-9);
        assert!(s.bert_score < 0.5);
    }

    #[test]
    fn monotone_in_overlap() {
        let ev = Evaluator::default();
        let r = "one two three four five six seven eight";
        let half = ev.score("one two three four junk1 junk2 junk3 junk4", r);
        let full = ev.score(r, r);
        let none = ev.score("a b c d e f g h", r);
        for (lo, mid, hi) in [
            (none.rouge1, half.rouge1, full.rouge1),
            (none.rouge_l, half.rouge_l, full.rouge_l),
            (none.bert_score, half.bert_score, full.bert_score),
            (none.meteor, half.meteor, full.meteor),
        ] {
            assert!(lo < mid && mid < hi, "{lo} {mid} {hi}");
        }
    }

    #[test]
    fn feedback_matches_paper_form() {
        let ev = Evaluator::default();
        let g = toks("a b c d");
        let r = toks("a b x y");
        // LCS = 2, max len = 4 -> f_R = 0.5
        let f = ev.feedback(&g, &r, 1.0, 0.0);
        assert!((f - 0.5).abs() < 1e-9);
        // adding BERT term increases it
        let f2 = ev.feedback(&g, &r, 1.0, 0.5);
        assert!(f2 > f);
    }

    #[test]
    fn mean_aggregation() {
        let a = QualityScores { rouge1: 1.0, ..Default::default() };
        let b = QualityScores { rouge1: 0.0, ..Default::default() };
        let m = QualityScores::mean(&[a, b]);
        assert!((m.rouge1 - 0.5).abs() < 1e-12);
        assert_eq!(QualityScores::mean(&[]), QualityScores::default());
    }
}
