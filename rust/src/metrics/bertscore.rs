//! BERTScore (Zhang et al., 2020) over deterministic contextual token
//! embeddings (see `text::embed::Embedder::token_embeddings`).
//!
//! Greedy matching, exactly the paper's Eq. for Prec/Rec:
//!   Prec = 1/|GEN| Σ_k max_j sim(E(GEN)_k, E(REF)_j)
//!   Rec  = 1/|REF| Σ_j max_k sim(E(REF)_j, E(GEN)_k)
//!   F    = 2·Prec·Rec/(Prec+Rec)
//!
//! Raw cosine similarities of random token pairs are near 0 here (unlike
//! RoBERTa's ~0.4 baseline), so scores are *rescaled-like* by construction;
//! absolute values differ from HuggingFace BERTScore but the ordering and
//! monotonicity in generation fidelity are preserved (DESIGN.md §5).

use crate::text::embed::{dot, Embedder};

/// BERTScore F1 between token sequences.
pub fn bert_score(embedder: &Embedder, gen: &[String], refr: &[String]) -> f64 {
    if gen.is_empty() || refr.is_empty() {
        return 0.0;
    }
    let ge = embedder.token_embeddings(gen);
    let re = embedder.token_embeddings(refr);
    let (p, r) = precision_recall(&ge, &re);
    if p + r <= 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// (Prec over gen, Rec over ref) from embedding matrices.
pub fn precision_recall(ge: &[Vec<f32>], re: &[Vec<f32>]) -> (f64, f64) {
    // single pass over the similarity matrix, tracking row & col maxima
    let mut row_max = vec![f32::NEG_INFINITY; ge.len()];
    let mut col_max = vec![f32::NEG_INFINITY; re.len()];
    for (i, g) in ge.iter().enumerate() {
        for (j, r) in re.iter().enumerate() {
            let s = dot(g, r);
            if s > row_max[i] {
                row_max[i] = s;
            }
            if s > col_max[j] {
                col_max[j] = s;
            }
        }
    }
    let p = row_max.iter().map(|&x| x.max(0.0) as f64).sum::<f64>() / ge.len() as f64;
    let r = col_max.iter().map(|&x| x.max(0.0) as f64).sum::<f64>() / re.len() as f64;
    (p, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::tokenize;

    fn t(s: &str) -> Vec<String> {
        tokenize(s)
    }

    #[test]
    fn identical_near_one() {
        let e = Embedder::default();
        let x = t("alpha beta gamma delta epsilon");
        let s = bert_score(&e, &x, &x);
        assert!(s > 0.999, "s={s}");
    }

    #[test]
    fn unrelated_low() {
        let e = Embedder::default();
        let s = bert_score(&e, &t("qqq www eee rrr"), &t("zzz xxx ccc vvv"));
        assert!(s < 0.5, "s={s}");
    }

    #[test]
    fn monotone_in_token_overlap() {
        let e = Embedder::default();
        let r = t("one two three four five six seven eight");
        let s25 = bert_score(&e, &t("one two junk1 junk2 junk3 junk4 junk5 junk6"), &r);
        let s50 = bert_score(&e, &t("one two three four junk1 junk2 junk3 junk4"), &r);
        let s75 = bert_score(&e, &t("one two three four five six junk1 junk2"), &r);
        assert!(s25 < s50 && s50 < s75, "{s25} {s50} {s75}");
    }

    #[test]
    fn symmetric_f1() {
        let e = Embedder::default();
        let a = t("a b c d e");
        let b = t("a b x y z");
        let s1 = bert_score(&e, &a, &b);
        let s2 = bert_score(&e, &b, &a);
        assert!((s1 - s2).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        let e = Embedder::default();
        assert_eq!(bert_score(&e, &t(""), &t("a")), 0.0);
        assert_eq!(bert_score(&e, &t("a"), &t("")), 0.0);
    }
}
