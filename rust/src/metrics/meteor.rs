//! METEOR (Banerjee & Lavie, 2005) — exact-match unigram variant.
//!
//! Full METEOR adds stemming/synonym stages backed by WordNet; offline we
//! implement the exact-match core, which is the dominant term on our
//! synthetic vocabulary (there are no inflections to stem). Keeps the
//! canonical harmonic mean F_α (α = 0.9 ⇒ recall-weighted) and the
//! fragmentation penalty `0.5·(chunks/matches)³`.

use std::collections::HashMap;

/// METEOR score of a candidate against a single reference.
pub fn meteor(gen: &[String], refr: &[String]) -> f64 {
    if gen.is_empty() || refr.is_empty() {
        return 0.0;
    }
    // Greedy left-to-right alignment of exact matches: for each gen token
    // consume the earliest unused matching ref position (standard first
    // stage of METEOR's alignment search).
    let mut ref_positions: HashMap<&String, Vec<usize>> = HashMap::new();
    for (j, t) in refr.iter().enumerate() {
        ref_positions.entry(t).or_default().push(j);
    }
    let mut used = vec![false; refr.len()];
    // alignment[i] = matched reference index for gen token i
    let mut alignment: Vec<Option<usize>> = vec![None; gen.len()];
    for (i, t) in gen.iter().enumerate() {
        if let Some(positions) = ref_positions.get(t) {
            if let Some(&j) = positions.iter().find(|&&j| !used[j]) {
                used[j] = true;
                alignment[i] = Some(j);
            }
        }
    }
    let matches = alignment.iter().flatten().count();
    if matches == 0 {
        return 0.0;
    }
    let p = matches as f64 / gen.len() as f64;
    let r = matches as f64 / refr.len() as f64;
    // METEOR F-mean: 10PR / (R + 9P)
    let f_mean = 10.0 * p * r / (r + 9.0 * p);

    // Chunks: maximal runs of gen matches whose ref indices are contiguous
    // and increasing.
    let mut chunks = 0usize;
    let mut prev: Option<usize> = None;
    for a in &alignment {
        match (a, prev) {
            (Some(j), Some(pj)) if *j == pj + 1 => {}
            (Some(_), _) => chunks += 1,
            (None, _) => {}
        }
        prev = *a;
    }
    let penalty = 0.5 * (chunks as f64 / matches as f64).powi(3);
    f_mean * (1.0 - penalty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::tokenize;

    fn t(s: &str) -> Vec<String> {
        tokenize(s)
    }

    #[test]
    fn identical_high() {
        let x = t("the cat sat on the mat");
        let m = meteor(&x, &x);
        // one chunk, penalty = 0.5*(1/6)^3 ~ 0.0023
        assert!(m > 0.99, "m={m}");
    }

    #[test]
    fn disjoint_zero() {
        assert_eq!(meteor(&t("a b c"), &t("x y z")), 0.0);
    }

    #[test]
    fn fragmentation_penalized() {
        let r = t("one two three four five six");
        // same tokens, same counts, different order -> more chunks -> lower
        let contiguous = meteor(&t("one two three four five six"), &r);
        let fragmented = meteor(&t("two one four three six five"), &r);
        assert!(contiguous > fragmented, "{contiguous} vs {fragmented}");
    }

    #[test]
    fn recall_weighted() {
        let r = t("a b c d e f g h");
        // candidate covering more of the reference scores higher even with
        // the same precision
        let low_recall = meteor(&t("a b"), &r);
        let high_recall = meteor(&t("a b c d e f"), &r);
        assert!(high_recall > low_recall);
    }

    #[test]
    fn duplicate_tokens_matched_once() {
        // gen repeats "a" 3x but ref has one "a": only 1 match
        let m = meteor(&t("a a a"), &t("a"));
        let p = 1.0 / 3.0;
        let r = 1.0;
        let f = 10.0 * p * r / (r + 9.0 * p);
        let pen = 0.5; // 1 chunk / 1 match -> 0.5
        assert!((m - f * (1.0 - pen)).abs() < 1e-9, "m={m}");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(meteor(&t(""), &t("a")), 0.0);
        assert_eq!(meteor(&t("a"), &t("")), 0.0);
    }
}
