//! BLEU-4 (Papineni et al., 2002) with uniform 1–4-gram weights, clipped
//! precision, brevity penalty and "+1" smoothing on higher-order n-grams
//! (Lin & Och smoothing method 1 style) so short texts don't zero out.

use std::collections::HashMap;

fn ngram_counts(toks: &[String], n: usize) -> HashMap<&[String], usize> {
    let mut m: HashMap<&[String], usize> = HashMap::new();
    if toks.len() >= n && n > 0 {
        for i in 0..=toks.len() - n {
            *m.entry(&toks[i..i + n]).or_insert(0) += 1;
        }
    }
    m
}

/// BLEU with max order 4 against a single reference.
pub fn bleu4(gen: &[String], refr: &[String]) -> f64 {
    bleu(gen, refr, 4)
}

/// BLEU with configurable max n-gram order.
pub fn bleu(gen: &[String], refr: &[String], max_n: usize) -> f64 {
    if gen.is_empty() || refr.is_empty() {
        return 0.0;
    }
    let mut log_sum = 0.0;
    for n in 1..=max_n {
        let gc = ngram_counts(gen, n);
        let rc = ngram_counts(refr, n);
        let total: usize = gc.values().sum();
        let clipped: usize = gc
            .iter()
            .map(|(k, &v)| v.min(rc.get(k).copied().unwrap_or(0)))
            .sum();
        // smoothing: add 1 to numerator & denominator for n>1 when the
        // raw precision would be 0 (method-1-like); hard zero for n=1.
        let p = if n == 1 {
            if total == 0 || clipped == 0 {
                return 0.0;
            }
            clipped as f64 / total as f64
        } else {
            (clipped as f64 + if clipped == 0 { 1.0 } else { 0.0 })
                / (total as f64 + if clipped == 0 { 1.0 } else { 0.0 }).max(1.0)
        };
        log_sum += p.ln() / max_n as f64;
    }
    let bp = if gen.len() >= refr.len() {
        1.0
    } else {
        (1.0 - refr.len() as f64 / gen.len() as f64).exp()
    };
    bp * log_sum.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::tokenize;

    fn t(s: &str) -> Vec<String> {
        tokenize(s)
    }

    #[test]
    fn identical_is_one() {
        let x = t("the quick brown fox jumps over the lazy dog today");
        assert!((bleu4(&x, &x) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(bleu4(&t("a b c d e"), &t("v w x y z")), 0.0);
    }

    #[test]
    fn brevity_penalty_applies() {
        let r = t("a b c d e f g h i j");
        let long_sub = t("a b c d e f g h");
        let short_sub = t("a b c d");
        let b_long = bleu4(&long_sub, &r);
        let b_short = bleu4(&short_sub, &r);
        assert!(b_long > b_short, "{b_long} vs {b_short}");
    }

    #[test]
    fn partial_overlap_between_zero_and_one() {
        let b = bleu4(&t("a b c d junk1 junk2"), &t("a b c d e f"));
        assert!(b > 0.0 && b < 1.0, "b={b}");
    }

    #[test]
    fn word_order_matters() {
        let r = t("one two three four five six");
        let ordered = bleu4(&t("one two three four five six"), &r);
        let scrambled = bleu4(&t("six four two five three one"), &r);
        assert!(ordered > scrambled + 0.3);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(bleu4(&t(""), &t("a")), 0.0);
        assert_eq!(bleu4(&t("a"), &t("")), 0.0);
    }
}
