//! Benchmark harness (criterion is unavailable offline): warmup +
//! repeated timing with mean/std/percentiles, table and series printers
//! shared by the paper-reproduction benches, and [`PhaseBreakdown`] — a
//! [`SlotObserver`] that accounts coordinator wall-time per phase live
//! instead of scraping `SlotReport`s afterwards.

use std::sync::{Arc, Mutex};

use crate::coordinator::observer::{SlotEvent, SlotObserver};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile, std};
use crate::util::timer::Timer;

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, items_per_iter: f64) -> String {
        format!(
            "{:<36} {:>10.3} ms/iter  (p50 {:.3}, p95 {:.3})  {:>12.0} items/s",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            items_per_iter / self.mean_s
        )
    }
}

/// Time `f` with warmup; chooses iteration count so total time ≈ budget.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        std_s: std(&samples),
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
    }
}

/// One case of a machine-readable bench dump: a name plus arbitrary
/// numeric fields (grid coordinates, rates, timings).
#[derive(Clone, Debug)]
pub struct BenchCase {
    pub name: String,
    pub fields: Vec<(&'static str, f64)>,
}

impl BenchCase {
    pub fn new(name: impl Into<String>) -> Self {
        BenchCase { name: name.into(), fields: Vec::new() }
    }

    pub fn field(mut self, key: &'static str, value: f64) -> Self {
        self.fields.push((key, value));
        self
    }

    /// Fold a timing summary in as `mean_s` / `p50_s` / `p95_s`.
    pub fn timing(self, r: &BenchResult) -> Self {
        self.field("mean_s", r.mean_s).field("p50_s", r.p50_s).field("p95_s", r.p95_s)
    }
}

/// Render a bench sweep as the `BENCH_<bench>.json` document text
/// (`{"bench": .., "cases": [{"name": .., <fields>...}, ..]}`,
/// newline-terminated). Key order and float formatting are deterministic,
/// so two identical sweeps serialize byte-identically — the `eval` grid
/// and its CI double-run diff rely on this.
pub fn bench_json(bench: &str, cases: &[BenchCase]) -> String {
    let json = Json::obj(vec![
        ("bench", Json::Str(bench.to_string())),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        let mut fields = vec![("name", Json::Str(c.name.clone()))];
                        fields.extend(c.fields.iter().map(|&(k, v)| (k, Json::Num(v))));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ]);
    json.to_string() + "\n"
}

/// Write a bench sweep as `BENCH_<bench>.json` in `dir` — the
/// machine-readable perf trajectory CI and notebooks can diff across
/// commits (see [`bench_json`] for the format). Returns the path written.
pub fn write_bench_json(
    dir: &std::path::Path,
    bench: &str,
    cases: &[BenchCase],
) -> std::io::Result<std::path::PathBuf> {
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, bench_json(bench, cases))?;
    Ok(path)
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len().max(8)).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(&cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn row_f(&mut self, label: &str, values: &[f64], decimals: usize) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.decimals$}")));
        self.row(cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("| {c:<w$} "));
            }
            s.push('|');
            s
        };
        let header = line(&self.headers, &self.widths);
        println!("{header}");
        println!("{}", "-".repeat(header.len()));
        for r in &self.rows {
            println!("{}", line(r, &self.widths));
        }
    }
}

/// Print an (x, series...) block for figure-style outputs, one line per x.
pub fn print_series(title: &str, x_label: &str, xs: &[f64], series: &[(&str, Vec<f64>)]) {
    println!("\n== {title} ==");
    let mut t = Table::new(
        &std::iter::once(x_label)
            .chain(series.iter().map(|(n, _)| *n))
            .collect::<Vec<_>>(),
    );
    for (i, &x) in xs.iter().enumerate() {
        let mut cells = vec![format!("{x}")];
        for (_, ys) in series {
            cells.push(format!("{:.4}", ys[i]));
        }
        t.row(cells);
    }
    t.print();
}

#[derive(Clone, Copy, Debug, Default)]
struct PhaseAccum {
    slots: usize,
    queries: usize,
    encode_s: f64,
    route_s: f64,
    serve_s: f64,
    feedback_s: f64,
}

/// Live per-phase wall-time accounting for the coordinator loop.
///
/// Clone one handle into the coordinator (`.observer(Box::new(pb.clone()))`)
/// and keep the other to [`print`](PhaseBreakdown::print) after the run —
/// both share the same accumulator.
#[derive(Clone, Default)]
pub struct PhaseBreakdown {
    inner: Arc<Mutex<PhaseAccum>>,
}

impl PhaseBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    /// (slots, queries) observed so far.
    pub fn totals(&self) -> (usize, usize) {
        let a = self.inner.lock().unwrap();
        (a.slots, a.queries)
    }

    /// Print mean per-slot phase timings as a table.
    pub fn print(&self) {
        let a = *self.inner.lock().unwrap();
        if a.slots == 0 {
            println!("(no slots observed)");
            return;
        }
        let n = a.slots as f64;
        let mut t = Table::new(&["phase", "mean ms/slot", "share %"]);
        let total = a.encode_s + a.route_s + a.serve_s + a.feedback_s;
        for (name, s) in [
            ("encode", a.encode_s),
            ("route", a.route_s),
            ("serve", a.serve_s),
            ("feedback", a.feedback_s),
        ] {
            t.row(vec![
                name.into(),
                format!("{:.3}", s / n * 1e3),
                format!("{:.1}", if total > 0.0 { s / total * 100.0 } else { 0.0 }),
            ]);
        }
        println!("phase breakdown over {} slots ({} queries):", a.slots, a.queries);
        t.print();
    }
}

impl SlotObserver for PhaseBreakdown {
    fn on_event(&mut self, event: &SlotEvent) {
        let mut a = self.inner.lock().unwrap();
        match event {
            SlotEvent::Encoded { elapsed_s, .. } => a.encode_s += elapsed_s,
            SlotEvent::Routed { elapsed_s, .. } => a.route_s += elapsed_s,
            SlotEvent::Served { elapsed_s, .. } => a.serve_s += elapsed_s,
            SlotEvent::Feedback { elapsed_s, .. } => a.feedback_s += elapsed_s,
            SlotEvent::SlotEnd { report, .. } => {
                a.slots += 1;
                a.queries += report.queries;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_breakdown_accumulates() {
        let pb = PhaseBreakdown::new();
        let mut handle = pb.clone();
        handle.on_event(&SlotEvent::Encoded { slot: 0, queries: 4, elapsed_s: 0.5 });
        let report = crate::coordinator::SlotReport { queries: 4, ..Default::default() };
        handle.on_event(&SlotEvent::SlotEnd { slot: 0, report: &report });
        assert_eq!(pb.totals(), (1, 4));
        pb.print();
    }

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn bench_json_roundtrips() {
        let dir = std::env::temp_dir();
        let cases = vec![
            BenchCase::new("lru 1200 chunks").field("corpus", 1200.0).field("hit_rate", 0.5),
            BenchCase::new("baseline").field("corpus", 1200.0),
        ];
        let path = write_bench_json(&dir, "cache_test", &cases).unwrap();
        assert!(path.ends_with("BENCH_cache_test.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("cache_test"));
        let arr = parsed.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("hit_rate").unwrap().as_f64(), Some(0.5));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn table_rows_align() {
        let mut t = Table::new(&["metric", "a", "b"]);
        t.row_f("rouge", &[0.5, 0.61234], 3);
        t.row(vec!["x".into(), "yy".into(), "zzz".into()]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][2], "0.612");
        t.print();
    }
}
