//! Ground-truth latency model (what the paper measures on real GPUs; the
//! intra-node scheduler only sees *samples* of it and fits the Eq. 13
//! quadratic surrogate).
//!
//! Throughput saturates in memory; batch latency adds a superlinear
//! contention term that grows when memory is tight — reproducing both
//! Fig. 3b regimes ("resource starvation in larger models" and
//! "underutilization of fast-response models").

use super::model::ModelSpec;
use crate::util::rng::Rng;

/// Ground-truth latency for one (model, GPU) pair.
#[derive(Clone, Debug)]
pub struct LatencyGroundTruth {
    /// GPU relative speed (heterogeneity across nodes).
    pub gpu_speed: f64,
    /// Measurement noise std as a fraction of the true latency.
    pub noise_frac: f64,
}

impl Default for LatencyGroundTruth {
    fn default() -> Self {
        LatencyGroundTruth { gpu_speed: 1.0, noise_frac: 0.02 }
    }
}

impl LatencyGroundTruth {
    pub fn new(gpu_speed: f64) -> Self {
        LatencyGroundTruth { gpu_speed, noise_frac: 0.02 }
    }

    /// Effective decode throughput (tokens/s) at memory fraction `r`.
    /// Saturating: ~45% of peak at min memory (weights resident, little KV
    /// headroom), ~100% at full memory — the response range vLLM shows
    /// between tight and generous gpu_memory_utilization settings.
    pub fn throughput(&self, m: &ModelSpec, r: f64) -> f64 {
        let r = r.clamp(m.min_mem, 1.0);
        let u = (r - m.min_mem) / (1.0 - m.min_mem);
        let sat = (1.0 - (-3.0 * u).exp()) / (1.0 - (-3.0f64).exp());
        m.tau_max * self.gpu_speed * (0.45 + 0.55 * sat)
    }

    /// True batch latency (seconds) for `q` queries at memory fraction `r`
    /// (noise-free).
    pub fn latency(&self, m: &ModelSpec, q: f64, r: f64) -> f64 {
        if q <= 0.0 {
            return 0.0;
        }
        let tau = self.throughput(m, r);
        let service = q * m.tokens_per_query / tau;
        // contention: superlinear in load, worse when memory is tight
        let contention = m.gamma * (q * m.tokens_per_query / tau / 10.0).powi(2) * (1.1 - r);
        0.05 + service + contention
    }

    /// Noisy measurement of the true latency.
    pub fn measure(&self, m: &ModelSpec, q: f64, r: f64, rng: &mut Rng) -> f64 {
        let l = self.latency(m, q, r);
        (l * (1.0 + self.noise_frac * rng.normal())).max(0.0)
    }

    /// Largest query count servable within `budget_s` at memory `r`
    /// (bisection on the monotone latency function).
    pub fn max_queries(&self, m: &ModelSpec, r: f64, budget_s: f64) -> f64 {
        if self.latency(m, 1.0, r) > budget_s {
            return 0.0;
        }
        let (mut lo, mut hi) = (1.0, 10.0);
        while self.latency(m, hi, r) < budget_s && hi < 1e7 {
            hi *= 2.0;
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.latency(m, mid, r) <= budget_s {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Vector-search time model TS_n^t: proportional to queries × log-ish
/// corpus size (flat exact search is linear, but per-query cost is tiny;
/// calibrated to ~0.2 ms per query per 1k chunks).
#[derive(Clone, Copy, Debug)]
pub struct SearchTimeModel {
    pub per_query_per_kchunk_s: f64,
}

impl Default for SearchTimeModel {
    fn default() -> Self {
        SearchTimeModel { per_query_per_kchunk_s: 2e-4 }
    }
}

impl SearchTimeModel {
    pub fn search_time(&self, queries: usize, corpus_chunks: usize) -> f64 {
        queries as f64 * self.per_query_per_kchunk_s * (corpus_chunks as f64 / 1000.0).max(0.1)
    }

    /// Refit the coefficient from a measured batched search, so TS_n^t can
    /// be driven by real index wall-clock instead of the synthetic default
    /// (EMA with factor `alpha`; `alpha = 1` replaces outright).
    pub fn calibrate(&mut self, queries: usize, corpus_chunks: usize, measured_s: f64, alpha: f64) {
        if queries == 0 || measured_s <= 0.0 {
            return;
        }
        let per = measured_s / (queries as f64 * (corpus_chunks as f64 / 1000.0).max(0.1));
        let a = alpha.clamp(0.0, 1.0);
        self.per_query_per_kchunk_s = (1.0 - a) * self.per_query_per_kchunk_s + a * per;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llmsim::model::standard_pool;

    #[test]
    fn latency_monotone_in_load() {
        let gt = LatencyGroundTruth::default();
        let pool = standard_pool();
        for m in &pool {
            let mut prev = 0.0;
            for q in [10.0, 50.0, 100.0, 200.0, 400.0] {
                let l = gt.latency(m, q, 0.8);
                assert!(l > prev, "{} q={q}", m.name);
                prev = l;
            }
        }
    }

    #[test]
    fn latency_decreasing_in_memory() {
        let gt = LatencyGroundTruth::default();
        let m = &standard_pool()[1];
        let l_lo = gt.latency(m, 200.0, m.min_mem + 0.05);
        let l_hi = gt.latency(m, 200.0, 0.95);
        assert!(l_lo > l_hi * 1.2, "{l_lo} vs {l_hi}");
    }

    #[test]
    fn bigger_models_slower() {
        let gt = LatencyGroundTruth::default();
        let pool = standard_pool();
        let l_small = gt.latency(&pool[0], 100.0, 0.9);
        let l_mid = gt.latency(&pool[1], 100.0, 0.9);
        let l_large = gt.latency(&pool[2], 100.0, 0.9);
        assert!(l_small < l_mid && l_mid < l_large);
    }

    #[test]
    fn per_query_scale_plausible() {
        // small model ~20-30 ms/query at moderate memory, large ~150-250 ms
        let gt = LatencyGroundTruth::default();
        let pool = standard_pool();
        let s = gt.latency(&pool[0], 100.0, 0.8) / 100.0;
        let l = gt.latency(&pool[2], 50.0, 0.8) / 50.0;
        assert!(s > 0.01 && s < 0.05, "small per-query {s}");
        assert!(l > 0.1 && l < 0.4, "large per-query {l}");
    }

    #[test]
    fn max_queries_respects_budget() {
        let gt = LatencyGroundTruth::default();
        let m = &standard_pool()[1];
        for budget in [2.0, 5.0, 10.0] {
            let q = gt.max_queries(m, 0.7, budget);
            assert!(gt.latency(m, q, 0.7) <= budget + 1e-6);
            assert!(gt.latency(m, q + 2.0, 0.7) > budget);
        }
    }

    #[test]
    fn max_queries_zero_when_budget_tiny() {
        let gt = LatencyGroundTruth::default();
        let m = &standard_pool()[2];
        assert_eq!(gt.max_queries(m, 0.5, 0.01), 0.0);
    }

    #[test]
    fn faster_gpu_lower_latency() {
        let m = &standard_pool()[1];
        let slow = LatencyGroundTruth::new(1.0);
        let fast = LatencyGroundTruth::new(1.5);
        assert!(fast.latency(m, 100.0, 0.8) < slow.latency(m, 100.0, 0.8));
    }

    #[test]
    fn measurement_noise_bounded() {
        let gt = LatencyGroundTruth::default();
        let m = &standard_pool()[0];
        let mut rng = Rng::new(3);
        let truth = gt.latency(m, 100.0, 0.8);
        let n = 200;
        let mean: f64 = (0..n).map(|_| gt.measure(m, 100.0, 0.8, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - truth).abs() / truth < 0.01);
    }

    #[test]
    fn search_time_scales() {
        let st = SearchTimeModel::default();
        assert!(st.search_time(1000, 2000) > st.search_time(1000, 1000));
        assert!(st.search_time(2000, 1000) > st.search_time(1000, 1000));
        // calibration with alpha=1 reproduces the measurement exactly
        let mut st = SearchTimeModel::default();
        st.calibrate(500, 4000, 0.8, 1.0);
        assert!((st.search_time(500, 4000) - 0.8).abs() < 1e-12);
    }
}
