//! GPU deployment state + reconfiguration cost accounting (paper Eq. 1–2
//! and the LD/RLD/ULD decomposition of §IV-C).
//!
//! Unloading is free; loading a previously-undeployed model costs l_m;
//! changing a persistent model's memory allocation forces a reload, also
//! l_m. Loads are serialized per GPU, so the slot's reconfiguration cost
//! is the sum over (re)loaded models — exactly Eq. 2 / Eq. 24.

use std::collections::BTreeMap;

/// Threshold below which a memory change is "no change" (the paper's ε₁).
pub const RESOURCE_EPS: f64 = 0.01;

/// A GPU's deployment state: model name → memory fraction.
#[derive(Clone, Debug, Default)]
pub struct GpuState {
    /// Relative speed factor (heterogeneity).
    pub speed: f64,
    /// Deployed models: name → memory fraction R ∈ (0, 1].
    pub deployed: BTreeMap<String, f64>,
}

impl GpuState {
    pub fn new(speed: f64) -> Self {
        GpuState { speed, deployed: BTreeMap::new() }
    }

    /// Total memory in use.
    pub fn used_mem(&self) -> f64 {
        self.deployed.values().sum()
    }

    /// Reconfiguration time to move to `target` given per-model load
    /// times. Implements:
    ///   ULD (unload):          free
    ///   LD  (fresh load):      l_m
    ///   RLD (resource change): l_m
    pub fn reconfig_time(
        &self,
        target: &BTreeMap<String, f64>,
        load_time: &dyn Fn(&str) -> f64,
    ) -> f64 {
        let mut t = 0.0;
        for (name, &r_new) in target {
            match self.deployed.get(name) {
                None => t += load_time(name), // LD
                Some(&r_old) => {
                    if (r_new - r_old).abs() > RESOURCE_EPS {
                        t += load_time(name); // RLD
                    }
                }
            }
        }
        // unloads (in self but not target) are free
        t
    }

    /// Apply a new deployment.
    pub fn apply(&mut self, target: BTreeMap<String, f64>) {
        self.deployed = target;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lt(name: &str) -> f64 {
        match name {
            "small" => 1.0,
            "mid" => 2.0,
            "large" => 4.0,
            _ => 0.0,
        }
    }

    fn map(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(n, r)| (n.to_string(), *r)).collect()
    }

    #[test]
    fn fresh_loads_charged() {
        let gpu = GpuState::new(1.0);
        let t = gpu.reconfig_time(&map(&[("small", 0.3), ("mid", 0.5)]), &lt);
        assert_eq!(t, 3.0);
    }

    #[test]
    fn unload_free() {
        let mut gpu = GpuState::new(1.0);
        gpu.apply(map(&[("small", 0.3), ("mid", 0.5)]));
        // drop mid entirely, keep small unchanged
        let t = gpu.reconfig_time(&map(&[("small", 0.3)]), &lt);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn resource_change_reloads() {
        let mut gpu = GpuState::new(1.0);
        gpu.apply(map(&[("small", 0.3), ("mid", 0.5)]));
        // grow small beyond eps, shrink mid beyond eps
        let t = gpu.reconfig_time(&map(&[("small", 0.5), ("mid", 0.4)]), &lt);
        assert_eq!(t, 3.0);
    }

    #[test]
    fn tiny_changes_ignored() {
        let mut gpu = GpuState::new(1.0);
        gpu.apply(map(&[("small", 0.3)]));
        let t = gpu.reconfig_time(&map(&[("small", 0.3 + RESOURCE_EPS * 0.5)]), &lt);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn swap_charges_only_load() {
        let mut gpu = GpuState::new(1.0);
        gpu.apply(map(&[("small", 1.0)]));
        // replace small with large: unload free + load large
        let t = gpu.reconfig_time(&map(&[("large", 1.0)]), &lt);
        assert_eq!(t, 4.0);
    }

    #[test]
    fn used_mem_sums() {
        let mut gpu = GpuState::new(1.0);
        gpu.apply(map(&[("a", 0.25), ("b", 0.5)]));
        assert!((gpu.used_mem() - 0.75).abs() < 1e-12);
    }
}
