//! Simulated generation: token-level noisy copy of the reference answer.
//!
//! Fidelity φ = q_m · (0.35 + 0.65·rel): the model's intrinsic capability
//! scaled by retrieval relevance. Each reference token is copied with
//! probability φ; otherwise it is substituted with a random domain-vocab
//! token (60%), dropped (25%) or duplicated (15%) — the classic error
//! modes of a weakly-grounded LLM. All quality metrics are then *actually
//! computed* on the result, so ROUGE/BLEU/METEOR/BERTScore respond to
//! routing, retrieval and model size exactly as in the paper's pipeline.

use super::model::ModelSpec;
use crate::corpus::synth::{QaPair, SyntheticDataset};
use crate::util::rng::Rng;

/// Retrieval relevance → fidelity (exposed for tests/calibration).
pub fn fidelity(model: &ModelSpec, rel: f64) -> f64 {
    (model.quality * (0.35 + 0.65 * rel.clamp(0.0, 1.0))).clamp(0.0, 1.0)
}

/// Generate an answer for `qa` given retrieval relevance `rel` ∈ [0,1].
/// Deterministic per (qa.id, model, rng stream).
pub fn generate(
    ds: &SyntheticDataset,
    qa: &QaPair,
    model: &ModelSpec,
    rel: f64,
    rng: &mut Rng,
) -> Vec<String> {
    let phi = fidelity(model, rel);
    let vocab = &ds.domain_vocab[qa.domain];
    let mut out = Vec::with_capacity(qa.answer_tokens.len());
    for tok in &qa.answer_tokens {
        if rng.chance(phi) {
            out.push(tok.clone());
        } else {
            let roll = rng.f64();
            if roll < 0.60 {
                // substitution with a plausible same-domain token
                out.push(vocab[rng.below(vocab.len())].clone());
            } else if roll < 0.85 {
                // drop
            } else {
                // duplicate previous (or substitute if none)
                if let Some(prev) = out.last().cloned() {
                    out.push(prev);
                } else {
                    out.push(vocab[rng.below(vocab.len())].clone());
                }
            }
        }
    }
    if out.is_empty() {
        out.push(vocab[rng.below(vocab.len())].clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{build_dataset, domainqa_spec};
    use crate::llmsim::model::standard_pool;
    use crate::metrics::Evaluator;

    #[test]
    fn fidelity_bounds_and_monotonicity() {
        let pool = standard_pool();
        for m in &pool {
            assert!(fidelity(m, 0.0) > 0.2);
            assert!(fidelity(m, 1.0) <= 1.0);
            assert!(fidelity(m, 1.0) > fidelity(m, 0.3));
        }
        // larger model, same rel -> higher fidelity
        assert!(fidelity(&pool[2], 0.7) > fidelity(&pool[0], 0.7));
    }

    #[test]
    fn quality_responds_to_relevance_and_model() {
        let ds = build_dataset(&domainqa_spec(30, 40), 5);
        let ev = Evaluator::default();
        let pool = standard_pool();
        let mut rng = Rng::new(17);
        let qa_sample: Vec<_> = ds.qa_pairs.iter().take(40).collect();

        let mean_rouge = |model: &ModelSpec, rel: f64, rng: &mut Rng| -> f64 {
            let scores: Vec<f64> = qa_sample
                .iter()
                .map(|qa| {
                    let gen = generate(&ds, qa, model, rel, rng);
                    crate::metrics::rouge::rouge_l(&gen, &qa.answer_tokens)
                })
                .collect();
            crate::util::stats::mean(&scores)
        };

        let small_good = mean_rouge(&pool[0], 1.0, &mut rng);
        let small_bad = mean_rouge(&pool[0], 0.1, &mut rng);
        let large_good = mean_rouge(&pool[2], 1.0, &mut rng);
        assert!(small_good > small_bad + 0.15, "{small_good} vs {small_bad}");
        assert!(large_good > small_good + 0.1, "{large_good} vs {small_good}");
        // composite feedback behaves the same
        let qa = qa_sample[0];
        let g_good = generate(&ds, qa, &pool[2], 1.0, &mut rng);
        let g_bad = generate(&ds, qa, &pool[0], 0.0, &mut rng);
        let f_good = ev.feedback(&g_good, &qa.answer_tokens, 1.0, 0.5);
        let f_bad = ev.feedback(&g_bad, &qa.answer_tokens, 1.0, 0.5);
        assert!(f_good > f_bad);
    }

    #[test]
    fn generation_never_empty() {
        let ds = build_dataset(&domainqa_spec(5, 10), 9);
        let pool = standard_pool();
        let mut rng = Rng::new(2);
        for qa in ds.qa_pairs.iter().take(10) {
            let g = generate(&ds, qa, &pool[0], 0.0, &mut rng);
            assert!(!g.is_empty());
        }
    }

    #[test]
    fn perfect_fidelity_reproduces_reference() {
        let ds = build_dataset(&domainqa_spec(5, 10), 9);
        let mut m = standard_pool()[2].clone();
        m.quality = 1.0;
        // rel=1, quality=1 -> phi=1 -> exact copy
        let mut rng = Rng::new(4);
        let qa = &ds.qa_pairs[0];
        let g = generate(&ds, qa, &m, 1.0, &mut rng);
        assert_eq!(g, qa.answer_tokens);
    }
}
