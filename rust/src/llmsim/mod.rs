//! Edge-LLM serving simulator (substitute for vLLM + LLaMA/Qwen/Falcon on
//! RTX 4090s — DESIGN.md §5).
//!
//! The scheduler only ever observes (generated tokens, latency, drops);
//! this module produces all three with the monotonicities the paper
//! measures:
//! - bigger models ⇒ higher-fidelity generations but lower throughput,
//! - more GPU memory ⇒ higher throughput, saturating (Fig. 3b),
//! - overload ⇒ superlinear latency growth (Fig. 2, Fig. 3b),
//! - irrelevant retrieval ⇒ quality collapse (Fig. 1),
//! - model load/reload costs charged per Eq. 1–2 / 19–24 semantics.

pub mod model;
pub mod latency;
pub mod gen;
pub mod gpu;

pub use gen::generate;
pub use gpu::GpuState;
pub use latency::{LatencyGroundTruth, SearchTimeModel};
pub use model::{standard_pool, ModelSize, ModelSpec};
