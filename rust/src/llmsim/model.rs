//! Model catalog: parameter-efficient variants of LLaMA / Qwen / Falcon
//! (paper §V-A "Edge LLMs": 1B/1.5B, 3B, 7B/8B classes).

/// Size class of an edge LLM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelSize {
    /// 1B–1.5B parameters.
    Small,
    /// ~3B parameters.
    Mid,
    /// 7B–8B parameters.
    Large,
}

impl ModelSize {
    pub fn label(&self) -> &'static str {
        match self {
            ModelSize::Small => "small",
            ModelSize::Mid => "mid",
            ModelSize::Large => "large",
        }
    }
}

/// A deployable model variant.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub size: ModelSize,
    /// Billions of parameters (for reporting).
    pub params_b: f64,
    /// Intrinsic generation capability q_m ∈ (0,1]: the per-token copy
    /// fidelity multiplier under ideal retrieval.
    pub quality: f64,
    /// Minimum GPU memory fraction to start (paper's r_m).
    pub min_mem: f64,
    /// Model loading time l_m in seconds (unloading is ~free).
    pub load_time_s: f64,
    /// Peak decode throughput (tokens/s) at full memory on a reference GPU.
    pub tau_max: f64,
    /// Decode tokens generated per query (fixed-length chunks & answers).
    pub tokens_per_query: f64,
    /// Contention coefficient for the superlinear overload term.
    pub gamma: f64,
}

/// The standard heterogeneous pool used across experiments: one model per
/// size class (per-node pools may subset this, emulating different series).
pub fn standard_pool() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "llama-1b".into(),
            size: ModelSize::Small,
            params_b: 1.2,
            quality: 0.78,
            min_mem: 0.10,
            load_time_s: 0.8,
            tau_max: 1200.0,
            tokens_per_query: 24.0,
            gamma: 0.8,
        },
        ModelSpec {
            name: "llama-3b".into(),
            size: ModelSize::Mid,
            params_b: 3.2,
            quality: 0.90,
            min_mem: 0.25,
            load_time_s: 1.8,
            tau_max: 240.0,
            tokens_per_query: 24.0,
            gamma: 1.6,
        },
        ModelSpec {
            name: "llama-8b".into(),
            size: ModelSize::Large,
            params_b: 8.0,
            quality: 1.0,
            min_mem: 0.45,
            load_time_s: 4.0,
            tau_max: 100.0,
            tokens_per_query: 24.0,
            gamma: 3.0,
        },
    ]
}

/// Pool of only the given size classes.
pub fn pool_of(sizes: &[ModelSize]) -> Vec<ModelSpec> {
    standard_pool()
        .into_iter()
        .filter(|m| sizes.contains(&m.size))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_ordering_invariants() {
        let pool = standard_pool();
        assert_eq!(pool.len(), 3);
        // quality increases with size; throughput decreases; memory+load grow
        for w in pool.windows(2) {
            assert!(w[0].size < w[1].size);
            assert!(w[0].quality < w[1].quality);
            assert!(w[0].tau_max > w[1].tau_max);
            assert!(w[0].min_mem < w[1].min_mem);
            assert!(w[0].load_time_s < w[1].load_time_s);
        }
    }

    #[test]
    fn pool_of_filters() {
        let p = pool_of(&[ModelSize::Small, ModelSize::Mid]);
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|m| m.size != ModelSize::Large));
    }
}
