//! Exact flat index — brute-force top-k inner product over a contiguous
//! matrix. This is the paper's retrieval configuration ("Faiss-based vector
//! database with a flat index for exact similarity search, top-5").

use std::collections::HashMap;

use super::{Hit, TopK, VectorIndex};
use crate::text::embed::dot;

/// Exact flat index with contiguous storage.
#[derive(Clone, Debug, Default)]
pub struct FlatIndex {
    dim: usize,
    ids: Vec<usize>,
    data: Vec<f32>, // row-major [len x dim]
    /// id → row of its *first* insertion (kept in `add`, so `score_of`
    /// is O(1) instead of a linear id scan; first-occurrence semantics
    /// match the previous `Vec::position` lookup for duplicate ids).
    row_of: HashMap<usize, usize>,
}

impl FlatIndex {
    /// An empty index for `dim`-dimensional vectors.
    pub fn new(dim: usize) -> Self {
        FlatIndex { dim, ids: Vec::new(), data: Vec::new(), row_of: HashMap::new() }
    }

    /// Embedding dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row view.
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Similarity of the query against a *stored id* (O(1) map lookup;
    /// used by tests/oracle paths). For ids added more than once, scores
    /// the first-inserted row, like the linear scan it replaced.
    pub fn score_of(&self, query: &[f32], id: usize) -> Option<f32> {
        let i = *self.row_of.get(&id)?;
        Some(dot(query, self.row(i)))
    }
}

impl VectorIndex for FlatIndex {
    fn add(&mut self, id: usize, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dim mismatch");
        self.row_of.entry(id).or_insert(self.ids.len());
        self.ids.push(id);
        self.data.extend_from_slice(vector);
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dim mismatch");
        let mut top = TopK::new(k);
        for i in 0..self.ids.len() {
            let score = dot(query, self.row(i));
            top.push(Hit { id: self.ids[i], score });
        }
        top.into_vec()
    }

    /// Blocked batched kernel: rows are scanned in cache-sized blocks and
    /// scored against every query while hot, instead of streaming the whole
    /// matrix once per query. Each query still sees rows in ascending
    /// storage order, so results are identical to the per-query loop.
    fn search_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        const BLOCK_ROWS: usize = 64;
        let mut tops: Vec<TopK> = queries
            .iter()
            .map(|q| {
                assert_eq!(q.len(), self.dim, "dim mismatch");
                TopK::new(k)
            })
            .collect();
        let n = self.ids.len();
        let mut start = 0;
        while start < n {
            let end = (start + BLOCK_ROWS).min(n);
            for (q, top) in queries.iter().zip(tops.iter_mut()) {
                for i in start..end {
                    let score = dot(q, self.row(i));
                    top.push(Hit { id: self.ids[i], score });
                }
            }
            start = end;
        }
        tops.into_iter().map(TopK::into_vec).collect()
    }

    fn len(&self) -> usize {
        self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::util::rng::Rng;

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn search_matches_bruteforce() {
        let mut rng = Rng::new(31);
        let dim = 32;
        let mut idx = FlatIndex::new(dim);
        let vectors: Vec<Vec<f32>> = (0..200).map(|_| random_unit(&mut rng, dim)).collect();
        for (i, v) in vectors.iter().enumerate() {
            idx.add(i + 1000, v);
        }
        let q = random_unit(&mut rng, dim);
        let hits = idx.search(&q, 5);
        assert_eq!(hits.len(), 5);
        // brute force
        let mut scores: Vec<(usize, f32)> = vectors
            .iter()
            .enumerate()
            .map(|(i, v)| (i + 1000, dot(&q, v)))
            .collect();
        scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (hit, (id, score)) in hits.iter().zip(scores.iter()) {
            assert_eq!(hit.id, *id);
            assert!((hit.score - score).abs() < 1e-6);
        }
        // descending
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn self_query_is_top_hit() {
        let mut rng = Rng::new(37);
        let dim = 16;
        let mut idx = FlatIndex::new(dim);
        let vecs: Vec<Vec<f32>> = (0..50).map(|_| random_unit(&mut rng, dim)).collect();
        for (i, v) in vecs.iter().enumerate() {
            idx.add(i, v);
        }
        for (i, v) in vecs.iter().enumerate().take(10) {
            let hits = idx.search(v, 1);
            assert_eq!(hits[0].id, i);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn k_larger_than_len() {
        let mut idx = FlatIndex::new(4);
        idx.add(7, &[1.0, 0.0, 0.0, 0.0]);
        let hits = idx.search(&[1.0, 0.0, 0.0, 0.0], 10);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 7);
    }

    #[test]
    fn empty_index() {
        let idx = FlatIndex::new(8);
        assert!(idx.is_empty());
        assert!(idx.search(&[0.0; 8], 3).is_empty());
        assert!(idx.search_batch(&[], 3).is_empty());
    }

    #[test]
    fn score_of_finds_ids_and_keeps_first_duplicate() {
        let dim = 4;
        let mut idx = FlatIndex::new(dim);
        idx.add(7, &[1.0, 0.0, 0.0, 0.0]);
        idx.add(9, &[0.0, 1.0, 0.0, 0.0]);
        // duplicate add: id 7 again with a different vector — lookups must
        // keep scoring the first-inserted row (the old linear scan's
        // semantics), while search still sees both rows
        idx.add(7, &[0.0, 0.0, 1.0, 0.0]);
        let q = [1.0f32, 0.0, 0.0, 0.0];
        assert_eq!(idx.score_of(&q, 7), Some(1.0));
        assert_eq!(idx.score_of(&q, 9), Some(0.0));
        assert_eq!(idx.score_of(&q, 8), None);
        assert_eq!(idx.len(), 3);
        let qz = [0.0f32, 0.0, 1.0, 0.0];
        assert_eq!(idx.score_of(&qz, 7), Some(0.0)); // first row, not the dup
        assert_eq!(idx.search(&qz, 1)[0].id, 7); // ...but search finds the dup
    }

    #[test]
    fn batch_kernel_matches_per_query_search() {
        let mut rng = Rng::new(53);
        let dim = 32;
        let mut idx = FlatIndex::new(dim);
        // 150 rows: not a multiple of the 64-row block, exercising the tail
        for i in 0..150 {
            idx.add(i, &random_unit(&mut rng, dim));
        }
        let queries: Vec<Vec<f32>> = (0..33).map(|_| random_unit(&mut rng, dim)).collect();
        let batched = idx.search_batch(&queries, 5);
        assert_eq!(batched.len(), queries.len());
        for (q, hits) in queries.iter().zip(&batched) {
            assert_eq!(*hits, idx.search(q, 5));
        }
    }
}
