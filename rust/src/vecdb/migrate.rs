//! Online reindex migration: background rebuild + atomic swap.
//!
//! A `reindex` scenario event asks a node to change its index *structure*
//! (e.g. `flat` → `quantized-flat`) without a dead-stop: the node
//! snapshots its corpus rows, a worker thread builds the target index
//! (add every snapshot row in order, then [`VectorIndex::finalize`]) in
//! the background, and every slot keeps serving from the old index until
//! the swap. Corpus rows ingested while the build is in flight land in
//! the old index immediately (they must stay searchable) *and* in a
//! write-log that is drained into the new index just before the swap, so
//! no row is reordered or dropped across the cutover.
//!
//! The swap slot is **modeled**, never wall-clock (ADR-001):
//! [`modeled_build_slots`] maps `(snapshot rows, target kind)` to a
//! deterministic slot count, the coordinator ticks the countdown once per
//! slot boundary, and the real background build is awaited when the
//! countdown reaches zero — transcripts pin the swap slot byte-for-byte
//! across machines and thread counts while the actual construction still
//! overlaps serving.

use std::sync::mpsc;
use std::sync::Arc;

use super::registry::{IndexBuildCtx, IndexKind, IndexRegistry, IndexSpec};
use super::VectorIndex;
use crate::util::threadpool::ThreadPool;
use crate::Result;

/// Modeled rebuild throughput: corpus rows indexed per slot by the
/// baseline (flat) builder. Only the *ratio* to corpus size matters —
/// it scales the swap-slot countdown, never enters latency math.
const MODELED_ROWS_PER_SLOT: f64 = 64.0;

/// Upper bound on the countdown so a huge corpus still swaps within any
/// realistic scenario horizon.
const MAX_BUILD_SLOTS: usize = 16;

/// Deterministic (modeled) number of slot boundaries a background build
/// of `to` over `rows` snapshot rows occupies before the swap. Always
/// ≥ 1: even a trivial rebuild serves at least one full slot from the
/// old index. Per-kind cost factors reflect relative construction cost
/// (graph/k-means builds are slower than flat copies); the fuzz oracle
/// recomputes this independently to pin the engine's swap slot.
pub fn modeled_build_slots(rows: usize, to: IndexKind) -> usize {
    let per_row = match to {
        IndexKind::Flat => 1.0,
        IndexKind::QuantizedFlat => 1.5,
        IndexKind::Ivf => 4.0,
        IndexKind::Hnsw => 6.0,
        IndexKind::ShardedFlat => 1.2,
        IndexKind::ShardedQuantized => 1.7,
        IndexKind::ShardedIvf => 4.2,
    };
    (1 + (rows as f64 * per_row / MODELED_ROWS_PER_SLOT) as usize).min(MAX_BUILD_SLOTS)
}

/// One in-flight reindex migration on a node: the background build, the
/// modeled swap countdown, and the write-log of rows ingested since the
/// snapshot. Owned by the node; dropped on swap (or when replaced by a
/// newer `reindex` event, which abandons the old build — its worker pool
/// joins on drop).
pub struct IndexMigration {
    to: IndexKind,
    from: String,
    spec: IndexSpec,
    slots_remaining: usize,
    write_log: Vec<usize>,
    rx: mpsc::Receiver<Result<Box<dyn VectorIndex>>>,
    // 1-worker pool the build runs on; Drop joins it, so an abandoned
    // migration never leaks a thread
    _pool: ThreadPool,
}

impl IndexMigration {
    /// Start a background build of `to` from a corpus snapshot.
    ///
    /// `snapshot` is the node's doc-id list at event time (in index
    /// ingestion order); `doc_embs[id]` holds each row's embedding.
    /// `spec` is the target index parameterization (its `kind` names
    /// `to`), `seed` the node's deterministic build seed, and
    /// `build_slots` the modeled countdown (normally
    /// [`modeled_build_slots`]; the fuzz oracle's fault-injection hook
    /// passes skewed values to prove swap-slot drift is caught).
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        registry: Arc<IndexRegistry>,
        spec: IndexSpec,
        to: IndexKind,
        from: &str,
        dim: usize,
        seed: u64,
        snapshot: Vec<usize>,
        doc_embs: Arc<Vec<Vec<f32>>>,
        build_slots: usize,
    ) -> Self {
        let (tx, rx) = mpsc::channel();
        let pool = ThreadPool::new(1);
        let build_spec = spec.clone();
        pool.execute(move || {
            let ctx = IndexBuildCtx { dim, seed, spec: &build_spec };
            let built = registry.build_from_snapshot(
                build_spec.kind.as_str(),
                &ctx,
                snapshot.iter().map(|&id| (id, doc_embs[id].as_slice())),
            );
            // a dropped receiver means the migration was abandoned
            // (replaced by a newer reindex) — nothing to report to
            let _ = tx.send(built);
        });
        IndexMigration {
            to,
            from: from.to_string(),
            spec,
            slots_remaining: build_slots.max(1),
            write_log: Vec::new(),
            rx,
            _pool: pool,
        }
    }

    /// The target kind this migration builds toward.
    pub fn target(&self) -> IndexKind {
        self.to
    }

    /// The target index parameterization (becomes the node's spec at swap).
    pub fn spec(&self) -> &IndexSpec {
        &self.spec
    }

    /// Modeled slots left before the swap.
    pub fn slots_remaining(&self) -> usize {
        self.slots_remaining
    }

    /// Record rows ingested while the build is in flight; drained into
    /// the new index (in ingestion order) just before the swap.
    pub fn log_ingest(&mut self, ids: &[usize]) {
        self.write_log.extend_from_slice(ids);
    }

    /// Transcript label while in flight: `from->to:remaining`.
    pub fn label(&self) -> String {
        format!("{}->{}:{}", self.from, self.to, self.slots_remaining)
    }

    /// Advance the modeled countdown by one slot boundary. Returns
    /// `true` when the countdown reaches zero — the caller must then
    /// [`finish`](Self::finish) the migration and swap.
    pub fn tick(&mut self) -> bool {
        self.slots_remaining = self.slots_remaining.saturating_sub(1);
        self.slots_remaining == 0
    }

    /// Await the background build (blocking — by the modeled contract
    /// the countdown has elapsed, so normally the index is long done),
    /// drain the write-log into it in ingestion order, and hand the
    /// ready-to-swap index back.
    pub fn finish(self, doc_embs: &[Vec<f32>]) -> Result<Box<dyn VectorIndex>> {
        let built = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("reindex build worker died before delivering"))?;
        let mut idx = built?;
        for &id in &self.write_log {
            idx.add(id, &doc_embs[id]);
        }
        Ok(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::util::rng::Rng;
    use crate::vecdb::FlatIndex;

    fn rows(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                l2_normalize(&mut v);
                v
            })
            .collect()
    }

    #[test]
    fn modeled_build_slots_is_monotone_capped_and_at_least_one() {
        assert_eq!(modeled_build_slots(0, IndexKind::Flat), 1);
        assert_eq!(modeled_build_slots(60, IndexKind::QuantizedFlat), 2);
        let mut prev = 0;
        for rows in [0, 16, 64, 256, 1024, 100_000] {
            let s = modeled_build_slots(rows, IndexKind::Hnsw);
            assert!(s >= prev, "rows={rows}");
            assert!((1..=MAX_BUILD_SLOTS).contains(&s), "rows={rows} slots={s}");
            prev = s;
        }
        assert_eq!(modeled_build_slots(100_000, IndexKind::Hnsw), MAX_BUILD_SLOTS);
        // costlier kinds never need fewer slots than flat
        for rows in [16, 64, 300] {
            for k in IndexKind::ALL {
                assert!(
                    modeled_build_slots(rows, k) >= modeled_build_slots(rows, IndexKind::Flat),
                    "{k} rows={rows}"
                );
            }
        }
    }

    #[test]
    fn migration_builds_in_background_and_drains_write_log_in_order() {
        let dim = 8;
        let embs = Arc::new(rows(50, dim, 0xA1));
        let snapshot: Vec<usize> = (0..40).collect();
        let mut mig = IndexMigration::start(
            Arc::new(IndexRegistry::with_builtins()),
            IndexSpec::of_kind("quantized-flat"),
            IndexKind::QuantizedFlat,
            "flat",
            dim,
            7,
            snapshot.clone(),
            Arc::clone(&embs),
            2,
        );
        assert_eq!(mig.label(), "flat->quantized-flat:2");
        mig.log_ingest(&[40, 41]);
        mig.log_ingest(&[42]);
        assert!(!mig.tick());
        assert_eq!(mig.label(), "flat->quantized-flat:1");
        assert!(mig.tick());
        let built = mig.finish(&embs).unwrap();
        assert_eq!(built.len(), 43);
        // parity with a fresh build over the same rows in the same order
        let mut fresh = FlatIndex::new(dim);
        for id in snapshot.iter().chain(&[40, 41, 42]) {
            fresh.add(*id, &embs[*id]);
        }
        for q in embs.iter().take(6) {
            assert_eq!(built.search(q, 5), fresh.search(q, 5));
        }
    }

    #[test]
    fn abandoned_migration_joins_cleanly() {
        let dim = 4;
        let embs = Arc::new(rows(20, dim, 3));
        let mig = IndexMigration::start(
            Arc::new(IndexRegistry::with_builtins()),
            IndexSpec::of_kind("hnsw"),
            IndexKind::Hnsw,
            "flat",
            dim,
            1,
            (0..20).collect(),
            embs,
            3,
        );
        drop(mig); // must not hang or leak the worker
    }
}
