//! Generic sharded index: segments vectors across N inner indexes and
//! fans batched searches out on the crate thread pool, merging per-query
//! top-k with [`TopK`].
//!
//! Sharding an exact index stays exact — including tie-breaking: every
//! shard scores the same dot products an unsharded index would, and the
//! merge replays candidates in global insertion order, so equal scores
//! keep the earlier-added vector exactly like a single `FlatIndex` scan.
//! `ShardedIndex<FlatIndex>` returns the same top-k as a `FlatIndex`
//! holding all vectors (property test in `tests/index_api.rs`).

use std::collections::HashMap;

use super::{Hit, TopK, VectorIndex};
use crate::util::threadpool::parallel_map;

/// Below this many score evaluations (stored vectors × queries) the shard
/// fan-out runs inline: spawning scoped threads costs more than the scan.
const PARALLEL_MIN_WORK: usize = 1 << 15;

/// N inner indexes with round-robin ingestion and parallel batched search.
pub struct ShardedIndex<I: VectorIndex> {
    shards: Vec<I>,
    /// Round-robin ingestion cursor.
    next: usize,
    /// Threads used for `search_batch` fan-out (default: one per shard).
    threads: usize,
    /// id → global insertion sequence (first occurrence wins, matching a
    /// flat scan), for flat-identical tie-breaking in the merge.
    seq: HashMap<usize, usize>,
    /// Monotone insertion counter (≠ `seq.len()` once ids repeat).
    count: usize,
}

impl<I: VectorIndex> ShardedIndex<I> {
    /// Wrap pre-built (typically empty) shards. Panics when empty.
    pub fn new(shards: Vec<I>) -> Self {
        assert!(!shards.is_empty(), "ShardedIndex needs at least one shard");
        let threads = shards.len();
        ShardedIndex { shards, next: 0, threads, seq: HashMap::new(), count: 0 }
    }

    /// Build `n` shards from a constructor closure (shard index as arg).
    pub fn from_fn(n: usize, f: impl FnMut(usize) -> I) -> Self {
        let n = n.max(1);
        ShardedIndex::new((0..n).map(f).collect())
    }

    /// Cap the fan-out thread count (≥1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The inner shards (diagnostics / tests).
    pub fn shards(&self) -> &[I] {
        &self.shards
    }

    /// Merge per-shard hit lists into one global top-k: candidates are
    /// replayed in insertion order so [`TopK`]'s earlier-push-wins ties
    /// resolve identically to an unsharded scan.
    fn merge(&self, lists: impl Iterator<Item = Hit>, k: usize) -> Vec<Hit> {
        let mut cands: Vec<Hit> = lists.collect();
        cands.sort_by_key(|h| self.seq.get(&h.id).copied().unwrap_or(usize::MAX));
        let mut top = TopK::new(k);
        for h in cands {
            top.push(h);
        }
        top.into_vec()
    }
}

impl<I: VectorIndex> VectorIndex for ShardedIndex<I> {
    fn add(&mut self, id: usize, vector: &[f32]) {
        self.seq.entry(id).or_insert(self.count);
        self.count += 1;
        self.shards[self.next].add(id, vector);
        self.next = (self.next + 1) % self.shards.len();
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        self.merge(self.shards.iter().flat_map(|s| s.search(query, k)), k)
    }

    /// One `search_batch` per shard — fanned out on scoped threads when the
    /// scan is large enough to amortize the spawns — then a per-query merge.
    fn search_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        if queries.is_empty() {
            return Vec::new();
        }
        let per_shard: Vec<Vec<Vec<Hit>>> =
            if self.threads <= 1 || self.len() * queries.len() < PARALLEL_MIN_WORK {
                self.shards.iter().map(|s| s.search_batch(queries, k)).collect()
            } else {
                parallel_map(self.shards.len(), self.threads, |s| {
                    self.shards[s].search_batch(queries, k)
                })
            };
        (0..queries.len())
            .map(|q| self.merge(per_shard.iter().flat_map(|s| s[q].iter().copied()), k))
            .collect()
    }

    fn finalize(&mut self, seed: u64) {
        for (i, shard) in self.shards.iter_mut().enumerate() {
            shard.finalize(seed ^ (i as u64).wrapping_mul(0x9E37_79B9));
        }
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::util::rng::Rng;
    use crate::vecdb::{FlatIndex, IvfIndex};

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn sharded_flat_matches_flat() {
        let mut rng = Rng::new(23);
        let dim = 24;
        let mut flat = FlatIndex::new(dim);
        let mut sharded = ShardedIndex::from_fn(3, |_| FlatIndex::new(dim));
        for i in 0..400 {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            sharded.add(i, &v);
        }
        assert_eq!(sharded.len(), 400);
        assert_eq!(sharded.num_shards(), 3);
        for _ in 0..20 {
            let q = random_unit(&mut rng, dim);
            assert_eq!(sharded.search(&q, 5), flat.search(&q, 5));
        }
    }

    /// Duplicate embeddings: flat keeps the earliest-inserted on ties and
    /// the sharded merge must reproduce that exactly.
    #[test]
    fn tie_breaking_matches_flat_insertion_order() {
        let dim = 4;
        let a = [1.0f32, 0.0, 0.0, 0.0];
        let b = [0.0f32, 1.0, 0.0, 0.0];
        let mut flat = FlatIndex::new(dim);
        let mut sharded = ShardedIndex::from_fn(2, |_| FlatIndex::new(dim));
        // ids 0..4 all share embedding `a`; ids 4..6 share `b`
        for i in 0..6 {
            let v = if i < 4 { &a } else { &b };
            flat.add(i, v);
            sharded.add(i, v);
        }
        for k in 1..=6 {
            assert_eq!(sharded.search(&a, k), flat.search(&a, k), "k={k}");
            assert_eq!(
                sharded.search_batch(&[a.to_vec()], k)[0],
                flat.search(&a, k),
                "batched k={k}"
            );
        }
    }

    /// A re-added id keeps its first insertion rank, so ties against ids
    /// added between the two insertions still resolve like a flat scan.
    #[test]
    fn duplicate_id_keeps_first_insertion_rank() {
        let dim = 4;
        let v = [1.0f32, 0.0, 0.0, 0.0];
        let mut flat = FlatIndex::new(dim);
        let mut sharded = ShardedIndex::from_fn(2, |_| FlatIndex::new(dim));
        for id in [5usize, 5, 6] {
            flat.add(id, &v);
            sharded.add(id, &v);
        }
        // all three rows tie at 1.0; flat returns id 5 first
        assert_eq!(sharded.search(&v, 2), flat.search(&v, 2));
    }

    #[test]
    fn batch_matches_sequential() {
        let mut rng = Rng::new(29);
        let dim = 16;
        let mut sharded = ShardedIndex::from_fn(4, |_| FlatIndex::new(dim));
        for i in 0..300 {
            sharded.add(i, &random_unit(&mut rng, dim));
        }
        let queries: Vec<Vec<f32>> = (0..32).map(|_| random_unit(&mut rng, dim)).collect();
        let batched = sharded.search_batch(&queries, 5);
        for (q, hits) in queries.iter().zip(&batched) {
            assert_eq!(*hits, sharded.search(q, 5));
        }
        // force the parallel path too (work threshold is on vectors × queries)
        let many: Vec<Vec<f32>> = (0..150).map(|_| random_unit(&mut rng, dim)).collect();
        let wide = sharded.search_batch(&many, 5);
        for (q, hits) in many.iter().zip(&wide) {
            assert_eq!(*hits, sharded.search(q, 5));
        }
    }

    #[test]
    fn finalize_reaches_every_shard() {
        let mut rng = Rng::new(31);
        let dim = 8;
        let mut sharded = ShardedIndex::from_fn(2, |_| IvfIndex::new(dim, 4, 4));
        let vecs: Vec<Vec<f32>> = (0..200).map(|_| random_unit(&mut rng, dim)).collect();
        for (i, v) in vecs.iter().enumerate() {
            sharded.add(i, v);
        }
        sharded.finalize(7); // trains both IVF shards
        let hits = sharded.search(&vecs[0], 1);
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn empty_batch_and_single_shard() {
        let sharded: ShardedIndex<FlatIndex> =
            ShardedIndex::from_fn(0, |_| FlatIndex::new(4)); // clamps to 1
        assert_eq!(sharded.num_shards(), 1);
        assert!(sharded.search_batch(&[], 5).is_empty());
        assert!(sharded.is_empty());
    }
}
