//! String-keyed index registry + per-node index configuration.
//!
//! Mirrors the scheduling tier's `AllocatorRegistry`: built-in kinds are
//! registered under their [`IndexKind`] names, custom indexes register a
//! factory under any other key, and the cluster layer builds whatever the
//! node's [`IndexSpec`] names — no downstream code branches on the kind.

use std::collections::BTreeMap;

use super::{FlatIndex, HnswIndex, IvfIndex, QuantizedFlatIndex, ShardedIndex, VectorIndex};
use anyhow::{anyhow, Result};

/// Built-in index kinds (also the registry's built-in keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    /// Exact brute-force search (the paper's configuration, the default).
    Flat,
    /// Exact search via i8 SoA candidate scan + f32 rescore — bitwise
    /// flat-identical at the default `rescore_factor`.
    QuantizedFlat,
    /// IVF approximate search (k-means coarse quantizer).
    Ivf,
    /// HNSW graph-based approximate search.
    Hnsw,
    /// Flat segments fanned out across N shards on the thread pool.
    ShardedFlat,
    /// Quantized-flat segments fanned out across N shards.
    ShardedQuantized,
    /// IVF segments fanned out across N shards.
    ShardedIvf,
}

impl IndexKind {
    /// Every built-in kind.
    pub const ALL: [IndexKind; 7] = [
        IndexKind::Flat,
        IndexKind::QuantizedFlat,
        IndexKind::Ivf,
        IndexKind::Hnsw,
        IndexKind::ShardedFlat,
        IndexKind::ShardedQuantized,
        IndexKind::ShardedIvf,
    ];

    /// Stable string key (CLI flag values, TOML, registry keys).
    pub fn as_str(&self) -> &'static str {
        match self {
            IndexKind::Flat => "flat",
            IndexKind::QuantizedFlat => "quantized-flat",
            IndexKind::Ivf => "ivf",
            IndexKind::Hnsw => "hnsw",
            IndexKind::ShardedFlat => "sharded-flat",
            IndexKind::ShardedQuantized => "sharded-quantized",
            IndexKind::ShardedIvf => "sharded-ivf",
        }
    }
}

impl std::fmt::Display for IndexKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for IndexKind {
    type Err = anyhow::Error;

    /// Exhaustive over [`IndexKind::ALL`]; the error lists every valid kind.
    fn from_str(s: &str) -> Result<Self> {
        IndexKind::ALL
            .iter()
            .find(|k| k.as_str() == s)
            .copied()
            .ok_or_else(|| {
                let valid: Vec<&str> = IndexKind::ALL.iter().map(|k| k.as_str()).collect();
                anyhow!("unknown index kind {s:?}; valid kinds: {}", valid.join(", "))
            })
    }
}

/// Per-node index configuration (TOML `[nodes.index]` / CLI `--index`).
///
/// `kind` is a registry key, so it may also name a custom index registered
/// through `CoordinatorBuilder::register_index`; unknown kinds fail at
/// build time with the registry's key list. Parameters not used by the
/// selected kind are ignored.
#[derive(Clone, Debug, PartialEq)]
pub struct IndexSpec {
    /// Registry key (`flat`, `quantized-flat`, `ivf`, `hnsw`,
    /// `sharded-flat`, `sharded-quantized`, `sharded-ivf`, or a custom
    /// registration).
    pub kind: String,
    /// IVF: number of k-means lists.
    pub nlist: usize,
    /// IVF: lists probed per query.
    pub nprobe: usize,
    /// Sharded kinds: number of shards.
    pub shards: usize,
    /// HNSW: max links per node (M).
    pub hnsw_m: usize,
    /// HNSW: construction beam width.
    pub hnsw_ef_construction: usize,
    /// HNSW: search beam width.
    pub hnsw_ef_search: usize,
    /// Quantized kinds: rescore-set floor multiplier (`k × rescore_factor`
    /// candidates rescored in f32). Values ≥ 2 keep the exactness margin
    /// (bitwise flat-identical hits); `1` is the fast approximate mode.
    pub rescore_factor: usize,
}

impl Default for IndexSpec {
    fn default() -> Self {
        IndexSpec {
            kind: IndexKind::Flat.as_str().into(),
            nlist: 64,
            nprobe: 8,
            shards: 4,
            hnsw_m: 16,
            hnsw_ef_construction: 100,
            hnsw_ef_search: 64,
            rescore_factor: 4,
        }
    }
}

impl IndexSpec {
    /// Default parameters with the given kind.
    pub fn of_kind(kind: &str) -> Self {
        IndexSpec { kind: kind.into(), ..IndexSpec::default() }
    }
}

/// What an index factory gets to build from.
pub struct IndexBuildCtx<'a> {
    /// Embedding dimensionality.
    pub dim: usize,
    /// Deterministic seed (per node).
    pub seed: u64,
    /// The node's index configuration.
    pub spec: &'a IndexSpec,
}

type IndexFactory = Box<dyn Fn(&IndexBuildCtx) -> Result<Box<dyn VectorIndex>> + Send + Sync>;

/// String-keyed registry of index factories.
pub struct IndexRegistry {
    factories: BTreeMap<String, IndexFactory>,
}

impl IndexRegistry {
    /// Empty registry (no built-ins).
    pub fn empty() -> Self {
        IndexRegistry { factories: BTreeMap::new() }
    }

    /// Registry with every [`IndexKind`] built-in registered.
    pub fn with_builtins() -> Self {
        let mut r = IndexRegistry::empty();
        r.register(IndexKind::Flat.as_str(), |ctx| {
            Ok(Box::new(FlatIndex::new(ctx.dim)))
        });
        r.register(IndexKind::QuantizedFlat.as_str(), |ctx| {
            Ok(Box::new(QuantizedFlatIndex::new(ctx.dim, ctx.spec.rescore_factor)))
        });
        r.register(IndexKind::Ivf.as_str(), |ctx| {
            Ok(Box::new(IvfIndex::new(ctx.dim, ctx.spec.nlist, ctx.spec.nprobe)))
        });
        r.register(IndexKind::Hnsw.as_str(), |ctx| {
            Ok(Box::new(HnswIndex::new(
                ctx.dim,
                ctx.spec.hnsw_m,
                ctx.spec.hnsw_ef_construction,
                ctx.spec.hnsw_ef_search,
                ctx.seed,
            )))
        });
        r.register(IndexKind::ShardedFlat.as_str(), |ctx| {
            let dim = ctx.dim;
            Ok(Box::new(ShardedIndex::from_fn(ctx.spec.shards, |_| FlatIndex::new(dim))))
        });
        r.register(IndexKind::ShardedQuantized.as_str(), |ctx| {
            let (dim, rf) = (ctx.dim, ctx.spec.rescore_factor);
            Ok(Box::new(ShardedIndex::from_fn(ctx.spec.shards, |_| {
                QuantizedFlatIndex::new(dim, rf)
            })))
        });
        r.register(IndexKind::ShardedIvf.as_str(), |ctx| {
            let (dim, nlist, nprobe) = (ctx.dim, ctx.spec.nlist, ctx.spec.nprobe);
            Ok(Box::new(ShardedIndex::from_fn(ctx.spec.shards, |_| {
                IvfIndex::new(dim, nlist, nprobe)
            })))
        });
        r
    }

    /// Register (or replace) a factory under `kind`.
    pub fn register(
        &mut self,
        kind: &str,
        factory: impl Fn(&IndexBuildCtx) -> Result<Box<dyn VectorIndex>> + Send + Sync + 'static,
    ) {
        self.factories.insert(kind.to_string(), Box::new(factory));
    }

    /// Registered keys, sorted.
    pub fn kinds(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Build an empty index of `kind`; the error lists every registered key.
    pub fn build(&self, kind: &str, ctx: &IndexBuildCtx) -> Result<Box<dyn VectorIndex>> {
        match self.factories.get(kind) {
            Some(f) => f(ctx),
            None => Err(anyhow!(
                "unknown index kind {kind:?}; registered kinds: {}",
                self.kinds().join(", ")
            )),
        }
    }

    /// Build an index of `kind` pre-populated from a corpus snapshot:
    /// every `(id, vector)` row is added in iteration order, then the
    /// index is finalized with `ctx.seed` — the same build-add-finalize
    /// sequence the cluster layer runs at node construction, so a
    /// snapshot rebuild of the same kind reproduces the node's index
    /// bit-for-bit. This is the reindex-migration build hook.
    pub fn build_from_snapshot<'a>(
        &self,
        kind: &str,
        ctx: &IndexBuildCtx,
        rows: impl IntoIterator<Item = (usize, &'a [f32])>,
    ) -> Result<Box<dyn VectorIndex>> {
        let mut idx = self.build(kind, ctx)?;
        for (id, v) in rows {
            idx.add(id, v);
        }
        idx.finalize(ctx.seed);
        Ok(idx)
    }
}

impl Default for IndexRegistry {
    fn default() -> Self {
        IndexRegistry::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_and_errors_list_valid() {
        for k in IndexKind::ALL {
            assert_eq!(k.as_str().parse::<IndexKind>().unwrap(), k);
        }
        let err = "bogus".parse::<IndexKind>().unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("sharded-flat"), "{err}");
    }

    #[test]
    fn builtins_build_every_kind() {
        let reg = IndexRegistry::with_builtins();
        let spec = IndexSpec::default();
        for k in IndexKind::ALL {
            let ctx = IndexBuildCtx { dim: 8, seed: 1, spec: &spec };
            let idx = reg.build(k.as_str(), &ctx).unwrap();
            assert!(idx.is_empty(), "{k}");
        }
    }

    #[test]
    fn unknown_kind_lists_registered_keys() {
        let reg = IndexRegistry::with_builtins();
        let spec = IndexSpec::default();
        let err = reg
            .build("nope", &IndexBuildCtx { dim: 8, seed: 1, spec: &spec })
            .map(|_| ())
            .unwrap_err()
            .to_string();
        for k in IndexKind::ALL {
            assert!(err.contains(k.as_str()), "{err}");
        }
    }

    #[test]
    fn build_from_snapshot_matches_manual_build_add_finalize() {
        use crate::text::embed::l2_normalize;
        use crate::util::rng::Rng;
        let reg = IndexRegistry::with_builtins();
        let spec = IndexSpec::default();
        let mut rng = Rng::new(0x5AAB);
        let rows: Vec<(usize, Vec<f32>)> = (0..90)
            .map(|i| {
                let mut v: Vec<f32> = (0..12).map(|_| rng.normal() as f32).collect();
                l2_normalize(&mut v);
                (i, v)
            })
            .collect();
        for k in IndexKind::ALL {
            let ctx = IndexBuildCtx { dim: 12, seed: 7, spec: &spec };
            let snap = reg
                .build_from_snapshot(k.as_str(), &ctx, rows.iter().map(|(i, v)| (*i, v.as_slice())))
                .unwrap();
            let mut manual = reg.build(k.as_str(), &ctx).unwrap();
            for (i, v) in &rows {
                manual.add(*i, v);
            }
            manual.finalize(7);
            assert_eq!(snap.len(), rows.len(), "{k}");
            let q = &rows[17].1;
            assert_eq!(snap.search(q, 5), manual.search(q, 5), "{k}");
        }
    }

    #[test]
    fn custom_registration() {
        struct Null;
        impl VectorIndex for Null {
            fn add(&mut self, _id: usize, _v: &[f32]) {}
            fn search(&self, _q: &[f32], _k: usize) -> Vec<super::super::Hit> {
                Vec::new()
            }
            fn len(&self) -> usize {
                0
            }
        }
        let mut reg = IndexRegistry::with_builtins();
        reg.register("null", |_| Ok(Box::new(Null)));
        let spec = IndexSpec::of_kind("null");
        let idx = reg.build("null", &IndexBuildCtx { dim: 4, seed: 0, spec: &spec }).unwrap();
        assert!(idx.search(&[0.0; 4], 3).is_empty());
        assert!(reg.kinds().contains(&"null".to_string()));
    }
}
