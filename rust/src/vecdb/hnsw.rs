//! HNSW (hierarchical navigable small world) approximate index.
//!
//! Graph-based search: each vector gets a random level; upper layers form
//! sparser navigation graphs, layer 0 holds everyone. Queries greedily
//! descend from the top entry point, then run an `ef`-wide beam at layer 0.
//! Unlike IVF there is no train step — the graph is built incrementally on
//! [`add`](VectorIndex::add) — so it suits corpora that grow online.
//! Deterministic for a fixed construction seed.

use super::{Hit, TopK, VectorIndex};
use crate::text::embed::dot;
use crate::util::rng::Rng;

/// Internal candidate ordered by score via total order (NaN-safe).
#[derive(Clone, Copy, Debug)]
struct Cand {
    score: f32,
    node: u32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.score.total_cmp(&other.score).is_eq() && self.node == other.node
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // higher score first in a max-heap; break ties on node id for
        // determinism across insertion orders of the heap
        self.score.total_cmp(&other.score).then(other.node.cmp(&self.node))
    }
}

/// HNSW graph index.
pub struct HnswIndex {
    dim: usize,
    /// Max links per node on layers ≥ 1 (layer 0 allows 2·M).
    m: usize,
    ef_construction: usize,
    ef_search: usize,
    /// 1/ln(M) — the level sampling scale from the HNSW paper.
    level_scale: f64,
    rng: Rng,
    ids: Vec<usize>,
    data: Vec<f32>, // row-major [len x dim]
    /// Per node: highest layer it appears on.
    levels: Vec<usize>,
    /// neighbors[layer][node] → adjacency list (nodes absent from a layer
    /// keep an empty list there).
    neighbors: Vec<Vec<Vec<u32>>>,
    entry: Option<u32>,
}

impl HnswIndex {
    /// `m` links per node, `ef_construction` build beam, `ef_search` query
    /// beam (raised to `k` when smaller at query time).
    pub fn new(dim: usize, m: usize, ef_construction: usize, ef_search: usize, seed: u64) -> Self {
        let m = m.max(2);
        HnswIndex {
            dim,
            m,
            ef_construction: ef_construction.max(m),
            ef_search: ef_search.max(1),
            level_scale: 1.0 / (m as f64).ln(),
            rng: Rng::new(seed ^ 0x9E3779B97F4A7C15),
            ids: Vec::new(),
            data: Vec::new(),
            levels: Vec::new(),
            neighbors: Vec::new(),
            entry: None,
        }
    }

    #[inline]
    fn row(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    fn sample_level(&mut self) -> usize {
        let u = self.rng.f64().max(1e-12);
        ((-u.ln() * self.level_scale) as usize).min(16)
    }

    /// Greedy 1-best walk on `layer` from `start`.
    fn greedy_step(&self, query: &[f32], mut cur: u32, layer: usize) -> u32 {
        let mut cur_s = dot(query, self.row(cur));
        loop {
            let mut improved = false;
            for &nb in &self.neighbors[layer][cur as usize] {
                let s = dot(query, self.row(nb));
                if s > cur_s {
                    cur_s = s;
                    cur = nb;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// `ef`-wide beam search on `layer`; returns candidates best-first.
    fn beam(&self, query: &[f32], start: u32, layer: usize, ef: usize) -> Vec<Cand> {
        use std::cmp::Reverse;
        use std::collections::{BinaryHeap, HashSet};
        let mut visited: HashSet<u32> = HashSet::new();
        visited.insert(start);
        let s0 = Cand { score: dot(query, self.row(start)), node: start };
        let mut frontier: BinaryHeap<Cand> = BinaryHeap::new(); // best-first
        frontier.push(s0);
        let mut best: BinaryHeap<Reverse<Cand>> = BinaryHeap::new(); // worst at top
        best.push(Reverse(s0));
        while let Some(c) = frontier.pop() {
            let worst = best.peek().map(|r| r.0.score).unwrap_or(f32::NEG_INFINITY);
            if best.len() >= ef && c.score < worst {
                break;
            }
            for &nb in &self.neighbors[layer][c.node as usize] {
                if !visited.insert(nb) {
                    continue;
                }
                let s = dot(query, self.row(nb));
                let worst = best.peek().map(|r| r.0.score).unwrap_or(f32::NEG_INFINITY);
                if best.len() < ef || s > worst {
                    let cand = Cand { score: s, node: nb };
                    frontier.push(cand);
                    best.push(Reverse(cand));
                    if best.len() > ef {
                        best.pop();
                    }
                }
            }
        }
        let mut out: Vec<Cand> = best.into_iter().map(|r| r.0).collect();
        out.sort_by(|a, b| b.cmp(a));
        out
    }

    /// Keep the top `max` links of `node` on `layer` by similarity.
    fn prune(&mut self, node: u32, layer: usize, max: usize) {
        let list = &self.neighbors[layer][node as usize];
        if list.len() <= max {
            return;
        }
        let base = self.row(node).to_vec();
        let mut scored: Vec<Cand> = list
            .iter()
            .map(|&nb| Cand { score: dot(&base, self.row(nb)), node: nb })
            .collect();
        scored.sort_by(|a, b| b.cmp(a));
        self.neighbors[layer][node as usize] =
            scored.into_iter().take(max).map(|c| c.node).collect();
    }

    fn max_links(&self, layer: usize) -> usize {
        if layer == 0 {
            self.m * 2
        } else {
            self.m
        }
    }
}

impl VectorIndex for HnswIndex {
    fn add(&mut self, id: usize, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dim mismatch");
        let node = self.ids.len() as u32;
        self.ids.push(id);
        self.data.extend_from_slice(vector);
        let level = self.sample_level();
        self.levels.push(level);
        while self.neighbors.len() <= level {
            // a new top layer starts with empty adjacency for everyone so far
            self.neighbors.push(vec![Vec::new(); self.ids.len().saturating_sub(1)]);
        }
        for layer in self.neighbors.iter_mut() {
            layer.push(Vec::new());
        }

        let Some(entry) = self.entry else {
            self.entry = Some(node);
            return;
        };
        let top = self.levels[entry as usize];

        // descend greedily through layers above the new node's level
        let mut cur = entry;
        for layer in ((level + 1)..=top).rev() {
            cur = self.greedy_step(vector, cur, layer);
        }
        // connect on each shared layer
        for layer in (0..=level.min(top)).rev() {
            let found = self.beam(vector, cur, layer, self.ef_construction);
            cur = found.first().map(|c| c.node).unwrap_or(cur);
            let links: Vec<u32> =
                found.iter().take(self.max_links(layer)).map(|c| c.node).collect();
            for &nb in &links {
                self.neighbors[layer][nb as usize].push(node);
                let max = self.max_links(layer);
                self.prune(nb, layer, max);
            }
            self.neighbors[layer][node as usize] = links;
        }
        if level > top {
            self.entry = Some(node);
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dim mismatch");
        let Some(entry) = self.entry else {
            return Vec::new();
        };
        if k == 0 {
            return Vec::new();
        }
        let mut cur = entry;
        for layer in (1..=self.levels[entry as usize]).rev() {
            cur = self.greedy_step(query, cur, layer);
        }
        let ef = self.ef_search.max(k);
        let found = self.beam(query, cur, 0, ef);
        let mut top = TopK::new(k);
        for c in found {
            top.push(Hit { id: self.ids[c.node as usize], score: c.score });
        }
        top.into_vec()
    }

    fn len(&self) -> usize {
        self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::vecdb::FlatIndex;

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn self_query_is_top_hit() {
        let mut rng = Rng::new(11);
        let dim = 16;
        let mut idx = HnswIndex::new(dim, 8, 48, 32, 5);
        let vecs: Vec<Vec<f32>> = (0..200).map(|_| random_unit(&mut rng, dim)).collect();
        for (i, v) in vecs.iter().enumerate() {
            idx.add(i, v);
        }
        for (i, v) in vecs.iter().enumerate().take(20) {
            let hits = idx.search(v, 1);
            assert_eq!(hits[0].id, i);
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn recall_vs_flat() {
        let mut rng = Rng::new(13);
        let dim = 32;
        let n = 1500;
        let vecs: Vec<Vec<f32>> = (0..n).map(|_| random_unit(&mut rng, dim)).collect();
        let mut flat = FlatIndex::new(dim);
        let mut hnsw = HnswIndex::new(dim, 12, 80, 64, 3);
        for (i, v) in vecs.iter().enumerate() {
            flat.add(i, v);
            hnsw.add(i, v);
        }
        let queries = 40;
        let mut recall_sum = 0.0;
        for _ in 0..queries {
            let q = random_unit(&mut rng, dim);
            let exact: std::collections::HashSet<usize> =
                flat.search(&q, 5).into_iter().map(|h| h.id).collect();
            let approx = hnsw.search(&q, 5);
            recall_sum +=
                approx.iter().filter(|h| exact.contains(&h.id)).count() as f64 / 5.0;
        }
        let recall = recall_sum / queries as f64;
        assert!(recall > 0.8, "recall@5={recall}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut rng = Rng::new(17);
        let dim = 8;
        let vecs: Vec<Vec<f32>> = (0..120).map(|_| random_unit(&mut rng, dim)).collect();
        let build = || {
            let mut idx = HnswIndex::new(dim, 6, 32, 24, 99);
            for (i, v) in vecs.iter().enumerate() {
                idx.add(i, v);
            }
            idx
        };
        let (a, b) = (build(), build());
        let q = random_unit(&mut rng, dim);
        assert_eq!(a.search(&q, 5), b.search(&q, 5));
    }

    #[test]
    fn empty_and_small() {
        let mut idx = HnswIndex::new(4, 4, 16, 16, 1);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 3).is_empty());
        idx.add(42, &[1.0, 0.0, 0.0, 0.0]);
        let hits = idx.search(&[1.0, 0.0, 0.0, 0.0], 10);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 42);
        assert!(idx.search(&[1.0, 0.0, 0.0, 0.0], 0).is_empty());
    }
}
