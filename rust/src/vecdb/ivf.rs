//! IVF (inverted-file) approximate index: k-means coarse quantizer +
//! per-centroid posting lists, probing the `nprobe` nearest lists.
//!
//! Not used by the paper's configuration (which is exact flat search) but
//! included for the perf study: at edge-node corpus sizes the flat index
//! is often faster; IVF wins once corpora grow past ~100k chunks. The
//! `perf_micro` bench quantifies the crossover with a corpus-size sweep
//! over the 1.2k / 12k / 120k-chunk tiers (flat vs ivf vs hnsw vs sharded).
//!
//! Vectors added after [`train`](IvfIndex::train) are routed online to the
//! nearest centroid's posting list, so they are immediately visible to
//! `search` without a re-train.

use super::{Hit, TopK, VectorIndex};
use crate::text::embed::{dot, l2_normalize};
use crate::util::rng::Rng;

/// IVF index with k-means-trained centroids.
#[derive(Clone, Debug)]
pub struct IvfIndex {
    dim: usize,
    nlist: usize,
    nprobe: usize,
    centroids: Vec<f32>, // [nlist x dim]
    lists: Vec<Vec<(usize, Vec<f32>)>>,
    len: usize,
    trained: bool,
    pending: Vec<(usize, Vec<f32>)>,
}

impl IvfIndex {
    /// An empty untrained index (`nlist` lists, probing `nprobe`).
    pub fn new(dim: usize, nlist: usize, nprobe: usize) -> Self {
        IvfIndex {
            dim,
            nlist: nlist.max(1),
            nprobe: nprobe.max(1),
            centroids: Vec::new(),
            lists: Vec::new(),
            len: 0,
            trained: false,
            pending: Vec::new(),
        }
    }

    /// Train the coarse quantizer on the pending vectors (k-means, few
    /// iterations — enough for routing quality) and build posting lists.
    pub fn train(&mut self, seed: u64) {
        if self.pending.is_empty() {
            return;
        }
        let n = self.pending.len();
        let k = self.nlist.min(n);
        let mut rng = Rng::new(seed);

        // init: random distinct points
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut centroids: Vec<Vec<f32>> = order[..k]
            .iter()
            .map(|&i| self.pending[i].1.clone())
            .collect();

        let mut assign = vec![0usize; n];
        for _iter in 0..8 {
            // assignment
            for (i, (_, v)) in self.pending.iter().enumerate() {
                let mut best = 0;
                let mut best_s = f32::NEG_INFINITY;
                for (c, cv) in centroids.iter().enumerate() {
                    let s = dot(v, cv);
                    if s > best_s {
                        best_s = s;
                        best = c;
                    }
                }
                assign[i] = best;
            }
            // update
            let mut sums = vec![vec![0f32; self.dim]; k];
            let mut counts = vec![0usize; k];
            for (i, (_, v)) in self.pending.iter().enumerate() {
                counts[assign[i]] += 1;
                for (s, x) in sums[assign[i]].iter_mut().zip(v) {
                    *s += x;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    let mut v = sums[c].clone();
                    l2_normalize(&mut v);
                    centroids[c] = v;
                } else {
                    // re-seed empty cluster
                    centroids[c] = self.pending[rng.below(n)].1.clone();
                }
            }
        }

        self.centroids = centroids.concat();
        self.lists = vec![Vec::new(); k];
        self.nlist = k;
        let pending = std::mem::take(&mut self.pending);
        for (i, (id, v)) in pending.into_iter().enumerate() {
            self.lists[assign[i]].push((id, v));
        }
        self.trained = true;
    }

    fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }
}

impl VectorIndex for IvfIndex {
    fn add(&mut self, id: usize, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim);
        self.len += 1;
        if self.trained {
            // route to nearest centroid online
            let mut best = 0;
            let mut best_s = f32::NEG_INFINITY;
            for c in 0..self.nlist {
                let s = dot(vector, self.centroid(c));
                if s > best_s {
                    best_s = s;
                    best = c;
                }
            }
            self.lists[best].push((id, vector.to_vec()));
        } else {
            self.pending.push((id, vector.to_vec()));
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        if self.len == 0 {
            return Vec::new();
        }
        assert!(self.trained, "IvfIndex::train must be called before search");
        // rank centroids
        let mut cs: Vec<(usize, f32)> = (0..self.nlist)
            .map(|c| (c, dot(query, self.centroid(c))))
            .collect();
        cs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut top = TopK::new(k);
        for &(c, _) in cs.iter().take(self.nprobe) {
            for (id, v) in &self.lists[c] {
                top.push(Hit { id: *id, score: dot(query, v) });
            }
        }
        top.into_vec()
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Train the coarse quantizer on everything ingested so far (the
    /// cluster layer's one-time build hook).
    fn finalize(&mut self, seed: u64) {
        self.train(seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdb::FlatIndex;

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn ivf_recall_vs_flat() {
        let mut rng = Rng::new(41);
        let dim = 32;
        let n = 2000;
        let vecs: Vec<Vec<f32>> = (0..n).map(|_| random_unit(&mut rng, dim)).collect();
        let mut flat = FlatIndex::new(dim);
        let mut ivf = IvfIndex::new(dim, 16, 6);
        for (i, v) in vecs.iter().enumerate() {
            flat.add(i, v);
            ivf.add(i, v);
        }
        ivf.train(42);
        // recall@5 of IVF vs exact
        let mut recall_sum = 0.0;
        let queries = 50;
        for _ in 0..queries {
            let q = random_unit(&mut rng, dim);
            let exact: std::collections::HashSet<usize> =
                flat.search(&q, 5).into_iter().map(|h| h.id).collect();
            let approx = ivf.search(&q, 5);
            let hits = approx.iter().filter(|h| exact.contains(&h.id)).count();
            recall_sum += hits as f64 / 5.0;
        }
        let recall = recall_sum / queries as f64;
        assert!(recall > 0.55, "recall@5={recall}");
    }

    #[test]
    fn ivf_exact_when_probing_all_lists() {
        let mut rng = Rng::new(43);
        let dim = 16;
        let vecs: Vec<Vec<f32>> = (0..300).map(|_| random_unit(&mut rng, dim)).collect();
        let mut flat = FlatIndex::new(dim);
        let mut ivf = IvfIndex::new(dim, 8, 8); // probe all
        for (i, v) in vecs.iter().enumerate() {
            flat.add(i, v);
            ivf.add(i, v);
        }
        ivf.train(7);
        let q = random_unit(&mut rng, dim);
        let e: Vec<usize> = flat.search(&q, 5).into_iter().map(|h| h.id).collect();
        let a: Vec<usize> = ivf.search(&q, 5).into_iter().map(|h| h.id).collect();
        assert_eq!(e, a);
    }

    #[test]
    fn add_after_train_routes_online() {
        let mut rng = Rng::new(47);
        let dim = 8;
        let mut ivf = IvfIndex::new(dim, 4, 4);
        for i in 0..100 {
            ivf.add(i, &random_unit(&mut rng, dim));
        }
        ivf.train(1);
        let v = random_unit(&mut rng, dim);
        ivf.add(999, &v);
        let hits = ivf.search(&v, 1);
        assert_eq!(hits[0].id, 999);
        assert_eq!(ivf.len(), 101);
    }

    /// Regression: post-train adds must land in a posting list (never in
    /// `pending`, where they would be invisible until a re-train) — every
    /// one of a stream of late adds is retrievable immediately.
    #[test]
    fn every_post_train_add_is_searchable_without_retrain() {
        let mut rng = Rng::new(59);
        let dim = 8;
        let mut ivf = IvfIndex::new(dim, 4, 4); // probe all lists → exact
        for i in 0..80 {
            ivf.add(i, &random_unit(&mut rng, dim));
        }
        ivf.finalize(3);
        let late: Vec<Vec<f32>> = (0..25).map(|_| random_unit(&mut rng, dim)).collect();
        for (j, v) in late.iter().enumerate() {
            ivf.add(1000 + j, v);
        }
        assert_eq!(ivf.len(), 105);
        for (j, v) in late.iter().enumerate() {
            let hits = ivf.search(v, 1);
            assert_eq!(hits[0].id, 1000 + j, "late add {j} not retrievable");
            assert!((hits[0].score - 1.0).abs() < 1e-5);
        }
        // batched search sees them too
        let batched = ivf.search_batch(&late, 1);
        for (j, hits) in batched.iter().enumerate() {
            assert_eq!(hits[0].id, 1000 + j);
        }
    }
}
