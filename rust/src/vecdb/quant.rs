//! Shared i8 quantization codec + integer dot-product kernels.
//!
//! Two codecs live here, one per consumer:
//!
//! * **Fixed-scale codec** ([`quantize_embedding`] / [`quantized_cosine`]) —
//!   the cache tier's key codec, moved here verbatim so the retrieval and
//!   cache tiers share one implementation. The formula is byte-for-byte the
//!   one the committed cache goldens were recorded with (multiplier form,
//!   `x * 127.0`, i64 cosine accumulators) and must never drift.
//! * **Per-vector-scale codec** ([`quantize_vector`]) — the retrieval hot
//!   path's codec: each vector gets its own scale `max|x| / 127`, so short
//!   and long vectors both use the full i8 range. The reconstruction error
//!   per component is at most `scale / 2`, which is what lets
//!   `QuantizedFlatIndex` bound its score error and rescore *provably
//!   exactly* (see `vecdb/quantized.rs`).
//!
//! The integer kernels ([`dot_i8`], [`scan_block`]) accumulate `i8×i8`
//! products in `i32`. Each product is ≤ 127² = 16 129, so the accumulator
//! is overflow-safe for any `dim < i32::MAX / 16 129 ≈ 133 000` — far above
//! the crate's `EMBED_DIM = 256` (debug-asserted at the call sites).
//!
//! # SIMD
//!
//! The scalar kernels are the always-on reference: written as straight
//! index loops over `i32` lanes so LLVM autovectorizes them. An explicit
//! AVX2 path compiles behind the `simd` cargo feature
//! (`cargo test --features simd`) with runtime detection — integer
//! arithmetic has one right answer, so the intrinsic path is bitwise
//! identical to the scalar one (parity-tested below and in CI).

/// Rows per SoA block in [`scan_block`] and `QuantizedFlatIndex` storage:
/// codes are laid out `block[d * BLOCK_ROWS + r]` so one dimension of 32
/// adjacent rows is contiguous — a full 256-bit vector register of i8.
pub const BLOCK_ROWS: usize = 32;

/// Deterministically quantize a (unit-norm) embedding into the cache key
/// space: one signed byte per dimension at a *fixed* scale of 127.
///
/// Exact duplicate queries embed identically and therefore key
/// identically; quantization only widens near-duplicate matching, never
/// splits exact duplicates. The cache tier re-exports this function — the
/// multiplier form (`x * 127.0`) is pinned by the committed cache goldens
/// and a byte-identity regression test in `cache/mod.rs`.
pub fn quantize_embedding(emb: &[f32]) -> Vec<i8> {
    emb.iter().map(|&x| (x * 127.0).round().clamp(-127.0, 127.0) as i8).collect()
}

/// Cosine similarity between two fixed-scale quantized keys (integer dot
/// product, fully deterministic across platforms). Kept on i64
/// accumulators — the exact arithmetic the cache goldens were recorded
/// with — rather than rebuilt on the i32 retrieval kernels.
pub fn quantized_cosine(a: &[i8], b: &[i8]) -> f64 {
    if a.len() != b.len() || a.is_empty() {
        return 0.0;
    }
    let (mut dot, mut na, mut nb) = (0i64, 0i64, 0i64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as i64 * y as i64;
        na += x as i64 * x as i64;
        nb += y as i64 * y as i64;
    }
    if na == 0 || nb == 0 {
        return 0.0;
    }
    dot as f64 / ((na as f64).sqrt() * (nb as f64).sqrt())
}

/// Per-vector-scale quantization: `codes[i] = round(v[i] * 127 / max|v|)`,
/// returned with `scale = max|v| / 127` so `v[i] ≈ scale * codes[i]` with
/// per-component error ≤ `scale / 2`.
///
/// Degenerate inputs (all-zero, or any non-finite component) return
/// all-zero codes with `scale = 0.0`; callers treat a zero scale as "no
/// usable approximation" and fall back to exact scoring.
pub fn quantize_vector(v: &[f32]) -> (Vec<i8>, f32) {
    let max_abs = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if max_abs <= 0.0 || !max_abs.is_finite() {
        return (vec![0i8; v.len()], 0.0);
    }
    let inv = 127.0 / max_abs;
    let codes =
        v.iter().map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8).collect();
    (codes, max_abs / 127.0)
}

/// Integer dot product of two i8 code vectors, i32 accumulate.
///
/// Four independent i32 lanes so LLVM autovectorizes the loop; the tail
/// is handled scalar. Overflow-safe for `dim < ~133k` (see module docs).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() < (i32::MAX / (127 * 127)) as usize);
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] as i32 * b[k] as i32;
        s1 += a[k + 1] as i32 * b[k + 1] as i32;
        s2 += a[k + 2] as i32 * b[k + 2] as i32;
        s3 += a[k + 3] as i32 * b[k + 3] as i32;
    }
    let mut acc = 0i32;
    for k in chunks * 4..a.len() {
        acc += a[k] as i32 * b[k] as i32;
    }
    acc + s0 + s1 + s2 + s3
}

/// Score one SoA block against a quantized query: for every dimension `d`,
/// `block[d * BLOCK_ROWS + r]` holds row `r`'s code, and `acc[r]`
/// accumulates `Σ_d query[d] * block[d * BLOCK_ROWS + r]` in i32.
///
/// `block.len()` must be `query.len() * BLOCK_ROWS` (tail rows of a
/// partially-filled block are zero-padded by the index, contributing 0).
/// Dispatches to the AVX2 kernel when the `simd` feature is enabled and
/// the CPU supports it; the scalar kernel is the always-on reference and
/// both produce bitwise-identical accumulators (integer arithmetic).
#[inline]
pub fn scan_block(query: &[i8], block: &[i8], acc: &mut [i32; BLOCK_ROWS]) {
    debug_assert_eq!(block.len(), query.len() * BLOCK_ROWS);
    debug_assert!(query.len() < (i32::MAX / (127 * 127)) as usize);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 support was just verified at runtime.
            unsafe { scan_block_avx2(query, block, acc) };
            return;
        }
    }
    scan_block_scalar(query, block, acc);
}

/// Scalar reference kernel for [`scan_block`] — always compiled, used for
/// SIMD parity tests, and written so the inner 32-lane loop autovectorizes.
pub fn scan_block_scalar(query: &[i8], block: &[i8], acc: &mut [i32; BLOCK_ROWS]) {
    for (d, &q) in query.iter().enumerate() {
        let q = q as i32;
        let lane = &block[d * BLOCK_ROWS..(d + 1) * BLOCK_ROWS];
        for (a, &c) in acc.iter_mut().zip(lane) {
            *a += q * c as i32;
        }
    }
}

/// AVX2 kernel for [`scan_block`]: per dimension, the 32 row codes are one
/// 256-bit load, widened i8→i16, multiplied by the broadcast query code
/// (products ≤ 127² fit i16), widened i16→i32 and accumulated in four
/// 8-lane i32 registers. Integer arithmetic ⇒ bitwise-identical to
/// [`scan_block_scalar`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn scan_block_avx2(query: &[i8], block: &[i8], acc: &mut [i32; BLOCK_ROWS]) {
    use std::arch::x86_64::*;
    let mut a0 = _mm256_loadu_si256(acc.as_ptr() as *const __m256i);
    let mut a1 = _mm256_loadu_si256(acc.as_ptr().add(8) as *const __m256i);
    let mut a2 = _mm256_loadu_si256(acc.as_ptr().add(16) as *const __m256i);
    let mut a3 = _mm256_loadu_si256(acc.as_ptr().add(24) as *const __m256i);
    for (d, &q) in query.iter().enumerate() {
        let qv = _mm256_set1_epi16(q as i16);
        let codes =
            _mm256_loadu_si256(block.as_ptr().add(d * BLOCK_ROWS) as *const __m256i);
        // rows 0..16 and 16..32 as i16
        let lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(codes));
        let hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256(codes, 1));
        let plo = _mm256_mullo_epi16(lo, qv); // exact: |q*c| ≤ 127² < 2^15
        let phi = _mm256_mullo_epi16(hi, qv);
        a0 = _mm256_add_epi32(a0, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(plo)));
        a1 = _mm256_add_epi32(a1, _mm256_cvtepi16_epi32(_mm256_extracti128_si256(plo, 1)));
        a2 = _mm256_add_epi32(a2, _mm256_cvtepi16_epi32(_mm256_castsi256_si128(phi)));
        a3 = _mm256_add_epi32(a3, _mm256_cvtepi16_epi32(_mm256_extracti128_si256(phi, 1)));
    }
    _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, a0);
    _mm256_storeu_si256(acc.as_mut_ptr().add(8) as *mut __m256i, a1);
    _mm256_storeu_si256(acc.as_mut_ptr().add(16) as *mut __m256i, a2);
    _mm256_storeu_si256(acc.as_mut_ptr().add(24) as *mut __m256i, a3);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::util::rng::Rng;

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn fixed_scale_codec_formula_is_pinned() {
        // the exact cache-key formula: round(x*127), clamped
        let v = [0.0f32, 1.0, -1.0, 0.5, 0.004, -0.004, 2.0, -2.0];
        assert_eq!(quantize_embedding(&v), vec![0, 127, -127, 64, 1, -1, 127, -127]);
    }

    #[test]
    fn quantized_cosine_basics() {
        let a = vec![10i8, 0, 0];
        let b = vec![0i8, 10, 0];
        assert_eq!(quantized_cosine(&a, &a), 1.0);
        assert_eq!(quantized_cosine(&a, &b), 0.0);
        assert_eq!(quantized_cosine(&a, &[0i8, 0, 0]), 0.0); // zero norm
        assert_eq!(quantized_cosine(&a, &b[..2]), 0.0); // length mismatch
    }

    #[test]
    fn per_vector_scale_bounds_reconstruction_error() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let v = random_unit(&mut rng, 37);
            let (codes, scale) = quantize_vector(&v);
            assert!(scale > 0.0);
            for (&x, &c) in v.iter().zip(&codes) {
                let err = (x - scale * c as f32).abs();
                assert!(err <= scale * 0.5 + 1e-7, "err={err} scale={scale}");
            }
            // the largest-magnitude component saturates the code range
            assert_eq!(codes.iter().map(|c| c.unsigned_abs()).max(), Some(127));
        }
    }

    #[test]
    fn degenerate_vectors_get_zero_scale() {
        assert_eq!(quantize_vector(&[0.0; 4]), (vec![0i8; 4], 0.0));
        assert_eq!(quantize_vector(&[]), (vec![], 0.0));
        let (codes, scale) = quantize_vector(&[1.0, f32::NAN]);
        assert_eq!((codes, scale), (vec![0i8, 0], 0.0));
        let (codes, scale) = quantize_vector(&[f32::INFINITY, 0.0]);
        assert_eq!((codes, scale), (vec![0i8, 0], 0.0));
    }

    #[test]
    fn dot_i8_matches_naive_i64() {
        let mut rng = Rng::new(13);
        for n in [0usize, 1, 3, 4, 7, 64, 103, 256] {
            let a: Vec<i8> = (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let naive: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(dot_i8(&a, &b) as i64, naive, "n={n}");
        }
    }

    #[test]
    fn scan_block_matches_per_row_dot() {
        let mut rng = Rng::new(17);
        let dim = 48;
        // build a block from 32 row code vectors
        let rows: Vec<Vec<i8>> = (0..BLOCK_ROWS)
            .map(|_| (0..dim).map(|_| (rng.below(255) as i64 - 127) as i8).collect())
            .collect();
        let mut block = vec![0i8; dim * BLOCK_ROWS];
        for (r, row) in rows.iter().enumerate() {
            for (d, &c) in row.iter().enumerate() {
                block[d * BLOCK_ROWS + r] = c;
            }
        }
        let q: Vec<i8> = (0..dim).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
        let mut acc = [0i32; BLOCK_ROWS];
        scan_block(&q, &block, &mut acc);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(acc[r], dot_i8(&q, row), "row {r}");
        }
        // the dispatching kernel and the scalar reference agree bitwise
        let mut acc_ref = [0i32; BLOCK_ROWS];
        scan_block_scalar(&q, &block, &mut acc_ref);
        assert_eq!(acc, acc_ref);
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn avx2_kernel_is_bitwise_identical_to_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return; // nothing to compare on this host
        }
        let mut rng = Rng::new(19);
        for dim in [1usize, 7, 64, 256] {
            let q: Vec<i8> = (0..dim).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let block: Vec<i8> =
                (0..dim * BLOCK_ROWS).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let mut a = [3i32; BLOCK_ROWS]; // non-zero init: kernels must accumulate
            let mut b = [3i32; BLOCK_ROWS];
            unsafe { scan_block_avx2(&q, &block, &mut a) };
            scan_block_scalar(&q, &block, &mut b);
            assert_eq!(a, b, "dim={dim}");
        }
    }
}
