//! Vector database: the retrieval tier behind every edge node.
//!
//! Index kinds: exact [`FlatIndex`] (the paper's Faiss flat setup), exact
//! [`QuantizedFlatIndex`] (i8 SoA candidate scan + f32 rescore, bitwise
//! flat-identical at the default `rescore_factor`), IVF ([`IvfIndex`]) and
//! HNSW ([`HnswIndex`]) approximate indexes, and a
//! generic [`ShardedIndex`] that segments any inner index across N shards
//! and fans batched searches out on the crate thread pool. Kinds are
//! string-keyed in [`IndexRegistry`] (mirroring the scheduling tier's
//! `AllocatorRegistry`) so deployments pick an index per node via TOML /
//! CLI and downstream code never branches on the concrete type.
//!
//! Stores unit-normalized embeddings contiguously (SoA) and returns top-k
//! by inner product (== cosine for unit vectors).

pub mod flat;
pub mod hnsw;
pub mod ivf;
pub mod migrate;
pub mod quant;
pub mod quantized;
pub mod registry;
pub mod sharded;

pub use flat::FlatIndex;
pub use hnsw::HnswIndex;
pub use ivf::IvfIndex;
pub use migrate::{modeled_build_slots, IndexMigration};
pub use quantized::QuantizedFlatIndex;
pub use registry::{IndexBuildCtx, IndexKind, IndexRegistry, IndexSpec};
pub use sharded::ShardedIndex;

/// A search hit: external id + similarity score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    /// External id of the stored vector.
    pub id: usize,
    /// Similarity score (inner product == cosine for unit vectors).
    pub score: f32,
}

/// Common interface over index kinds.
///
/// The serving hot path issues one [`search_batch`](VectorIndex::search_batch)
/// per node per slot; implementations are expected to override it when they
/// can beat the per-query loop (blocked kernels, shard fan-out).
pub trait VectorIndex: Send + Sync {
    /// Add a vector with an external id. Vectors must share the index dim.
    fn add(&mut self, id: usize, vector: &[f32]);

    /// Exact or approximate top-k by cosine similarity.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;

    /// Batched top-k: one result list per query, in query order.
    ///
    /// The default implementation loops over [`search`](VectorIndex::search);
    /// override for batched kernels. Implementations must return results
    /// identical to the per-query loop (same hits, same order) so callers
    /// can batch without changing retrieval semantics.
    fn search_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        queries.iter().map(|q| self.search(q, k)).collect()
    }

    /// One-time build hook after ingestion (e.g. IVF k-means training).
    /// Called once by the cluster layer when a node's corpus is loaded;
    /// the default is a no-op for indexes that build incrementally.
    fn finalize(&mut self, _seed: u64) {}

    /// Number of stored vectors.
    fn len(&self) -> usize;

    /// Whether the index holds no vectors.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Bounded max-k collector (min-heap semantics via sorted insertion —
/// k is small [top-5 in the paper], so linear insertion beats a heap).
///
/// Public so custom [`VectorIndex`] implementations (and shard mergers)
/// can reuse the exact tie-breaking the built-ins have: equal scores keep
/// the earlier-pushed hit first, and NaN scores never displace real ones.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    hits: Vec<Hit>,
}

impl TopK {
    /// An empty collector keeping the best `k` hits.
    pub fn new(k: usize) -> Self {
        TopK { k, hits: Vec::with_capacity(k + 1) }
    }

    /// Current k-th best score (−∞ while under-filled or when the k-th
    /// slot holds a NaN — a NaN occupant is always displaceable).
    #[inline]
    pub fn worst(&self) -> f32 {
        if self.hits.len() < self.k {
            return f32::NEG_INFINITY;
        }
        match self.hits.last() {
            Some(h) if !h.score.is_nan() => h.score,
            _ => f32::NEG_INFINITY,
        }
    }

    /// Offer a hit; kept only if it beats the current k-th best. A NaN
    /// occupant in the k-th slot is always displaceable (even by −∞),
    /// while a NaN offer never displaces anything.
    #[inline]
    pub fn push(&mut self, hit: Hit) {
        if self.hits.len() >= self.k {
            if hit.score.is_nan() {
                return;
            }
            if let Some(last) = self.hits.last() {
                if !last.score.is_nan() && hit.score <= last.score {
                    return;
                }
            }
        }
        let pos = self
            .hits
            .iter()
            .position(|h| h.score < hit.score || h.score.is_nan())
            .unwrap_or(self.hits.len());
        self.hits.insert(pos, hit);
        if self.hits.len() > self.k {
            self.hits.pop();
        }
    }

    /// Hits collected so far, best-first.
    pub fn into_vec(self) -> Vec<Hit> {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_best() {
        let mut t = TopK::new(3);
        for (i, s) in [0.1f32, 0.9, 0.5, 0.7, 0.3, 0.8].iter().enumerate() {
            t.push(Hit { id: i, score: *s });
        }
        let v = t.into_vec();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].id, 1); // 0.9
        assert_eq!(v[1].id, 5); // 0.8
        assert_eq!(v[2].id, 3); // 0.7
    }

    #[test]
    fn topk_fewer_than_k() {
        let mut t = TopK::new(5);
        t.push(Hit { id: 0, score: 0.2 });
        t.push(Hit { id: 1, score: 0.4 });
        let v = t.into_vec();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].id, 1);
    }

    #[test]
    fn topk_ties_keep_insertion_order() {
        let mut t = TopK::new(2);
        t.push(Hit { id: 10, score: 0.5 });
        t.push(Hit { id: 11, score: 0.5 });
        t.push(Hit { id: 12, score: 0.5 }); // tie with the worst: not kept
        let v = t.into_vec();
        assert_eq!(v.iter().map(|h| h.id).collect::<Vec<_>>(), vec![10, 11]);
    }

    #[test]
    fn topk_k_zero_collects_nothing() {
        let mut t = TopK::new(0);
        t.push(Hit { id: 0, score: 1.0 });
        t.push(Hit { id: 1, score: f32::NEG_INFINITY });
        t.push(Hit { id: 2, score: f32::NAN });
        assert!(t.into_vec().is_empty());
    }

    #[test]
    fn topk_k_larger_than_candidates() {
        let mut t = TopK::new(10);
        for i in 0..3 {
            t.push(Hit { id: i, score: i as f32 });
        }
        let v = t.into_vec();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].id, 2);
    }

    #[test]
    fn topk_nan_never_displaces_real_scores() {
        let mut t = TopK::new(2);
        t.push(Hit { id: 0, score: 0.3 });
        t.push(Hit { id: 1, score: 0.1 });
        t.push(Hit { id: 2, score: f32::NAN });
        let v = t.clone().into_vec();
        assert_eq!(v.iter().map(|h| h.id).collect::<Vec<_>>(), vec![0, 1]);
        // and a real score still displaces the current worst afterwards
        t.push(Hit { id: 3, score: 0.2 });
        let v = t.into_vec();
        assert_eq!(v.iter().map(|h| h.id).collect::<Vec<_>>(), vec![0, 3]);
    }

    #[test]
    fn topk_nan_fills_only_spare_slots() {
        // under-filled: NaN may occupy a spare slot (ranked last) but is
        // evicted as soon as enough real scores arrive
        let mut t = TopK::new(2);
        t.push(Hit { id: 0, score: f32::NAN });
        t.push(Hit { id: 1, score: 0.5 });
        t.push(Hit { id: 2, score: 0.4 });
        let ids: Vec<usize> = t.into_vec().iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn topk_neg_inf_is_a_valid_score() {
        let mut t = TopK::new(2);
        t.push(Hit { id: 0, score: f32::NEG_INFINITY });
        t.push(Hit { id: 1, score: 0.0 });
        let v = t.into_vec();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].id, 1);
        assert_eq!(v[1].id, 0);
    }

    #[test]
    fn topk_neg_inf_displaces_nan_occupant() {
        let mut t = TopK::new(2);
        t.push(Hit { id: 0, score: f32::NAN });
        t.push(Hit { id: 1, score: 0.5 }); // → [0.5, NaN]
        t.push(Hit { id: 2, score: f32::NEG_INFINITY });
        let ids: Vec<usize> = t.into_vec().iter().map(|h| h.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }
}
