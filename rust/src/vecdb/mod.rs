//! Vector database: exact flat index (the paper's Faiss flat setup) and an
//! IVF approximate index for the performance study.
//!
//! Stores unit-normalized embeddings contiguously (SoA) and returns top-k
//! by inner product (== cosine for unit vectors).

pub mod flat;
pub mod ivf;

pub use flat::FlatIndex;
pub use ivf::IvfIndex;

/// A search hit: external id + similarity score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub id: usize,
    pub score: f32,
}

/// Common interface over index kinds.
pub trait VectorIndex: Send + Sync {
    /// Add a vector with an external id. Vectors must share the index dim.
    fn add(&mut self, id: usize, vector: &[f32]);
    /// Exact or approximate top-k by cosine similarity.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;
    /// Number of stored vectors.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Bounded max-k collector (min-heap semantics via sorted insertion —
/// k is small [top-5 in the paper], so linear insertion beats a heap).
#[derive(Clone, Debug)]
pub(crate) struct TopK {
    k: usize,
    hits: Vec<Hit>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, hits: Vec::with_capacity(k + 1) }
    }

    #[inline]
    pub fn worst(&self) -> f32 {
        if self.hits.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.hits.last().map(|h| h.score).unwrap_or(f32::NEG_INFINITY)
        }
    }

    #[inline]
    pub fn push(&mut self, hit: Hit) {
        if self.hits.len() == self.k && hit.score <= self.worst() {
            return;
        }
        let pos = self
            .hits
            .iter()
            .position(|h| h.score < hit.score)
            .unwrap_or(self.hits.len());
        self.hits.insert(pos, hit);
        if self.hits.len() > self.k {
            self.hits.pop();
        }
    }

    pub fn into_vec(self) -> Vec<Hit> {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_best() {
        let mut t = TopK::new(3);
        for (i, s) in [0.1f32, 0.9, 0.5, 0.7, 0.3, 0.8].iter().enumerate() {
            t.push(Hit { id: i, score: *s });
        }
        let v = t.into_vec();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].id, 1); // 0.9
        assert_eq!(v[1].id, 5); // 0.8
        assert_eq!(v[2].id, 3); // 0.7
    }

    #[test]
    fn topk_fewer_than_k() {
        let mut t = TopK::new(5);
        t.push(Hit { id: 0, score: 0.2 });
        t.push(Hit { id: 1, score: 0.4 });
        let v = t.into_vec();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].id, 1);
    }
}
