//! Quantized flat index: i8 SoA candidate scan + exact f32 rescore.
//!
//! The hot loop never touches f32 rows: queries and stored vectors are
//! quantized with the per-vector-scale codec (`vecdb/quant.rs`) and
//! candidates are scored with the integer [`scan_block`] kernel over
//! 32-row structure-of-arrays blocks — a quarter of the memory traffic of
//! the f32 scan. The top candidates are then *rescored* with the exact
//! same f32 [`dot`] kernel [`FlatIndex`](super::FlatIndex) uses, on
//! bit-identical stored rows, so the final `Hit` list is byte-equal to
//! the flat scan's.
//!
//! # Why the default configuration is provably exact
//!
//! Write a query `x` as `s_x·a + e_x` (codes `a`, scale `s_x`, rounding
//! error `|e_x| ≤ s_x/2` per component) and row `r` as `s_r·b_r + e_r`
//! likewise. The integer score `A_r = s_x·s_r·(a·b_r)` then satisfies
//!
//! ```text
//! |dot(x, y_r) − A_r| ≤ (s_x/2)·(‖a‖₁·max_r s_r + max_r ‖y_r‖₁) = ε
//! ```
//!
//! a *uniform* bound over rows, computable per query from stored
//! bookkeeping. If `T` is the true k-th best f32 score and `A_(k)` the
//! k-th best integer score, every true top-k row has `A_r ≥ T − ε` and
//! `A_(k) ≤ T + ε`, so rescoring every row with `A_r ≥ A_(k) − 2ε`
//! provably covers the exact top-k — including rows flat keeps on score
//! ties, because candidates are rescored in storage order through the
//! same [`TopK`] and ties resolve by push order. ε is additionally
//! inflated to cover f32 summation error in the reference `dot` itself,
//! so the guarantee holds against the *computed* flat scores, not just
//! the real-valued ones.
//!
//! `rescore_factor` (default 4) additionally floors the candidate set at
//! `k × rescore_factor` rows, keeping the scan robust when ε is loose;
//! `rescore_factor = 1` drops the ε margin entirely and rescores exactly
//! the integer top-k — the fast *approximate* mode (recall@5 ≥ 0.9 on
//! unit-norm corpora, property-tested in `tests/index_api.rs`).

use super::quant::{quantize_vector, scan_block, BLOCK_ROWS};
use super::{Hit, TopK, VectorIndex};
use crate::text::embed::dot;

/// Flat index with i8 SoA candidate generation and exact f32 rescore.
#[derive(Clone, Debug, Default)]
pub struct QuantizedFlatIndex {
    dim: usize,
    rescore_factor: usize,
    ids: Vec<usize>,
    /// Full-precision rows (row-major), the rescore ground truth — stored
    /// bit-identical to `FlatIndex` so rescored scores match bitwise.
    rows: Vec<f32>,
    /// i8 codes in blocked SoA layout: block `b` spans rows
    /// `b*BLOCK_ROWS..`, holding `codes[b*dim*32 + d*32 + r]`; tail rows
    /// of the last block are zero-padded (score 0, never selected ahead
    /// of real candidates — they are sliced off before thresholding).
    codes: Vec<i8>,
    /// Per-row quantization scale (`max|y|/127`).
    scales: Vec<f32>,
    /// Running maxima feeding the uniform error bound ε.
    max_scale: f64,
    max_norm1: f64,
    /// Any stored row with a NaN/∞ component voids the error bound; the
    /// index then falls back to the exact full scan (still flat-identical).
    has_nonfinite: bool,
}

impl QuantizedFlatIndex {
    /// An empty index for `dim`-dimensional vectors. `rescore_factor`
    /// (clamped ≥ 1) floors the rescore set at `k × rescore_factor`
    /// candidates; values ≥ 2 keep the ε-margin exactness guarantee,
    /// `1` switches to approximate integer-top-k mode.
    pub fn new(dim: usize, rescore_factor: usize) -> Self {
        QuantizedFlatIndex { dim, rescore_factor: rescore_factor.max(1), ..Default::default() }
    }

    /// Embedding dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The configured rescore factor (≥ 1).
    pub fn rescore_factor(&self) -> usize {
        self.rescore_factor
    }

    /// Full-precision row view (rescore path).
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.dim..(i + 1) * self.dim]
    }

    /// Integer candidate scores for every stored row, in storage order.
    fn approx_scores(&self, qcodes: &[i8], qscale: f32) -> Vec<f64> {
        let n = self.ids.len();
        let mut approx = Vec::with_capacity(n);
        let block_len = self.dim * BLOCK_ROWS;
        for (b, block) in self.codes.chunks_exact(block_len).enumerate() {
            let mut acc = [0i32; BLOCK_ROWS];
            scan_block(qcodes, block, &mut acc);
            let rows_here = (n - b * BLOCK_ROWS).min(BLOCK_ROWS);
            for (r, &a) in acc.iter().enumerate().take(rows_here) {
                let row = b * BLOCK_ROWS + r;
                approx.push(a as f64 * qscale as f64 * self.scales[row] as f64);
            }
        }
        approx
    }

    /// Uniform score-error bound ε for this query (see module docs):
    /// quantization error of both sides plus an allowance for f32
    /// summation error in the reference `dot`, inflated 5 % for slack.
    fn score_epsilon(&self, qcodes: &[i8], qscale: f32, qmax_abs: f64) -> f64 {
        let qnorm1: f64 = qcodes.iter().map(|&c| (c as i64).abs() as f64).sum();
        let quant = (qscale as f64 / 2.0) * (qnorm1 * self.max_scale + self.max_norm1);
        let f32_sum = 2.0 * self.dim as f64 * f32::EPSILON as f64 * qmax_abs * self.max_norm1;
        (quant + f32_sum) * 1.05 + 1e-12
    }

    /// Exact rescore of `candidates` (storage-order row indexes) through
    /// the same f32 kernel + [`TopK`] a [`FlatIndex`](super::FlatIndex)
    /// scan uses — identical scores, identical tie-breaking.
    fn rescore(&self, query: &[f32], candidates: impl Iterator<Item = usize>, k: usize) -> Vec<Hit> {
        let mut top = TopK::new(k);
        for i in candidates {
            top.push(Hit { id: self.ids[i], score: dot(query, self.row(i)) });
        }
        top.into_vec()
    }
}

impl VectorIndex for QuantizedFlatIndex {
    fn add(&mut self, id: usize, vector: &[f32]) {
        assert_eq!(vector.len(), self.dim, "dim mismatch");
        let lane = self.ids.len() % BLOCK_ROWS;
        if lane == 0 {
            // open a fresh zero-padded block (incremental: corpus-ingest
            // events keep adding rows after finalize)
            self.codes.resize(self.codes.len() + self.dim * BLOCK_ROWS, 0);
        }
        let (codes, scale) = quantize_vector(vector);
        let block_start = (self.ids.len() / BLOCK_ROWS) * self.dim * BLOCK_ROWS;
        for (d, &c) in codes.iter().enumerate() {
            self.codes[block_start + d * BLOCK_ROWS + lane] = c;
        }
        let norm1: f64 = vector.iter().map(|&x| x.abs() as f64).sum();
        self.has_nonfinite |= !norm1.is_finite();
        self.max_scale = self.max_scale.max(scale as f64);
        self.max_norm1 = self.max_norm1.max(norm1);
        self.scales.push(scale);
        self.ids.push(id);
        self.rows.extend_from_slice(vector);
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim, "dim mismatch");
        let n = self.ids.len();
        if n == 0 || k == 0 {
            return Vec::new();
        }
        let (qcodes, qscale) = quantize_vector(query);
        let qmax = query.iter().fold(0f32, |m, &x| m.max(x.abs())) as f64;
        let eps = self.score_epsilon(&qcodes, qscale, qmax);
        // Degenerate query (zero / non-finite → zero scale), non-finite
        // stored rows, unusable bound, or k covering the whole corpus:
        // fall back to the exact full scan — still flat-identical.
        if qscale == 0.0 || self.has_nonfinite || !eps.is_finite() || k >= n {
            return self.rescore(query, 0..n, k);
        }
        // All rows and the query are finite here, so every integer
        // candidate score is finite and totally ordered.
        let approx = self.approx_scores(&qcodes, qscale);
        let m = k.saturating_mul(self.rescore_factor).min(n);
        let desc = |a: &f64, b: &f64| b.partial_cmp(a).unwrap();
        let mut ranked = approx.clone();
        // m-th best integer score; the partition's lead then yields the
        // k-th best without a full sort.
        let (lead, &mut a_m, _) = ranked.select_nth_unstable_by(m - 1, desc);
        let a_k = if lead.len() >= k {
            let (_, &mut v, _) = lead.select_nth_unstable_by(k - 1, desc);
            v
        } else {
            a_m // m == k (rescore_factor 1 or clamped by n)
        };
        // ε-margin threshold: every row whose integer score could still be
        // the true f32 top-k survives; a_m floors the set at m candidates.
        let threshold = if self.rescore_factor <= 1 { a_k } else { a_m.min(a_k - 2.0 * eps) };
        let cands = (0..n).filter(|&i| approx[i] >= threshold);
        self.rescore(query, cands, k)
    }

    fn len(&self) -> usize {
        self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::embed::l2_normalize;
    use crate::util::rng::Rng;
    use crate::vecdb::FlatIndex;

    fn random_unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn paired(seed: u64, dim: usize, n: usize, rf: usize) -> (FlatIndex, QuantizedFlatIndex) {
        let mut rng = Rng::new(seed);
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, rf);
        for i in 0..n {
            let v = random_unit(&mut rng, dim);
            flat.add(i + 100, &v);
            quant.add(i + 100, &v);
        }
        (flat, quant)
    }

    #[test]
    fn default_rescore_is_bitwise_flat_identical() {
        let (flat, quant) = paired(41, 32, 500, 4);
        let mut rng = Rng::new(42);
        for _ in 0..40 {
            let q = random_unit(&mut rng, 32);
            for k in [1usize, 3, 5, 17] {
                assert_eq!(quant.search(&q, k), flat.search(&q, k), "k={k}");
            }
        }
    }

    #[test]
    fn batch_matches_per_query_and_flat() {
        let (flat, quant) = paired(43, 24, 300, 4);
        let mut rng = Rng::new(44);
        let queries: Vec<Vec<f32>> = (0..21).map(|_| random_unit(&mut rng, 24)).collect();
        let batched = quant.search_batch(&queries, 5);
        assert_eq!(batched, flat.search_batch(&queries, 5));
    }

    #[test]
    fn ties_resolve_like_flat() {
        let dim = 8;
        let mut a = vec![0f32; dim];
        a[0] = 1.0;
        let mut b = vec![0f32; dim];
        b[1] = 1.0;
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, 4);
        // ids 0..5 duplicate `a`, 5..8 duplicate `b`: heavy score ties
        for i in 0..8 {
            let v = if i < 5 { &a } else { &b };
            flat.add(i, v);
            quant.add(i, v);
        }
        for k in 1..=8 {
            assert_eq!(quant.search(&a, k), flat.search(&a, k), "k={k}");
        }
    }

    #[test]
    fn degenerate_queries_and_shapes_match_flat() {
        let (flat, quant) = paired(47, 16, 60, 4);
        let zero = vec![0f32; 16];
        assert_eq!(quant.search(&zero, 5), flat.search(&zero, 5));
        let mut rng = Rng::new(48);
        let q = random_unit(&mut rng, 16);
        assert_eq!(quant.search(&q, 0), flat.search(&q, 0)); // k = 0
        assert_eq!(quant.search(&q, 60), flat.search(&q, 60)); // k = n
        assert_eq!(quant.search(&q, 100), flat.search(&q, 100)); // k > n
        let empty = QuantizedFlatIndex::new(16, 4);
        assert!(empty.is_empty());
        assert!(empty.search(&q, 5).is_empty());
    }

    #[test]
    fn incremental_add_crosses_block_boundaries() {
        // corpus sizes straddling BLOCK_ROWS multiples, grown between
        // searches (post-finalize ingest path)
        let dim = 12;
        let mut rng = Rng::new(49);
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, 4);
        let q = random_unit(&mut rng, dim);
        for i in 0..(BLOCK_ROWS * 3 + 7) {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            quant.add(i, &v);
            if i % 13 == 0 {
                assert_eq!(quant.search(&q, 5), flat.search(&q, 5), "n={}", i + 1);
            }
        }
        assert_eq!(quant.search(&q, 5), flat.search(&q, 5));
    }

    #[test]
    fn nonfinite_rows_fall_back_to_exact_scan() {
        let dim = 8;
        let mut rng = Rng::new(50);
        let mut flat = FlatIndex::new(dim);
        let mut quant = QuantizedFlatIndex::new(dim, 4);
        for i in 0..40 {
            let v = random_unit(&mut rng, dim);
            flat.add(i, &v);
            quant.add(i, &v);
        }
        let mut bad = vec![0f32; dim];
        bad[0] = f32::NAN;
        flat.add(999, &bad);
        quant.add(999, &bad);
        let q = random_unit(&mut rng, dim);
        assert_eq!(quant.search(&q, 5), flat.search(&q, 5));
    }

    #[test]
    fn rescore_factor_one_is_decent_approximation() {
        let (flat, quant) = paired(51, 32, 400, 1);
        let mut rng = Rng::new(52);
        let (mut hit, mut total) = (0usize, 0usize);
        for _ in 0..30 {
            let q = random_unit(&mut rng, 32);
            let exact: Vec<usize> = flat.search(&q, 5).iter().map(|h| h.id).collect();
            let approx = quant.search(&q, 5);
            assert_eq!(approx.len(), 5);
            hit += approx.iter().filter(|h| exact.contains(&h.id)).count();
            total += 5;
        }
        let recall = hit as f64 / total as f64;
        assert!(recall >= 0.9, "recall@5 = {recall}");
    }

    #[test]
    fn accessors() {
        let q = QuantizedFlatIndex::new(16, 0); // clamps to 1
        assert_eq!(q.dim(), 16);
        assert_eq!(q.rescore_factor(), 1);
        assert_eq!(QuantizedFlatIndex::new(16, 4).rescore_factor(), 4);
    }
}
