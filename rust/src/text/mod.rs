//! Text processing: tokenization and deterministic embeddings.
//!
//! The paper encodes queries with BGE-base-en-v1.5; offline we substitute a
//! dependency-free, deterministic embedding — hashed word/character-n-gram
//! features folded through a signed random projection (see DESIGN.md §5).
//! The only property the PPO identifier and vector retrieval need is that
//! same-domain texts land near each other and cross-domain texts separate,
//! which hashing of shared domain vocabulary provides.

pub mod tokenizer;
pub mod embed;

pub use embed::{Embedder, EMBED_DIM};
pub use tokenizer::tokenize;
