//! Deterministic text embeddings (BGE substitute, see DESIGN.md §5).
//!
//! Sentence embedding: each token (and token bigram) is FNV-hashed to a
//! bucket with a deterministic ±1 sign ("feature hashing" / signed random
//! projection). The accumulated vector is L2-normalized. Same-domain texts
//! share topical vocabulary, so their embeddings cluster — the property the
//! PPO identifier, retrieval, and BERTScore need.
//!
//! Token embeddings (for BERTScore): the token hash seeds a small
//! pseudo-random Gaussian vector, mixed with the hashes of its left/right
//! neighbors so that the embedding is mildly *contextual* like a
//! transformer token embedding.

use crate::text::tokenizer::tokenize;

/// Sentence-embedding dimensionality. Matches the policy network's input
/// width compiled into the AOT artifacts (python/compile/model.py).
pub const EMBED_DIM: usize = 256;

/// Token-embedding dimensionality for BERTScore.
pub const TOKEN_DIM: usize = 64;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a hash of a byte string, with a seed mixed in.
#[inline]
pub fn fnv1a(bytes: &[u8], seed: u64) -> u64 {
    let mut h = FNV_OFFSET ^ seed.wrapping_mul(0x9E3779B97F4A7C15);
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    // final avalanche (splitmix-style)
    let mut z = h;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic embedder. Cloneable and thread-safe (stateless).
#[derive(Clone, Debug)]
pub struct Embedder {
    seed: u64,
}

impl Default for Embedder {
    fn default() -> Self {
        Embedder::new(0x0C0EDCE_u64)
    }
}

impl Embedder {
    /// An embedder with the given hash seed (the default seed is what the
    /// whole stack — corpora, queries, policy features — embeds with).
    pub fn new(seed: u64) -> Self {
        Embedder { seed }
    }

    /// Embed raw text into a unit-norm `EMBED_DIM` vector.
    pub fn embed(&self, text: &str) -> Vec<f32> {
        let tokens = tokenize(text);
        self.embed_tokens(&tokens)
    }

    /// Embed a pre-tokenized text.
    pub fn embed_tokens(&self, tokens: &[String]) -> Vec<f32> {
        let mut v = vec![0f32; EMBED_DIM];
        // Unigrams: weight 1.0. Each token contributes to 4 buckets to
        // reduce hash-collision variance (like multiple hash functions).
        for tok in tokens {
            for probe in 0..4u64 {
                let h = fnv1a(tok.as_bytes(), self.seed ^ (probe.wrapping_mul(0xA5A5A5A5)));
                let bucket = (h as usize >> 1) % EMBED_DIM;
                let sign = if h & 1 == 0 { 1.0 } else { -1.0 };
                v[bucket] += sign;
            }
        }
        // Bigrams: weight 0.5 — adds phrase-level signal.
        for w in tokens.windows(2) {
            let key = format!("{} {}", w[0], w[1]);
            for probe in 0..2u64 {
                let h = fnv1a(key.as_bytes(), self.seed ^ 0xB16B00B5 ^ probe);
                let bucket = (h as usize >> 1) % EMBED_DIM;
                let sign = if h & 1 == 0 { 0.5 } else { -0.5 };
                v[bucket] += sign;
            }
        }
        l2_normalize(&mut v);
        v
    }

    /// Contextual token embeddings for BERTScore: each token's vector is a
    /// mix of its own hash-seeded Gaussian direction (weight 0.7) and its
    /// neighbors' (0.15 each).
    ///
    /// Base directions are deterministic per token hash, so they are
    /// memoized in a process-wide cache (§Perf: regenerating the Gaussian
    /// draws dominated BERTScore cost before this cache, ~2.5 µs/token).
    pub fn token_embeddings(&self, tokens: &[String]) -> Vec<Vec<f32>> {
        let base: Vec<std::sync::Arc<Vec<f32>>> = tokens
            .iter()
            .map(|t| cached_gaussian(fnv1a(t.as_bytes(), self.seed ^ 0x7E57)))
            .collect();
        let n = tokens.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut v = vec![0f32; TOKEN_DIM];
            for (w, j) in [
                (0.7f32, i as isize),
                (0.15, i as isize - 1),
                (0.15, i as isize + 1),
            ] {
                if j >= 0 && (j as usize) < n {
                    for (o, b) in v.iter_mut().zip(base[j as usize].iter()) {
                        *o += w * b;
                    }
                }
            }
            l2_normalize(&mut v);
            out.push(v);
        }
        out
    }
}

/// Process-wide memo for token base directions (bounded; cleared when it
/// exceeds ~200k entries to cap memory on unbounded vocabularies).
fn cached_gaussian(seed: u64) -> std::sync::Arc<Vec<f32>> {
    use std::collections::HashMap;
    use std::sync::{Arc, OnceLock, RwLock};
    static CACHE: OnceLock<RwLock<HashMap<u64, Arc<Vec<f32>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| RwLock::new(HashMap::new()));
    if let Some(v) = cache.read().unwrap().get(&seed) {
        return v.clone();
    }
    let v = Arc::new(gaussian_vec(seed, TOKEN_DIM));
    let mut w = cache.write().unwrap();
    if w.len() > 200_000 {
        w.clear();
    }
    w.insert(seed, v.clone());
    v
}

/// Seeded pseudo-Gaussian unit vector (deterministic per seed).
fn gaussian_vec(seed: u64, dim: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

/// In-place L2 normalization (no-op on zero vectors).
pub fn l2_normalize(v: &mut [f32]) {
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
}

/// Dense cosine similarity (assumes same length).
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let mut dot = 0f32;
    let mut na = 0f32;
    let mut nb = 0f32;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na <= 1e-12 || nb <= 1e-12 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

/// Dot product of two unit vectors (cosine for pre-normalized inputs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    // 4-way unrolled accumulation — hot path for retrieval.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    for k in chunks * 4..a.len() {
        acc += a[k] * b[k];
    }
    acc + s0 + s1 + s2 + s3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_unit_norm_and_deterministic() {
        let e = Embedder::default();
        let v1 = e.embed("the market closed higher on strong earnings");
        let v2 = e.embed("the market closed higher on strong earnings");
        assert_eq!(v1, v2);
        let n: f32 = v1.iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 1e-5);
    }

    #[test]
    fn similar_texts_closer_than_different() {
        let e = Embedder::default();
        let a = e.embed("stock market equity dividend portfolio earnings");
        let b = e.embed("market earnings dividend stock price equity");
        let c = e.embed("tennis football championship goal referee match");
        assert!(cosine(&a, &b) > cosine(&a, &c) + 0.2);
    }

    #[test]
    fn empty_text_is_zero_vector() {
        let e = Embedder::default();
        let v = e.embed("");
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn token_embeddings_contextual() {
        let e = Embedder::default();
        let t1: Vec<String> = ["bank", "river", "water"].iter().map(|s| s.to_string()).collect();
        let t2: Vec<String> = ["bank", "money", "loan"].iter().map(|s| s.to_string()).collect();
        let e1 = e.token_embeddings(&t1);
        let e2 = e.token_embeddings(&t2);
        // same token in different contexts -> similar but not identical
        let sim = cosine(&e1[0], &e2[0]);
        assert!(sim > 0.5, "sim={sim}");
        assert!(sim < 0.9999, "sim={sim}");
        // unit norms
        for v in e1.iter().chain(e2.iter()) {
            let n: f32 = v.iter().map(|x| x * x).sum();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut r = crate::util::rng::Rng::new(77);
        let a: Vec<f32> = (0..103).map(|_| r.normal() as f32).collect();
        let b: Vec<f32> = (0..103).map(|_| r.normal() as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn fnv_seed_sensitivity() {
        assert_ne!(fnv1a(b"hello", 1), fnv1a(b"hello", 2));
        assert_ne!(fnv1a(b"hello", 1), fnv1a(b"hellp", 1));
    }
}
