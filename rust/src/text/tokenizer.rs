//! Whitespace/punctuation tokenizer with lowercasing.
//!
//! All metrics (ROUGE/BLEU/METEOR/BERTScore) and the embedder share this
//! tokenization so lexical and semantic scores are computed over the same
//! token stream, as in the paper's evaluation pipeline.

/// Tokenize: lowercase, split on non-alphanumeric, drop empties.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_alphanumeric() {
            for lc in c.to_lowercase() {
                cur.push(lc);
            }
        } else if !cur.is_empty() {
            tokens.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        tokens.push(cur);
    }
    tokens
}

/// N-gram iterator over a token slice (as joined strings).
pub fn ngrams(tokens: &[String], n: usize) -> Vec<String> {
    if tokens.len() < n || n == 0 {
        return Vec::new();
    }
    (0..=tokens.len() - n)
        .map(|i| tokens[i..i + n].join(" "))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_tokenize() {
        assert_eq!(
            tokenize("Hello, World! 42x"),
            vec!["hello", "world", "42x"]
        );
    }

    #[test]
    fn empty_and_punct_only() {
        assert!(tokenize("").is_empty());
        assert!(tokenize("?!., --").is_empty());
    }

    #[test]
    fn unicode_lowercase() {
        assert_eq!(tokenize("Größe MATTERS"), vec!["größe", "matters"]);
    }

    #[test]
    fn ngram_windows() {
        let t: Vec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        assert_eq!(ngrams(&t, 2), vec!["a b", "b c"]);
        assert_eq!(ngrams(&t, 3), vec!["a b c"]);
        assert!(ngrams(&t, 4).is_empty());
        assert!(ngrams(&t, 0).is_empty());
    }
}
