//! `coedge` — CLI launcher for the CoEdge-RAG framework.
//!
//! Subcommands:
//!   run      [--config FILE] [--slots N] [--allocator KIND] [--slo S]
//!            [--checkpoint FILE] [--index KIND] [--shards N]
//!            [--rescore-factor N] [--cache KIND] [--cache-mb N]
//!            [--scenario FILE] [--transcript FILE]
//!            run a full experiment and print per-slot results; with
//!            --scenario, replay a cluster-dynamics timeline (node churn,
//!            bursts, SLO changes, live corpus ingest, live reindex
//!            migration with background rebuild + atomic swap) under its
//!            arrival trace and optionally dump the byte-stable run
//!            transcript;
//!            --allocator ppo-pretrained --checkpoint FILE deploys a
//!            frozen trained policy
//!   eval     [--grid paper|smoke] [--threads N] [--scenarios DIR]
//!            [--bench-dir DIR] [--results FILE] [--checkpoint FILE]
//!            run the baseline-comparison evaluation grid (allocators ×
//!            datasets × scenario fixtures) in parallel and regenerate
//!            BENCH_eval.json + docs/RESULTS.md — byte-deterministic, so
//!            CI replays it like the golden traces; with --checkpoint,
//!            the grid grows a ppo-pretrained column
//!   train    [--scenarios DIR] [--replicas N] [--epochs N] [--seed S]
//!            [--threads N] [--checkpoint-out FILE] [--bench-dir DIR]
//!            run the vectorized PPO rollout farm over the scenario
//!            fixtures, write the learning curve to BENCH_train.json and
//!            the trained policy to a versioned checkpoint —
//!            byte-deterministic across runs and thread counts
//!   fuzz     [--count N] [--seed S] [--allocator KIND|all] [--threads N]
//!            [--out-dir DIR]
//!            generate N random-but-valid scenario timelines, replay each
//!            under the invariant oracle on a fresh seeded coordinator,
//!            shrink any failure to a minimal repro, and write
//!            BENCH_fuzz.json + FUZZ_failures.txt (byte-deterministic
//!            across runs and thread counts); exits 1 on violations
//!   serve    [--addr A] [--config FILE] [--transcript FILE] [--pipeline]
//!            [--queue-depth N] [--max-batch N] [--batch-window-ms MS]
//!            start the TCP serving front-end: bounded admission queue
//!            with explicit overload responses, dynamic batching, and —
//!            with --pipeline — encode/serve overlap on the
//!            coordinator's phase seam (wall-clock only; responses and
//!            transcripts are byte-identical either way)
//!   profile  [--config FILE]                 print per-node capacity models
//!   info                                     artifact/runtime diagnostics

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{
    AllocatorKind, CacheKind, DatasetKind, ExperimentConfig, IndexKind, PPO_PRETRAINED_KEY,
};
use coedge_rag::coordinator::{AllocatorRegistry, CoordinatorBuilder};
use coedge_rag::experiments::EvalGrid;
use coedge_rag::fuzz::{run_fuzz, FuzzConfig};
use coedge_rag::policy::ppo::Backend;
use coedge_rag::runtime::PolicyRuntime;
use coedge_rag::scenario::{resolve_scenarios_dir, Scenario, ScenarioRunner};
use coedge_rag::server::{serve, ServerConfig};
use coedge_rag::train::{TrainConfig, TrainFarm};
use coedge_rag::util::logging;

fn parse_flags(args: &[String]) -> std::collections::HashMap<String, String> {
    let mut m = std::collections::HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

fn load_config(flags: &std::collections::HashMap<String, String>) -> ExperimentConfig {
    let mut cfg = match flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).expect("read config");
            ExperimentConfig::from_toml(&text).expect("parse config")
        }
        None => ExperimentConfig::paper_cluster(DatasetKind::DomainQa),
    };
    if let Some(v) = flags.get("slots") {
        cfg.slots = v.parse().expect("--slots");
    }
    if let Some(v) = flags.get("slo") {
        cfg.slo_s = v.parse().expect("--slo");
    }
    if let Some(v) = flags.get("queries") {
        cfg.queries_per_slot = v.parse().expect("--queries");
    }
    if let Some(v) = flags.get("allocator") {
        // Table II enum kinds resolve directly; ppo-pretrained is a
        // registry-key override (needs --checkpoint); anything else lists
        // every registered key
        match v.parse::<AllocatorKind>() {
            Ok(kind) => {
                cfg.allocator = kind;
                cfg.allocator_override = None;
            }
            Err(_) if v == PPO_PRETRAINED_KEY => {
                cfg.allocator_override = Some(v.clone());
            }
            Err(_) => {
                eprintln!(
                    "[coedge] --allocator: unknown allocator {v:?}; valid kinds: {}",
                    AllocatorRegistry::with_builtins().kinds().join(", ")
                );
                std::process::exit(2);
            }
        }
    }
    if let Some(v) = flags.get("checkpoint") {
        cfg.checkpoint = Some(std::path::PathBuf::from(v));
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse().expect("--seed");
    }
    if let Some(v) = flags.get("index") {
        // built-in kinds validate here; custom kinds need register_index
        let kind = v.parse::<IndexKind>().unwrap_or_else(|e| {
            eprintln!("[coedge] --index: {e}");
            std::process::exit(2);
        });
        for n in cfg.nodes.iter_mut() {
            n.index.kind = kind.as_str().to_string();
        }
    }
    if let Some(v) = flags.get("shards") {
        let shards: usize = v.parse().expect("--shards");
        for n in cfg.nodes.iter_mut() {
            n.index.shards = shards;
        }
    }
    if let Some(v) = flags.get("rescore-factor") {
        let rf: usize = v.parse().expect("--rescore-factor");
        for n in cfg.nodes.iter_mut() {
            n.index.rescore_factor = rf;
        }
    }
    if let Some(v) = flags.get("cache") {
        // built-in kinds validate here; custom kinds need register_cache
        let kind = v.parse::<CacheKind>().unwrap_or_else(|e| {
            eprintln!("[coedge] --cache: {e}");
            std::process::exit(2);
        });
        cfg.cache.kind = kind.as_str().to_string();
        for n in cfg.nodes.iter_mut() {
            n.cache.kind = kind.as_str().to_string();
        }
    }
    if let Some(v) = flags.get("cache-mb") {
        let mb: usize = v.parse().expect("--cache-mb");
        cfg.cache.capacity_mb = mb;
        for n in cfg.nodes.iter_mut() {
            n.cache.capacity_mb = mb;
        }
    }
    cfg
}

/// The allocator a config will resolve to, for log lines (the registry-key
/// override wins over the Table II enum, mirroring the builder).
fn allocator_label(cfg: &ExperimentConfig) -> String {
    cfg.allocator_override.clone().unwrap_or_else(|| cfg.allocator.to_string())
}

fn backend() -> Backend {
    match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => {
            eprintln!("[coedge] PJRT runtime loaded ({} artifacts)", rt.manifest().artifacts.len());
            Backend::Pjrt(Arc::new(rt))
        }
        Err(e) => {
            eprintln!("[coedge] no artifacts ({e}); using the pure-Rust reference backend");
            Backend::Reference
        }
    }
}

fn cmd_run(flags: std::collections::HashMap<String, String>) {
    let cfg = load_config(&flags);
    if let Some(path) = flags.get("scenario") {
        return cmd_run_scenario(cfg, path, flags.get("transcript"));
    }
    let slots = cfg.slots;
    eprintln!(
        "[coedge] running {slots} slots × {} queries, SLO {}s, allocator {}",
        cfg.queries_per_slot,
        cfg.slo_s,
        allocator_label(&cfg)
    );
    let mut co =
        CoordinatorBuilder::new(cfg).backend(backend()).build().expect("build coordinator");
    let mut table = Table::new(&[
        "slot", "queries", "R-L", "BERT", "drop%", "latency(s)", "p_j", "ppo_upd",
    ]);
    for t in 0..slots {
        let qids = co.sample_queries(co.cfg.queries_per_slot).expect("sample queries");
        let r = co.run_slot(&qids).expect("slot");
        table.row(vec![
            format!("{t}"),
            format!("{}", r.queries),
            format!("{:.3}", r.mean_scores.rouge_l),
            format!("{:.3}", r.mean_scores.bert_score),
            format!("{:.2}", r.drop_rate * 100.0),
            format!("{:.2}", r.latency_s),
            r.proportions.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join("/"),
            format!("{}", r.ppo_updates),
        ]);
    }
    table.print();
}

/// `run --scenario FILE`: replay a cluster-dynamics timeline under its
/// arrival trace, printing per-slot events/availability next to the usual
/// quality columns; `--transcript FILE` dumps the byte-stable JSONL.
fn cmd_run_scenario(cfg: ExperimentConfig, path: &str, transcript: Option<&String>) {
    let text = std::fs::read_to_string(path).expect("read scenario");
    let sc = Scenario::from_toml(&text).unwrap_or_else(|e| {
        eprintln!("[coedge] --scenario: {e}");
        std::process::exit(2);
    });
    eprintln!(
        "[coedge] scenario {:?}: {} events over {} slots, allocator {}",
        sc.name,
        sc.events.len(),
        sc.slots.unwrap_or(cfg.slots),
        allocator_label(&cfg)
    );
    let mut co =
        CoordinatorBuilder::new(cfg).backend(backend()).build().expect("build coordinator");
    let runner = ScenarioRunner::new(sc);
    let run = runner.run(&mut co).unwrap_or_else(|e| {
        eprintln!("[coedge] scenario run: {e}");
        std::process::exit(2);
    });
    let mut table = Table::new(&[
        "slot", "queries", "events", "active", "R-L", "drop%", "p_j",
    ]);
    for (t, r) in run.reports.iter().enumerate() {
        let events: Vec<String> =
            runner.scenario().events_at(t).map(|e| e.event.label()).collect();
        table.row(vec![
            format!("{t}"),
            format!("{}", r.queries),
            if events.is_empty() { "-".into() } else { events.join(" ") },
            r.active.iter().map(|&a| if a { '#' } else { '.' }).collect::<String>(),
            format!("{:.3}", r.mean_scores.rouge_l),
            format!("{:.2}", r.drop_rate * 100.0),
            r.proportions.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join("/"),
        ]);
    }
    table.print();
    if let Some(out) = transcript {
        run.transcript.write_to(std::path::Path::new(out)).expect("write transcript");
        eprintln!("[coedge] transcript written to {out}");
    }
}

/// `eval`: run the baseline-comparison grid and regenerate the committed
/// evaluation artifacts (`BENCH_eval.json` + `docs/RESULTS.md`). Two runs
/// of the same grid are byte-identical — CI diffs them like goldens.
fn cmd_eval(flags: std::collections::HashMap<String, String>) {
    let grid_name = flags.get("grid").map(String::as_str).unwrap_or("paper");
    let mut grid = EvalGrid::by_name(grid_name).unwrap_or_else(|e| {
        eprintln!("[coedge] --grid: {e}");
        std::process::exit(2);
    });
    if let Some(ckpt) = flags.get("checkpoint") {
        grid.pretrained = Some(std::path::PathBuf::from(ckpt));
    }
    let threads: usize = match flags.get("threads") {
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("[coedge] --threads: expected a number, got {v:?}");
            std::process::exit(2);
        }),
        None => 0,
    };
    let scenarios_dir = resolve_scenarios_dir(flags.get("scenarios").map(String::as_str))
        .unwrap_or_else(|e| {
            eprintln!("[coedge] --scenarios: {e}");
            std::process::exit(2);
        });
    // default artifact locations: the repository root (the parent of the
    // fixture directory), so `coedge eval` run from the root or from
    // `rust/` regenerates the committed files in place
    let root = scenarios_dir.parent().map(std::path::Path::to_path_buf).unwrap_or_default();
    let bench_dir = flags.get("bench-dir").map(std::path::PathBuf::from).unwrap_or_else(|| {
        if root.as_os_str().is_empty() { std::path::PathBuf::from(".") } else { root.clone() }
    });
    let results = flags
        .get("results")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| root.join("docs/RESULTS.md"));

    eprintln!(
        "[coedge] eval grid {:?}: {} cells ({} datasets × {} scenarios × {} allocators)",
        grid.name,
        grid.num_cells(),
        grid.datasets.len(),
        grid.scenarios.len(),
        grid.allocators.len() + usize::from(grid.pretrained.is_some())
    );
    let report = grid.run(&scenarios_dir, threads).unwrap_or_else(|e| {
        eprintln!("[coedge] eval: {e}");
        std::process::exit(2);
    });

    let mut table = Table::new(&[
        "cell", "R-L", "BERT", "drop%", "lat(s)", "p95(s)", "slo%", "hit%",
    ]);
    for c in &report.cells {
        let m = &c.metrics;
        table.row(vec![
            c.name(),
            format!("{:.4}", m.rouge_l),
            format!("{:.4}", m.bert_score),
            format!("{:.2}", m.drop_rate * 100.0),
            format!("{:.3}", m.mean_latency_s),
            format!("{:.3}", m.p95_latency_s),
            format!("{:.1}", m.slo_attainment * 100.0),
            m.cache_hit_rate.map(|h| format!("{:.1}", h * 100.0)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print();

    fn fail(what: &str, e: &dyn std::fmt::Display) -> ! {
        eprintln!("[coedge] {what}: {e}");
        std::process::exit(2);
    }
    let json_path = coedge_rag::bench_harness::write_bench_json(
        &bench_dir,
        "eval",
        &report.to_bench_cases(),
    )
    .unwrap_or_else(|e| fail("write BENCH_eval.json", &e));
    if let Some(parent) = results.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .unwrap_or_else(|e| fail(&format!("create {}", parent.display()), &e));
        }
    }
    std::fs::write(&results, report.render_markdown())
        .unwrap_or_else(|e| fail(&format!("write {}", results.display()), &e));
    eprintln!("[coedge] wrote {} and {}", json_path.display(), results.display());
}

/// `train`: run the vectorized PPO rollout farm over the scenario
/// fixtures, print the learning curve, and persist `BENCH_train.json` +
/// a versioned policy checkpoint. Byte-deterministic across runs and
/// thread counts (CI double-runs at `--threads 4` vs `--threads 1` and
/// byte-diffs both artifacts).
fn cmd_train(flags: std::collections::HashMap<String, String>) {
    fn numeric<T: std::str::FromStr>(
        flags: &std::collections::HashMap<String, String>,
        key: &str,
        default: T,
    ) -> T {
        match flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("[coedge] --{key}: expected a number, got {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }
    let scenarios_dir = resolve_scenarios_dir(flags.get("scenarios").map(String::as_str))
        .unwrap_or_else(|e| {
            eprintln!("[coedge] --scenarios: {e}");
            std::process::exit(2);
        });
    let defaults = TrainConfig::default();
    let tcfg = TrainConfig {
        replicas: numeric(&flags, "replicas", defaults.replicas),
        epochs: numeric(&flags, "epochs", defaults.epochs),
        seed: numeric(&flags, "seed", defaults.seed),
        threads: numeric(&flags, "threads", defaults.threads),
        ..defaults
    };
    let farm = TrainFarm::from_dir(&scenarios_dir, tcfg.clone()).unwrap_or_else(|e| {
        eprintln!("[coedge] train: {e}");
        std::process::exit(2);
    });
    eprintln!(
        "[coedge] train: {} cells/epoch ({} fixtures × {} replicas) × {} epochs, seed {}",
        farm.num_cells(),
        farm.num_cells() / tcfg.replicas,
        tcfg.replicas,
        tcfg.epochs,
        tcfg.seed
    );
    let report = farm.run().unwrap_or_else(|e| {
        eprintln!("[coedge] train: {e}");
        std::process::exit(2);
    });

    let mut table = Table::new(&[
        "epoch", "transitions", "updates", "reward", "R-L", "drop%", "loss", "entropy",
    ]);
    for e in &report.curve {
        table.row(vec![
            format!("{}", e.epoch),
            format!("{}", e.transitions),
            format!("{}", e.updates),
            format!("{:.4}", e.mean_reward),
            format!("{:.4}", e.rouge_l),
            format!("{:.2}", e.drop_rate * 100.0),
            format!("{:.4}", e.loss),
            format!("{:.4}", e.entropy),
        ]);
    }
    table.print();

    // default artifact locations mirror `coedge eval`: the repository root
    // (the parent of the fixture directory)
    let root = scenarios_dir.parent().map(std::path::Path::to_path_buf).unwrap_or_default();
    let bench_dir = flags.get("bench-dir").map(std::path::PathBuf::from).unwrap_or_else(|| {
        if root.as_os_str().is_empty() { std::path::PathBuf::from(".") } else { root.clone() }
    });
    let ckpt = flags
        .get("checkpoint-out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| bench_dir.join("policy.ckpt"));
    let json_path = coedge_rag::bench_harness::write_bench_json(
        &bench_dir,
        "train",
        &report.to_bench_cases(),
    )
    .unwrap_or_else(|e| {
        eprintln!("[coedge] write BENCH_train.json: {e}");
        std::process::exit(2);
    });
    report.save_checkpoint(&ckpt).unwrap_or_else(|e| {
        eprintln!("[coedge] write {}: {e}", ckpt.display());
        std::process::exit(2);
    });
    eprintln!(
        "[coedge] wrote {} and {} (deploy with: coedge run --allocator {} --checkpoint {})",
        json_path.display(),
        ckpt.display(),
        PPO_PRETRAINED_KEY,
        ckpt.display()
    );
}

/// `fuzz`: run the scenario fuzzing sweep — seeded timeline generator →
/// invariant oracle → failure shrinker — and write `BENCH_fuzz.json` +
/// `FUZZ_failures.txt` (plus one minimized fixture TOML per failing
/// case). Byte-deterministic across runs and thread counts (CI runs the
/// sweep twice and diffs both artifacts). Exits 1 if any case fails.
fn cmd_fuzz(flags: std::collections::HashMap<String, String>) {
    fn numeric<T: std::str::FromStr>(
        flags: &std::collections::HashMap<String, String>,
        key: &str,
        default: T,
    ) -> T {
        match flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("[coedge] --{key}: expected a number, got {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }
    let defaults = FuzzConfig::default();
    let allocator = match flags.get("allocator").map(String::as_str) {
        None | Some("all") => None,
        Some(v) => match v.parse::<AllocatorKind>() {
            Ok(kind) => Some(kind),
            Err(e) => {
                eprintln!("[coedge] --allocator: {e} (or \"all\" to cycle every kind)");
                std::process::exit(2);
            }
        },
    };
    let fcfg = FuzzConfig {
        count: numeric(&flags, "count", defaults.count),
        seed: numeric(&flags, "seed", defaults.seed),
        allocator,
        threads: numeric(&flags, "threads", defaults.threads),
        ..defaults
    };
    let out_dir = std::path::PathBuf::from(
        flags.get("out-dir").map(String::as_str).unwrap_or("."),
    );
    eprintln!(
        "[coedge] fuzz: {} cases from seed {}, allocator {}",
        fcfg.count,
        fcfg.seed,
        fcfg.allocator.map(|k| k.to_string()).unwrap_or_else(|| "all (seed-cycled)".into())
    );
    let report = run_fuzz(&fcfg);

    let mut table = Table::new(&["allocator", "cases", "failures", "events", "queries"]);
    for kind in AllocatorKind::ALL {
        let cases: Vec<_> = report.cases.iter().filter(|c| c.allocator == kind).collect();
        if cases.is_empty() {
            continue;
        }
        table.row(vec![
            kind.to_string(),
            format!("{}", cases.len()),
            format!("{}", cases.iter().filter(|c| !c.violations.is_empty()).count()),
            format!("{}", cases.iter().map(|c| c.events).sum::<usize>()),
            format!("{}", cases.iter().map(|c| c.queries).sum::<usize>()),
        ]);
    }
    table.print();

    let paths = report.write_artifacts(&out_dir).unwrap_or_else(|e| {
        eprintln!("[coedge] write fuzz artifacts: {e}");
        std::process::exit(2);
    });
    eprintln!(
        "[coedge] wrote {}",
        paths.iter().map(|p| p.display().to_string()).collect::<Vec<_>>().join(", ")
    );
    let failures = report.failures();
    if !failures.is_empty() {
        eprintln!("[coedge] {} of {} cases violated invariants:", failures.len(), fcfg.count);
        eprint!("{}", report.failure_report());
        std::process::exit(1);
    }
    eprintln!("[coedge] all {} cases passed", fcfg.count);
}

fn cmd_profile(flags: std::collections::HashMap<String, String>) {
    let cfg = load_config(&flags);
    let co = CoordinatorBuilder::new(cfg).backend(Backend::Reference).build().expect("build");
    let mut t = Table::new(&[
        "node", "gpus", "corpus", "index", "cache", "C(5s)", "C(15s)", "C(60s)", "k", "b",
    ]);
    for (n, cap) in co.nodes.iter().zip(&co.capacities) {
        t.row(vec![
            n.name.clone(),
            format!("{}", n.gpus.len()),
            format!("{}", n.corpus_size()),
            n.index_kind.clone(),
            n.cache_kind.clone(),
            format!("{:.0}", cap.eval(5.0)),
            format!("{:.0}", cap.eval(15.0)),
            format!("{:.0}", cap.eval(60.0)),
            format!("{:.1}", cap.k),
            format!("{:.1}", cap.b),
        ]);
    }
    t.print();
}

/// `serve`: expose the coordinator over the line-JSON TCP protocol.
/// `--pipeline` turns on the two-stage engine (encode batch k+1 while
/// batch k serves — wall-clock only, responses identical); the admission
/// queue is bounded by `--queue-depth` and answers overload explicitly.
fn cmd_serve(flags: std::collections::HashMap<String, String>) {
    fn numeric<T: std::str::FromStr>(
        flags: &std::collections::HashMap<String, String>,
        key: &str,
        default: T,
    ) -> T {
        match flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("[coedge] --{key}: expected a number, got {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }
    let cfg = load_config(&flags);
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7717".into());
    let transcript_path = flags.get("transcript").map(std::path::PathBuf::from);
    let defaults = ServerConfig::default();
    let scfg = ServerConfig {
        addr,
        transcript_path,
        pipeline: flags.contains_key("pipeline"),
        queue_depth: numeric(&flags, "queue-depth", defaults.queue_depth),
        max_batch: numeric(&flags, "max-batch", defaults.max_batch),
        batch_window_ms: numeric(&flags, "batch-window-ms", defaults.batch_window_ms),
        ..defaults
    };
    let co =
        CoordinatorBuilder::new(cfg).backend(backend()).build().expect("build coordinator");
    let shutdown = Arc::new(AtomicBool::new(false));
    eprintln!(
        "[coedge] serving on {} ({}, queue depth {}; line-JSON; send {{\"id\":1,\"qa_id\":0}})",
        scfg.addr,
        if scfg.pipeline { "pipelined" } else { "synchronous" },
        scfg.queue_depth
    );
    serve(co, scfg, shutdown).expect("serve");
}

fn cmd_info() {
    match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => {
            let m = rt.manifest();
            println!("artifacts dir : {:?}", PolicyRuntime::default_dir());
            println!("embed_dim     : {}", m.embed_dim);
            println!("lr / clip / β : {} / {} / {}", m.learning_rate, m.clip_eps, m.entropy_beta);
            let mut t = Table::new(&["name", "kind", "n", "batch"]);
            for a in &m.artifacts {
                t.row(vec![
                    a.name.clone(),
                    a.kind.clone(),
                    a.n_actions.to_string(),
                    a.batch.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("no artifacts: {e}\nrun `make artifacts` first"),
    }
}

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "run" => cmd_run(flags),
        "eval" => cmd_eval(flags),
        "train" => cmd_train(flags),
        "fuzz" => cmd_fuzz(flags),
        "profile" => cmd_profile(flags),
        "serve" => cmd_serve(flags),
        "info" => cmd_info(),
        _ => {
            println!("coedge — CoEdge-RAG launcher");
            println!("usage: coedge <run|eval|train|fuzz|serve|profile|info> [--config FILE] [--slots N]");
            println!(
                "              [--queries N] [--slo S] [--allocator {}]",
                AllocatorRegistry::with_builtins().kinds().join("|")
            );
            println!("              [--checkpoint FILE]   (with --allocator ppo-pretrained)");
            println!(
                "              [--index {}] [--shards N] [--rescore-factor N]",
                IndexKind::ALL.map(|k| k.as_str()).join("|")
            );
            println!(
                "              [--cache {}] [--cache-mb N]",
                CacheKind::ALL.map(|k| k.as_str()).join("|")
            );
            println!("              [--scenario FILE] [--transcript FILE]");
            println!("       coedge eval [--grid paper|smoke] [--threads N] [--scenarios DIR]");
            println!("              [--bench-dir DIR] [--results FILE] [--checkpoint FILE]");
            println!("       coedge train [--scenarios DIR] [--replicas N] [--epochs N] [--seed S]");
            println!("              [--threads N] [--checkpoint-out FILE] [--bench-dir DIR]");
            println!("       coedge fuzz [--count N] [--seed S] [--allocator KIND|all]");
            println!("              [--threads N] [--out-dir DIR]");
            println!("       coedge serve [--addr A] [--pipeline] [--queue-depth N]");
            println!("              [--max-batch N] [--batch-window-ms MS] [--transcript FILE]");
        }
    }
}
