//! Memory-governed multi-level query caching: the third registry-patterned
//! subsystem (mirroring `AllocatorRegistry` / `IndexRegistry`).
//!
//! Two cache levels share one [`QueryCache`] trait:
//!
//! * a **per-node retrieval cache** — quantized-query-embedding key →
//!   the top-k [`Hit`] list the node's vector index returned, so repeated
//!   and near-duplicate queries skip the index search entirely;
//! * a **cluster-level semantic answer cache** — the same quantized key,
//!   looked up by cosine-similarity threshold ([`QueryCache::get_similar`];
//!   `threshold = 1.0` means *exact duplicates only*), holding the full
//!   served answer ([`CachedAnswer`]) so a duplicate query never reaches a
//!   node at all.
//!
//! Everything is **modeled and deterministic**: keys are deterministic
//! i8-quantized embeddings, the byte accounting is a fixed per-entry
//! model ([`entry_bytes`]), and eviction order depends only on the access
//! sequence — never on wall-clock — so cached runs replay byte-identically
//! in the golden-trace harness. Cache bytes are charged against the node's
//! memory budget (`CacheSpec::node_mem_mb`), shrinking the memory cap the
//! intra-node solver may hand to generation models: cache footprint
//! genuinely competes with generation memory, the paper's §IV-C
//! latency-quality trade-off widened by a third axis.
//!
//! Policies are string-keyed in [`CacheRegistry`] (`lru` / `lfu` /
//! `none`); custom policies register through
//! `CoordinatorBuilder::register_cache` exactly like custom allocators
//! and indexes.

pub mod registry;

pub use registry::{CacheBuildCtx, CacheKind, CacheRegistry, CacheSpec};

use std::collections::BTreeMap;

use crate::metrics::QualityScores;
use crate::vecdb::Hit;

/// Provenance tag stored with every entry, consulted by invalidation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EntryTag {
    /// Node whose corpus/serving produced the entry.
    pub node: usize,
    /// Query domain the entry was written for.
    pub domain: usize,
}

/// A complete served answer, replayable on a cache hit without touching
/// any node. Scores are the *stored* (originally generated) metrics, so a
/// hit at `threshold = 1.0` reproduces the original quality bitwise.
#[derive(Clone, Debug)]
pub struct CachedAnswer {
    /// Node that originally served the answer (provenance).
    pub node: usize,
    /// Model within the node's pool that generated it (None if dropped).
    pub model_idx: Option<usize>,
    /// Retrieval relevance achieved when the answer was generated.
    pub rel: f64,
    /// Quality metrics of the original generation (replayed bitwise).
    pub scores: QualityScores,
    /// Composite feedback f_i of the original serve.
    pub feedback: f64,
}

/// What a cache entry holds: retrieval results or a full answer.
#[derive(Clone, Debug)]
pub enum CachePayload {
    /// Top-k retrieval hits (per-node retrieval cache).
    Hits(Vec<Hit>),
    /// A served answer (cluster-level semantic answer cache).
    Answer(CachedAnswer),
}

/// One cache entry: provenance tag + full-precision identity guard +
/// payload. `guard` is [`embedding_guard`] of the embedding the entry was
/// written for; exact-threshold lookups reject a key hit whose guard
/// differs (quantization collision — see [`embedding_guard`]).
#[derive(Clone, Debug)]
pub struct CacheEntry {
    /// Provenance (node, domain) consulted by invalidation.
    pub tag: EntryTag,
    /// Full-precision identity guard ([`embedding_guard`]).
    pub guard: u64,
    /// The cached retrieval hits or served answer.
    pub payload: CachePayload,
}

/// Modeled size of one entry in bytes (deterministic — never `size_of`
/// guesses that could drift across platforms): quantized key + a fixed
/// per-payload cost + bookkeeping overhead.
pub fn entry_bytes(key: &[i8], entry: &CacheEntry) -> usize {
    const OVERHEAD: usize = 32;
    const PER_HIT: usize = 16; // id + score, padded
    const ANSWER: usize = 64; // scores + provenance
    let payload = match &entry.payload {
        CachePayload::Hits(hits) => PER_HIT * hits.len(),
        CachePayload::Answer(_) => ANSWER,
    };
    key.len() + payload + OVERHEAD
}

// The i8 key codec is the retrieval tier's shared fixed-scale codec
// (`vecdb/quant.rs`) — re-exported so existing callers keep their paths.
// Byte-identity with the historical private implementation is pinned by
// `shared_codec_is_byte_identical_to_cache_keys` below: cache keys (and
// therefore the committed cache goldens, e.g. `repeat_storm_lru`) must
// not move.
pub use crate::vecdb::quant::{quantize_embedding, quantized_cosine};

/// 64-bit identity guard of the *full-precision* embedding (FNV-1a over
/// the raw f32 bit patterns). Quantized keys can in principle merge two
/// nearly-identical-but-distinct embeddings; exact-threshold callers
/// store this with the entry and compare it on a key hit, so a
/// quantization collision degrades to a cache miss instead of silently
/// serving another query's answer.
pub fn embedding_guard(emb: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &x in emb {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// The pluggable cache interface both cache levels run behind.
///
/// Implementations must be deterministic: same call sequence ⇒ same hits,
/// same evictions. `get`/`get_similar` are `&mut self` because lookups
/// update replacement-policy state (recency / frequency).
pub trait QueryCache: Send {
    /// Short stable identifier (registry key for built-ins).
    fn name(&self) -> &str;

    /// Exact lookup by quantized key.
    fn get(&mut self, key: &[i8]) -> Option<CacheEntry>;

    /// Best entry whose key has cosine similarity ≥ `threshold` to `key`.
    /// A `threshold >= 1.0` must return only exact key matches (true
    /// duplicates) — the default delegates to [`get`](QueryCache::get)
    /// then, and returns `None` for sub-exact thresholds.
    fn get_similar(&mut self, key: &[i8], threshold: f64) -> Option<CacheEntry> {
        if threshold >= 1.0 {
            self.get(key)
        } else {
            None
        }
    }

    /// Insert (or overwrite) an entry; returns how many *other* entries
    /// were evicted to fit it. A cache with zero capacity stores nothing.
    fn insert(&mut self, key: Vec<i8>, entry: CacheEntry) -> usize;

    /// Drop every entry whose tag matches; returns how many were dropped.
    /// The conservative default flushes everything.
    fn invalidate(&mut self, pred: &mut dyn FnMut(&EntryTag) -> bool) -> usize {
        let _ = pred;
        self.clear()
    }

    /// Drop everything; returns how many entries were dropped.
    fn clear(&mut self) -> usize;

    /// Entries currently stored.
    fn len(&self) -> usize;

    /// Whether the cache holds no entries.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Modeled bytes currently in use (see [`entry_bytes`]).
    fn bytes(&self) -> usize;

    /// Configured byte budget.
    fn capacity_bytes(&self) -> usize;
}

/// The `none` policy: a cache-shaped hole. Stores nothing, hits nothing,
/// occupies zero bytes — the default, pinning "adding the cache tier
/// changed nothing" in the golden-trace harness.
pub struct NoneCache;

impl QueryCache for NoneCache {
    fn name(&self) -> &str {
        "none"
    }
    fn get(&mut self, _key: &[i8]) -> Option<CacheEntry> {
        None
    }
    fn insert(&mut self, _key: Vec<i8>, _entry: CacheEntry) -> usize {
        0
    }
    fn clear(&mut self) -> usize {
        0
    }
    fn len(&self) -> usize {
        0
    }
    fn bytes(&self) -> usize {
        0
    }
    fn capacity_bytes(&self) -> usize {
        0
    }
}

/// Eviction policy for [`PolicyCache`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Evict the least-recently-used entry.
    Lru,
    /// Evict the least-frequently-used entry (ties broken LRU).
    Lfu,
}

struct Stored {
    entry: CacheEntry,
    bytes: usize,
    last_used: u64,
    freq: u64,
}

/// The eviction rank of a stored entry under `policy`. Ranks are unique:
/// the tick is strictly monotone, so `last_used` never repeats across
/// live entries (and therefore neither does `(freq, last_used)`), which
/// makes the rank index a total order identical to the reference scan's
/// `min_by_key` — pinned by `victim`'s debug assertion and the
/// `rank_index_*` regression tests.
fn rank_of(policy: EvictPolicy, s: &Stored) -> (u64, u64) {
    match policy {
        EvictPolicy::Lru => (s.last_used, 0),
        EvictPolicy::Lfu => (s.freq, s.last_used),
    }
}

/// Byte-budgeted cache with pluggable LRU/LFU eviction. Entries live in a
/// `BTreeMap` so iteration (and therefore similarity scans and eviction
/// tie-breaks) is key-ordered and deterministic; a second `BTreeMap` keyed
/// by eviction rank makes victim selection O(log n) instead of an O(n)
/// scan (the ROADMAP open item for saturated production caches).
pub struct PolicyCache {
    policy: EvictPolicy,
    capacity_bytes: usize,
    entries: BTreeMap<Vec<i8>, Stored>,
    /// Eviction-order index: [`rank_of`] → cache key. Maintained by every
    /// operation that changes recency/frequency; its first entry is the
    /// next victim, so eviction is a tree-min instead of a full scan.
    rank: BTreeMap<(u64, u64), Vec<i8>>,
    bytes: usize,
    tick: u64,
}

impl PolicyCache {
    /// An empty cache with the given policy and byte budget.
    pub fn new(policy: EvictPolicy, capacity_bytes: usize) -> Self {
        PolicyCache {
            policy,
            capacity_bytes,
            entries: BTreeMap::new(),
            rank: BTreeMap::new(),
            bytes: 0,
            tick: 0,
        }
    }

    /// Advance the clock and refresh an existing entry's recency and
    /// frequency, keeping the rank index in sync; returns the refreshed
    /// entry. Policy state (the tick included) never changes on a miss.
    /// This is the one copy of the remove-rank / update / re-insert-rank
    /// sequence every lookup path shares.
    fn bump(&mut self, key: &[i8]) -> Option<CacheEntry> {
        let s = self.entries.get_mut(key)?;
        self.tick += 1;
        self.rank.remove(&rank_of(self.policy, s));
        s.last_used = self.tick;
        s.freq += 1;
        self.rank.insert(rank_of(self.policy, s), key.to_vec());
        Some(s.entry.clone())
    }

    /// Key of the current eviction victim under the policy: the first
    /// rank-index entry, skipping `protect` — the just-inserted key, which
    /// naive LFU would otherwise evict (freq 1) so a full cache could
    /// never turn over. O(log n); every debug build cross-checks the
    /// result against the O(n) reference scan.
    fn victim(&self, protect: &[i8]) -> Option<Vec<i8>> {
        let v = self.rank.values().find(|k| k.as_slice() != protect).cloned();
        debug_assert_eq!(
            v,
            self.victim_scan(protect),
            "rank index diverged from the reference eviction scan"
        );
        v
    }

    /// The original O(n) victim scan, kept as the executable specification
    /// the rank index is pinned against (debug assertion in
    /// [`victim`](Self::victim) + the `rank_index_*` regression tests).
    fn victim_scan(&self, protect: &[i8]) -> Option<Vec<i8>> {
        self.entries
            .iter()
            .filter(|(k, _)| k.as_slice() != protect)
            .min_by_key(|(_, s)| rank_of(self.policy, s))
            .map(|(k, _)| k.clone())
    }

    fn evict_to_fit(&mut self, protect: &[i8]) -> usize {
        let mut evicted = 0;
        while self.bytes > self.capacity_bytes {
            let Some(victim) = self.victim(protect) else { break };
            if let Some(s) = self.entries.remove(&victim) {
                self.rank.remove(&rank_of(self.policy, &s));
                self.bytes -= s.bytes;
                evicted += 1;
            }
        }
        evicted
    }
}

impl QueryCache for PolicyCache {
    fn name(&self) -> &str {
        match self.policy {
            EvictPolicy::Lru => "lru",
            EvictPolicy::Lfu => "lfu",
        }
    }

    fn get(&mut self, key: &[i8]) -> Option<CacheEntry> {
        // single tree walk; the tick advances only on hits, as for every
        // other policy-state update
        self.bump(key)
    }

    fn get_similar(&mut self, key: &[i8], threshold: f64) -> Option<CacheEntry> {
        // exact-only thresholds never do float comparisons: true
        // duplicates hit, everything else misses
        if threshold >= 1.0 {
            return self.get(key);
        }
        // exact duplicates score cosine 1.0 >= any threshold — serve them
        // without scanning (the warm-cache common case)
        if let Some(hit) = self.get(key) {
            return Some(hit);
        }
        let mut best: Option<(f64, Vec<i8>)> = None;
        for stored_key in self.entries.keys() {
            let sim = quantized_cosine(key, stored_key);
            // strict > keeps the first (lowest) key on ties: deterministic
            if sim >= threshold && best.as_ref().map(|(b, _)| sim > *b).unwrap_or(true) {
                best = Some((sim, stored_key.clone()));
            }
        }
        let (_, k) = best?;
        self.bump(&k)
    }

    fn insert(&mut self, key: Vec<i8>, entry: CacheEntry) -> usize {
        let size = entry_bytes(&key, &entry);
        if self.capacity_bytes == 0 || size > self.capacity_bytes {
            return 0; // never store what can never fit
        }
        self.tick += 1;
        if let Some(s) = self.entries.get_mut(&key) {
            // overwrite: recency/frequency refresh, entry count unchanged
            self.rank.remove(&rank_of(self.policy, s));
            self.bytes = self.bytes - s.bytes + size;
            s.entry = entry;
            s.bytes = size;
            s.last_used = self.tick;
            s.freq += 1;
            self.rank.insert(rank_of(self.policy, s), key.clone());
        } else {
            self.bytes += size;
            let stored = Stored { entry, bytes: size, last_used: self.tick, freq: 1 };
            self.rank.insert(rank_of(self.policy, &stored), key.clone());
            self.entries.insert(key.clone(), stored);
        }
        self.evict_to_fit(&key)
    }

    fn invalidate(&mut self, pred: &mut dyn FnMut(&EntryTag) -> bool) -> usize {
        let doomed: Vec<Vec<i8>> = self
            .entries
            .iter()
            .filter(|(_, s)| pred(&s.entry.tag))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &doomed {
            if let Some(s) = self.entries.remove(k) {
                self.rank.remove(&rank_of(self.policy, &s));
                self.bytes -= s.bytes;
            }
        }
        doomed.len()
    }

    fn clear(&mut self) -> usize {
        let n = self.entries.len();
        self.entries.clear();
        self.rank.clear();
        self.bytes = 0;
        n
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn bytes(&self) -> usize {
        self.bytes
    }

    fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

/// Per-slot cache activity, aggregated across both levels and surfaced in
/// `SlotReport::cache` (and, when caching is enabled, in run transcripts).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSlotStats {
    /// Per-node retrieval-cache hits (index search skipped).
    pub retrieval_hits: usize,
    /// Retrieval-cache lookups that fell through to the index.
    pub retrieval_misses: usize,
    /// Entries the retrieval caches evicted to stay in budget.
    pub retrieval_evictions: usize,
    /// Cluster answer-cache hits (query never routed to a node).
    pub answer_hits: usize,
    /// Answer-cache lookups that went through the full serve path.
    pub answer_misses: usize,
    /// Entries the answer cache evicted to stay in budget.
    pub answer_evictions: usize,
    /// Entries dropped by event-driven invalidation since the last slot.
    pub invalidations: usize,
    /// Total modeled cache bytes in use after the slot (all levels).
    pub bytes: usize,
}

impl CacheSlotStats {
    /// Combined hits across both levels.
    pub fn hits(&self) -> usize {
        self.retrieval_hits + self.answer_hits
    }

    /// Combined misses across both levels.
    pub fn misses(&self) -> usize {
        self.retrieval_misses + self.answer_misses
    }

    /// Combined evictions across both levels.
    pub fn evictions(&self) -> usize {
        self.retrieval_evictions + self.answer_evictions
    }

    /// Hit rate over all lookups this slot (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits() + self.misses();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The cache keys were originally produced by a private codec in this
    /// module; they are now the shared `vecdb/quant.rs` one. This pins the
    /// exact historical bytes (multiplier form `round(x * 127.0)`, clamped)
    /// so every committed cache golden (e.g. `repeat_storm_lru`) keys
    /// identically forever.
    #[test]
    fn shared_codec_is_byte_identical_to_cache_keys() {
        let mut rng = crate::util::rng::Rng::new(97);
        let mut emb: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        crate::text::embed::l2_normalize(&mut emb);
        let legacy: Vec<i8> =
            emb.iter().map(|&x| (x * 127.0).round().clamp(-127.0, 127.0) as i8).collect();
        assert_eq!(quantize_embedding(&emb), legacy);
        // edge values incl. out-of-range magnitudes (clamp) and signed zero
        let edges = [0.0f32, -0.0, 1.0, -1.0, 0.00394, -0.00394, 1.5, -1.5];
        let legacy_edges: Vec<i8> =
            edges.iter().map(|&x| (x * 127.0).round().clamp(-127.0, 127.0) as i8).collect();
        assert_eq!(quantize_embedding(&edges), legacy_edges);
        // and the similarity metric is still the i64-accumulator cosine
        let a = quantize_embedding(&emb);
        let (mut dot, mut na) = (0i64, 0i64);
        for &x in &a {
            dot += x as i64 * x as i64;
            na += x as i64 * x as i64;
        }
        let legacy_cos = dot as f64 / ((na as f64).sqrt() * (na as f64).sqrt());
        assert_eq!(quantized_cosine(&a, &a), legacy_cos);
    }

    fn hits_entry(node: usize, domain: usize, n_hits: usize) -> CacheEntry {
        CacheEntry {
            tag: EntryTag { node, domain },
            guard: 0,
            payload: CachePayload::Hits(
                (0..n_hits).map(|i| Hit { id: i, score: 0.5 }).collect(),
            ),
        }
    }

    fn key(tag: u8) -> Vec<i8> {
        vec![tag as i8; 8]
    }

    /// Capacity in bytes for exactly `n` of the `hits_entry(_, _, 5)`
    /// entries with 8-byte keys.
    fn cap_for(n: usize) -> usize {
        n * entry_bytes(&key(0), &hits_entry(0, 0, 5))
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = PolicyCache::new(EvictPolicy::Lru, cap_for(2));
        assert_eq!(c.insert(key(1), hits_entry(0, 0, 5)), 0);
        assert_eq!(c.insert(key(2), hits_entry(0, 0, 5)), 0);
        assert!(c.get(&key(1)).is_some()); // 1 is now more recent than 2
        assert_eq!(c.insert(key(3), hits_entry(0, 0, 5)), 1);
        assert!(c.get(&key(2)).is_none(), "LRU victim must be 2");
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn lfu_evicts_least_frequently_used() {
        let mut c = PolicyCache::new(EvictPolicy::Lfu, cap_for(2));
        c.insert(key(1), hits_entry(0, 0, 5));
        c.insert(key(2), hits_entry(0, 0, 5));
        // key 1 is hot, key 2 cold
        for _ in 0..3 {
            assert!(c.get(&key(1)).is_some());
        }
        assert_eq!(c.insert(key(3), hits_entry(0, 0, 5)), 1);
        assert!(c.get(&key(2)).is_none(), "LFU victim must be the cold key");
        assert!(c.get(&key(1)).is_some());
    }

    #[test]
    fn capacity_zero_never_stores() {
        let mut c = PolicyCache::new(EvictPolicy::Lru, 0);
        assert_eq!(c.insert(key(1), hits_entry(0, 0, 5)), 0);
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        assert!(c.get(&key(1)).is_none());
    }

    #[test]
    fn reinsert_updates_recency_not_size() {
        let mut c = PolicyCache::new(EvictPolicy::Lru, cap_for(2));
        c.insert(key(1), hits_entry(0, 0, 5));
        c.insert(key(2), hits_entry(0, 0, 5));
        assert_eq!(c.len(), 2);
        let before = c.bytes();
        c.insert(key(1), hits_entry(0, 1, 5)); // overwrite, refresh recency
        assert_eq!(c.len(), 2, "re-insert must not grow the cache");
        assert_eq!(c.bytes(), before);
        // 2 is now the LRU entry
        assert_eq!(c.insert(key(3), hits_entry(0, 0, 5)), 1);
        assert!(c.get(&key(2)).is_none());
        let e = c.get(&key(1)).unwrap();
        assert_eq!(e.tag.domain, 1, "overwrite must replace the payload");
    }

    #[test]
    fn bytes_never_exceed_budget() {
        let cap = cap_for(3) + 7; // deliberately not entry-aligned
        let mut c = PolicyCache::new(EvictPolicy::Lru, cap);
        for i in 0..50u8 {
            c.insert(key(i), hits_entry(0, 0, 5));
            assert!(c.bytes() <= cap, "bytes {} > cap {cap}", c.bytes());
        }
        assert!(c.len() >= 1);
        // an entry that can never fit is refused outright
        let mut tiny = PolicyCache::new(EvictPolicy::Lru, 10);
        assert_eq!(tiny.insert(key(1), hits_entry(0, 0, 5)), 0);
        assert_eq!(tiny.len(), 0);
    }

    #[test]
    fn exact_threshold_returns_only_true_duplicates() {
        let mut c = PolicyCache::new(EvictPolicy::Lru, cap_for(4));
        c.insert(vec![100, 0, 0, 0], hits_entry(0, 0, 5));
        // a near-duplicate key (cosine ≈ 0.995) must NOT hit at 1.0
        assert!(c.get_similar(&[100, 10, 0, 0], 1.0).is_none());
        assert!(c.get_similar(&[100, 0, 0, 0], 1.0).is_some());
        // ... but does hit at a sub-exact threshold
        assert!(c.get_similar(&[100, 10, 0, 0], 0.9).is_some());
        // and an unrelated key misses at any threshold ≥ 0.5
        assert!(c.get_similar(&[0, 0, -100, 0], 0.5).is_none());
    }

    #[test]
    fn invalidate_drops_matching_tags_only() {
        let mut c = PolicyCache::new(EvictPolicy::Lru, cap_for(4));
        c.insert(key(1), hits_entry(0, 2, 5));
        c.insert(key(2), hits_entry(1, 2, 5));
        c.insert(key(3), hits_entry(0, 4, 5));
        let dropped = c.invalidate(&mut |t| t.node == 0);
        assert_eq!(dropped, 2);
        assert!(c.get(&key(2)).is_some());
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.clear(), 1);
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn quantization_is_exact_for_duplicates_and_cosine_sane() {
        let emb: Vec<f32> = vec![0.5, -0.25, 0.75, 0.0];
        assert_eq!(quantize_embedding(&emb), quantize_embedding(&emb.clone()));
        let a = quantize_embedding(&emb);
        assert!((quantized_cosine(&a, &a) - 1.0).abs() < 1e-12);
        let b = quantize_embedding(&[-0.5, 0.25, -0.75, 0.0]);
        assert!(quantized_cosine(&a, &b) < -0.99);
    }

    /// The O(log n) rank index must pick victims in *exactly* the order
    /// the original O(n) scan did, under both policies, across a long
    /// deterministic mix of inserts / hits / overwrites / invalidations
    /// (beyond this explicit sequence, `victim` debug-asserts rank-vs-scan
    /// agreement on every eviction the whole test suite takes).
    #[test]
    fn rank_index_matches_scan_eviction_order() {
        for policy in [EvictPolicy::Lru, EvictPolicy::Lfu] {
            let mut c = PolicyCache::new(policy, cap_for(3));
            for step in 0..400u32 {
                let k = key((step.wrapping_mul(7) % 13) as u8);
                match step % 5 {
                    0 | 3 => {
                        c.insert(k, hits_entry((step % 2) as usize, 0, 5));
                    }
                    1 => {
                        let _ = c.get(&k);
                    }
                    2 => {
                        let _ = c.get_similar(&k, 1.0);
                    }
                    _ => {
                        if step % 60 == 4 {
                            c.invalidate(&mut |t| t.node == 1);
                        }
                    }
                }
                // the rank index mirrors the entry map at every step, and
                // agrees with the reference scan on the next victim
                assert_eq!(c.rank.len(), c.len(), "policy {policy:?} step {step}");
                assert_eq!(
                    c.victim(&key(255)),
                    c.victim_scan(&key(255)),
                    "policy {policy:?} step {step}"
                );
            }
            assert!(c.len() <= 3);
            let live = c.len();
            assert_eq!(c.clear(), live);
            assert!(c.rank.is_empty());
        }
    }

    /// Every rank-index entry points back at a live cache entry whose
    /// recomputed rank is the index key (no stale ranks after overwrites).
    #[test]
    fn rank_index_stays_consistent_after_overwrites() {
        let mut c = PolicyCache::new(EvictPolicy::Lfu, cap_for(4));
        for i in 0..4u8 {
            c.insert(key(i), hits_entry(0, 0, 5));
        }
        for _ in 0..3 {
            c.get(&key(1));
            c.insert(key(2), hits_entry(0, 1, 5)); // overwrite refreshes rank
        }
        for (rank, k) in &c.rank {
            let s = c.entries.get(k).expect("rank points at a live entry");
            assert_eq!(*rank, rank_of(c.policy, s));
        }
        assert_eq!(c.rank.len(), c.len());
    }

    #[test]
    fn guard_distinguishes_quantization_collisions() {
        // two distinct full-precision embeddings that land on the same
        // quantized key — the guard is what keeps them apart
        let a: Vec<f32> = vec![0.5, 0.25, 0.0, 0.0];
        let b: Vec<f32> = vec![0.5001, 0.25, 0.0, 0.0];
        assert_eq!(quantize_embedding(&a), quantize_embedding(&b));
        assert_ne!(embedding_guard(&a), embedding_guard(&b));
        // and it is stable for true duplicates
        assert_eq!(embedding_guard(&a), embedding_guard(&a.clone()));
    }

    #[test]
    fn none_cache_is_a_hole() {
        let mut c = NoneCache;
        assert_eq!(c.insert(key(1), hits_entry(0, 0, 5)), 0);
        assert!(c.get(&key(1)).is_none());
        assert!(c.get_similar(&key(1), 1.0).is_none());
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.clear(), 0);
    }

    #[test]
    fn slot_stats_rates() {
        let s = CacheSlotStats {
            retrieval_hits: 3,
            retrieval_misses: 1,
            answer_hits: 1,
            answer_misses: 3,
            ..Default::default()
        };
        assert_eq!(s.hits(), 4);
        assert_eq!(s.misses(), 4);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(CacheSlotStats::default().hit_rate(), 0.0);
    }
}
