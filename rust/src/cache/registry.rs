//! String-keyed cache registry + cache configuration.
//!
//! Mirrors `AllocatorRegistry` / `IndexRegistry`: built-in policies are
//! registered under their [`CacheKind`] names, custom caches register a
//! factory under any other key, and both the cluster layer (per-node
//! retrieval caches) and the coordinator (the semantic answer cache)
//! build whatever the [`CacheSpec`] names — no downstream code branches
//! on the policy kind.

use std::collections::BTreeMap;

use super::{EvictPolicy, NoneCache, PolicyCache, QueryCache};
use anyhow::{anyhow, Result};

/// Built-in cache policies (also the registry's built-in keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// Least-recently-used eviction.
    Lru,
    /// Least-frequently-used eviction (ties broken LRU).
    Lfu,
    /// No caching at all — the default; byte-identical to the pre-cache
    /// system (pinned by the golden-trace parity tests).
    None,
}

impl CacheKind {
    /// Every built-in kind.
    pub const ALL: [CacheKind; 3] = [CacheKind::Lru, CacheKind::Lfu, CacheKind::None];

    /// Stable string key (CLI flag values, TOML, registry keys).
    pub fn as_str(&self) -> &'static str {
        match self {
            CacheKind::Lru => "lru",
            CacheKind::Lfu => "lfu",
            CacheKind::None => "none",
        }
    }
}

impl std::fmt::Display for CacheKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for CacheKind {
    type Err = anyhow::Error;

    /// Exhaustive over [`CacheKind::ALL`]; the error lists every valid kind.
    fn from_str(s: &str) -> Result<Self> {
        CacheKind::ALL
            .iter()
            .find(|k| k.as_str() == s)
            .copied()
            .ok_or_else(|| {
                let valid: Vec<&str> = CacheKind::ALL.iter().map(|k| k.as_str()).collect();
                anyhow!("unknown cache kind {s:?}; valid kinds: {}", valid.join(", "))
            })
    }
}

/// Cache configuration (TOML `[cache]` global table, `[nodes.cache]`
/// per-node sub-tables, CLI `--cache` / `--cache-mb`).
///
/// `kind` is a registry key, so it may also name a custom cache registered
/// through `CoordinatorBuilder::register_cache`; unknown kinds fail at
/// build time with the registry's key list.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheSpec {
    /// Registry key (`lru`, `lfu`, `none`, or a custom registration).
    pub kind: String,
    /// Cache byte budget in MiB (`--cache-mb`). Zero stores nothing.
    pub capacity_mb: usize,
    /// Semantic answer-cache similarity threshold; `1.0` (the default)
    /// serves exact duplicates only, guaranteeing bitwise-equal quality.
    pub threshold: f64,
    /// Modeled node memory (MiB) the retrieval cache competes within: the
    /// intra-node solver's generation-memory cap shrinks by
    /// `cache_bytes / node_mem_mb` as the cache fills.
    pub node_mem_mb: usize,
}

impl Default for CacheSpec {
    fn default() -> Self {
        CacheSpec {
            kind: CacheKind::None.as_str().into(),
            capacity_mb: 32,
            threshold: 1.0,
            node_mem_mb: 8192,
        }
    }
}

impl CacheSpec {
    /// Default parameters with the given kind.
    pub fn of_kind(kind: &str) -> Self {
        CacheSpec { kind: kind.into(), ..CacheSpec::default() }
    }

    /// Whether this spec configures an actual cache (anything but `none`).
    pub fn enabled(&self) -> bool {
        self.kind != CacheKind::None.as_str()
    }

    /// The byte budget.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_mb * 1024 * 1024
    }

    /// The modeled node memory budget in bytes.
    pub fn node_mem_bytes(&self) -> usize {
        self.node_mem_mb * 1024 * 1024
    }
}

/// What a cache factory gets to build from.
pub struct CacheBuildCtx<'a> {
    /// The resolved cache configuration.
    pub spec: &'a CacheSpec,
}

type CacheFactory = Box<dyn Fn(&CacheBuildCtx) -> Result<Box<dyn QueryCache>> + Send + Sync>;

/// String-keyed registry of cache factories.
pub struct CacheRegistry {
    factories: BTreeMap<String, CacheFactory>,
}

impl CacheRegistry {
    /// Empty registry (no built-ins).
    pub fn empty() -> Self {
        CacheRegistry { factories: BTreeMap::new() }
    }

    /// Registry with every [`CacheKind`] built-in registered.
    pub fn with_builtins() -> Self {
        let mut r = CacheRegistry::empty();
        r.register(CacheKind::Lru.as_str(), |ctx| {
            Ok(Box::new(PolicyCache::new(EvictPolicy::Lru, ctx.spec.capacity_bytes())))
        });
        r.register(CacheKind::Lfu.as_str(), |ctx| {
            Ok(Box::new(PolicyCache::new(EvictPolicy::Lfu, ctx.spec.capacity_bytes())))
        });
        r.register(CacheKind::None.as_str(), |_| Ok(Box::new(NoneCache)));
        r
    }

    /// Register (or replace) a factory under `kind`.
    pub fn register(
        &mut self,
        kind: &str,
        factory: impl Fn(&CacheBuildCtx) -> Result<Box<dyn QueryCache>> + Send + Sync + 'static,
    ) {
        self.factories.insert(kind.to_string(), Box::new(factory));
    }

    /// Registered keys, sorted.
    pub fn kinds(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Build an empty cache of `kind`; the error lists every registered key.
    pub fn build(&self, kind: &str, ctx: &CacheBuildCtx) -> Result<Box<dyn QueryCache>> {
        match self.factories.get(kind) {
            Some(f) => f(ctx),
            None => Err(anyhow!(
                "unknown cache kind {kind:?}; registered kinds: {}",
                self.kinds().join(", ")
            )),
        }
    }
}

impl Default for CacheRegistry {
    fn default() -> Self {
        CacheRegistry::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_and_errors_list_valid() {
        for k in CacheKind::ALL {
            assert_eq!(k.as_str().parse::<CacheKind>().unwrap(), k);
        }
        let err = "bogus".parse::<CacheKind>().unwrap_err().to_string();
        assert!(err.contains("valid kinds") && err.contains("lru"), "{err}");
    }

    #[test]
    fn builtins_build_every_kind() {
        let reg = CacheRegistry::with_builtins();
        let spec = CacheSpec::default();
        for k in CacheKind::ALL {
            let cache = reg.build(k.as_str(), &CacheBuildCtx { spec: &spec }).unwrap();
            assert!(cache.is_empty(), "{k}");
            assert_eq!(cache.name(), k.as_str());
        }
    }

    #[test]
    fn unknown_kind_lists_registered_keys() {
        let reg = CacheRegistry::with_builtins();
        let spec = CacheSpec::default();
        let err = reg
            .build("redis", &CacheBuildCtx { spec: &spec })
            .map(|_| ())
            .unwrap_err()
            .to_string();
        for k in CacheKind::ALL {
            assert!(err.contains(k.as_str()), "{err}");
        }
        assert!(err.contains("redis"), "{err}");
    }

    #[test]
    fn spec_defaults_are_off_and_exact() {
        let spec = CacheSpec::default();
        assert!(!spec.enabled());
        assert_eq!(spec.threshold, 1.0);
        assert_eq!(CacheSpec::of_kind("lru").kind, "lru");
        assert!(CacheSpec::of_kind("lru").enabled());
        assert_eq!(spec.capacity_bytes(), 32 * 1024 * 1024);
    }
}
