//! Seeded scenario generator: random-but-valid [`Scenario`] timelines.
//!
//! The grammar covers every event kind the scenario engine defines —
//! node churn (`node-down`/`node-up`), capacity scaling, SLO changes,
//! bursts (including the `queries = 0` empty-slot edge), skew shifts
//! (including the boundary `frac` values 0 and 1), corpus ingest, and
//! live reindex migrations toward every built-in index kind (including
//! the redundant same-kind rebuild and reindexes landing on currently
//! down nodes, which the engine must reject) — plus optional arrival
//! traces with varied base/amplitude/burst parameters. Every generated scenario passes [`Scenario::validate`]
//! against the fuzz cluster (asserted by `tests/fuzz.rs` over many
//! seeds), so a failing replay always indicts the engine, not the input.

use crate::config::{AllocatorKind, CacheSpec, DatasetKind, ExperimentConfig};
use crate::scenario::{Scenario, ScenarioEvent, TimedEvent};
use crate::util::rng::Rng;
use crate::workload::{SkewPattern, TraceConfig};

/// Generator bounds: the cluster shape events index into and the size of
/// the timelines produced. The defaults match the paper cluster's shape
/// (4 nodes, 6 domains) at a reduced corpus scale so a thousand-case
/// sweep stays cheap.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Nodes the cluster has (event `node` indices stay below this).
    pub n_nodes: usize,
    /// Dataset domains (skew / ingest `domain` indices stay below this).
    pub n_domains: usize,
    /// Upper bound on the slot count (timelines run 2..=max_slots slots).
    pub max_slots: usize,
    /// Upper bound on events per timeline.
    pub max_events: usize,
    /// QA pairs per domain in the fuzz dataset.
    pub qa_per_domain: usize,
    /// Documents per domain in the fuzz dataset.
    pub docs_per_domain: usize,
    /// Per-node corpus size.
    pub corpus_docs: usize,
    /// Upper bound on the arrival-trace base load (queries per slot).
    pub max_base_load: usize,
    /// Probability that a generated skew-shift carries an out-of-range
    /// `frac` (> 1). Always 0 in production sweeps; tests raise it as the
    /// injected-bug hook to prove the oracle + shrinker find and minimize
    /// the exact class of bug the `frac` validation fix closed.
    pub bug_rate: f64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            n_nodes: 4,
            n_domains: 6,
            max_slots: 8,
            max_events: 10,
            qa_per_domain: 8,
            docs_per_domain: 12,
            corpus_docs: 16,
            max_base_load: 60,
            bug_rate: 0.0,
        }
    }
}

fn random_pattern(rng: &mut Rng, gc: &GenConfig) -> SkewPattern {
    match rng.below(3) {
        0 => SkewPattern::Balanced,
        1 => {
            let frac = if rng.chance(gc.bug_rate) {
                // injected bug: out-of-range frac the validation fix rejects
                1.0 + rng.range_f64(0.1, 1.0)
            } else if rng.chance(0.2) {
                // boundary values are part of the valid grammar
                if rng.chance(0.5) {
                    0.0
                } else {
                    1.0
                }
            } else {
                rng.range_f64(0.0, 1.0)
            };
            SkewPattern::Primary { domain: rng.below(gc.n_domains), frac }
        }
        _ => SkewPattern::Dirichlet { alpha: rng.range_f64(0.05, 5.0) },
    }
}

/// Generate one random-but-valid scenario from `seed`. Deterministic:
/// the same `(seed, config)` always yields the same timeline.
pub fn generate_scenario(seed: u64, gc: &GenConfig) -> Scenario {
    let mut rng = Rng::new(seed);
    let slots = 2 + rng.below(gc.max_slots.saturating_sub(1).max(1));
    let trace = if rng.chance(0.7) {
        Some(TraceConfig {
            slots,
            base: 5 + rng.below(gc.max_base_load.max(6) - 5),
            diurnal_amp: rng.range_f64(0.0, 0.6),
            period: 2 + rng.below(slots),
            burst_prob: rng.range_f64(0.0, 0.3),
            burst_mult: rng.range_f64(1.0, 2.5),
            // kept within i64 range so emitted fixture TOML reparses
            seed: rng.below(1 << 31) as u64,
        })
    } else {
        None
    };
    let n_events = rng.below(gc.max_events + 1);
    let mut events = Vec::with_capacity(n_events);
    for _ in 0..n_events {
        let slot = rng.below(slots);
        let node = rng.below(gc.n_nodes);
        let event = match rng.below(8) {
            0 => ScenarioEvent::NodeDown { node },
            1 => ScenarioEvent::NodeUp { node },
            2 => ScenarioEvent::CapacityScale { node, factor: rng.range_f64(0.05, 4.0) },
            3 => ScenarioEvent::SloChange { slo_s: rng.range_f64(1.0, 30.0) },
            4 => ScenarioEvent::CorpusIngest {
                node,
                docs: rng.below(20),
                domain: rng.below(gc.n_domains),
            },
            5 => ScenarioEvent::BurstOverride {
                // zero-query bursts (an empty live slot) are a first-class
                // part of the grammar — run_slot(&[]) must stay finite
                queries: if rng.chance(0.25) { 0 } else { rng.below(200) },
            },
            6 => ScenarioEvent::SkewShift { pattern: random_pattern(&mut rng, gc) },
            _ => {
                // live reindex toward any built-in kind: same-kind
                // rebuilds are a valid (vacuous) part of the grammar,
                // and the node may be down when the event fires — the
                // engine must reject that case, which the oracle treats
                // as an expected rejection
                let kinds = crate::vecdb::IndexKind::ALL;
                ScenarioEvent::Reindex {
                    node,
                    to: kinds[rng.below(kinds.len())].as_str().to_string(),
                    shards: None,
                    rescore_factor: None,
                }
            }
        };
        events.push(TimedEvent { slot, event });
    }
    // stable sort: same-slot events keep generation order, matching the
    // parser's same-slot file-order semantics
    events.sort_by_key(|e| e.slot);
    Scenario { name: format!("fuzz-{seed:016x}"), slots: Some(slots), trace, events }
}

/// The experiment config one fuzz case replays under: the paper cluster
/// shape at the generator's reduced corpus scale, with the case's
/// allocator and (optionally) the LRU answer/retrieval cache enabled so
/// the staleness invariant is exercised.
pub fn fuzz_experiment_config(
    gc: &GenConfig,
    seed: u64,
    allocator: AllocatorKind,
    cached: bool,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.seed = seed;
    cfg.qa_per_domain = gc.qa_per_domain;
    cfg.docs_per_domain = gc.docs_per_domain;
    cfg.allocator = allocator;
    if cached {
        cfg.cache = CacheSpec { kind: "lru".into(), capacity_mb: 4, ..CacheSpec::default() };
    }
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = gc.corpus_docs;
        if cached {
            n.cache = cfg.cache.clone();
        }
    }
    cfg
}
