//! Invariant oracle: replay a scenario and check every property the
//! engine guarantees.
//!
//! The invariants are the same ones `tests/property_invariants.rs`
//! asserts, factored into reusable functions that return [`Violation`]s
//! instead of panicking — the fuzzer needs failures as data (to count,
//! render, and hand to the shrinker), and the property tests consume the
//! same functions so the two suites can never drift apart:
//!
//! - **conservation** — every sampled query id appears exactly once, in
//!   order, in the slot's outcomes;
//! - **proportions** — the routing proportions sum to 1 iff the slot is
//!   nonempty and any node is live, and are all-zero otherwise;
//! - **routing** — no query is ever routed to a down node; a shed
//!   (never-routed) outcome only occurs when every node is down;
//! - **finiteness** — every numeric quantity in the report and in the
//!   serialized transcript is finite (the JSON writer would emit a
//!   literal `NaN`, which is not JSON, so this is load-bearing);
//! - **cache staleness** — a cached answer is never served for a
//!   `(node, domain)` whose corpus changed after the entry was written,
//!   is bitwise-equal to the serve that wrote it, and never survives a
//!   skew-shift flush;
//! - **migration** — a reindexing node serves its old index on every
//!   slot strictly before the modeled swap boundary and the target kind
//!   exactly from that boundary on (never an unfinalized index, never an
//!   early or late swap — the tracker recomputes the expected swap slot
//!   from [`modeled_build_slots`] independently of the engine); a
//!   reindex targeting a down node must be rejected naming `node-up`;
//! - **determinism** — an independent replay of the same timeline on a
//!   freshly built coordinator produces a byte-identical transcript.

use std::collections::{BTreeMap, HashMap};

use super::generator::{fuzz_experiment_config, GenConfig};
use crate::config::AllocatorKind;
use crate::coordinator::{Coordinator, CoordinatorBuilder, SlotReport};
use crate::corpus::synth::SyntheticDataset;
use crate::metrics::QualityScores;
use crate::router::capacity::CapacityModel;
use crate::scenario::transcript::RunTranscript;
use crate::scenario::{Scenario, ScenarioEvent, ScenarioRunner};
use crate::util::json::Json;
use crate::vecdb::{modeled_build_slots, IndexKind};

/// One invariant violation: which invariant, where, and what happened.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Stable invariant key (`conservation`, `proportions`, `routing`,
    /// `finiteness`, `cache-staleness`, `migration`, `determinism`,
    /// `run-error`).
    pub invariant: &'static str,
    /// Slot the violation occurred in, when it is slot-local.
    pub slot: Option<usize>,
    /// Human-readable description of the observed breakage.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.slot {
            Some(s) => write!(f, "[{} @ slot {s}] {}", self.invariant, self.detail),
            None => write!(f, "[{}] {}", self.invariant, self.detail),
        }
    }
}

/// Conservation: the report accounts every sampled query exactly once,
/// in sampling order.
pub fn check_conservation(slot: usize, qids: &[usize], r: &SlotReport) -> Vec<Violation> {
    let mut out = Vec::new();
    if r.queries != qids.len() || r.outcomes.len() != qids.len() {
        out.push(Violation {
            invariant: "conservation",
            slot: Some(slot),
            detail: format!(
                "sampled {} queries but report has queries={} outcomes={}",
                qids.len(),
                r.queries,
                r.outcomes.len()
            ),
        });
        return out;
    }
    for (i, (o, &q)) in r.outcomes.iter().zip(qids).enumerate() {
        if o.qa_id != q {
            out.push(Violation {
                invariant: "conservation",
                slot: Some(slot),
                detail: format!("outcome {i} is qa {} but qa {q} was sampled there", o.qa_id),
            });
            return out;
        }
    }
    out
}

/// Proportions: a distribution iff anything could run (nonempty slot,
/// some node live); all-zero otherwise.
pub fn check_proportions(slot: usize, r: &SlotReport) -> Vec<Violation> {
    let any_live = r.active.iter().any(|&a| a);
    let psum: f64 = r.proportions.iter().sum();
    let ok = if r.queries > 0 && any_live { (psum - 1.0).abs() < 1e-9 } else { psum == 0.0 };
    if ok {
        Vec::new()
    } else {
        vec![Violation {
            invariant: "proportions",
            slot: Some(slot),
            detail: format!(
                "proportions sum to {psum} with {} queries and any_live={any_live}",
                r.queries
            ),
        }]
    }
}

/// Routing: never to a down or out-of-range node; a shed (never-routed)
/// outcome only when every node is down, and always dropped.
pub fn check_routing(slot: usize, r: &SlotReport) -> Vec<Violation> {
    let any_live = r.active.iter().any(|&a| a);
    let mut out = Vec::new();
    for o in &r.outcomes {
        if o.node == usize::MAX {
            if any_live || !o.dropped {
                out.push(Violation {
                    invariant: "routing",
                    slot: Some(slot),
                    detail: format!(
                        "qa {} shed (never routed) with any_live={any_live} dropped={}",
                        o.qa_id, o.dropped
                    ),
                });
            }
        } else if o.node >= r.active.len() || !r.active[o.node] {
            out.push(Violation {
                invariant: "routing",
                slot: Some(slot),
                detail: format!("qa {} routed to down/out-of-range node {}", o.qa_id, o.node),
            });
        }
    }
    out
}

fn finite_scores(s: &QualityScores) -> bool {
    [s.rouge1, s.rouge2, s.rouge_l, s.bleu4, s.meteor, s.bert_score]
        .iter()
        .all(|x| x.is_finite())
}

/// Finiteness of the slot report: every modeled numeric quantity that
/// feeds the transcript, the allocator feedback, or downstream
/// aggregation must be finite.
pub fn check_report_finite(slot: usize, r: &SlotReport) -> Vec<Violation> {
    let mut bad: Vec<String> = Vec::new();
    if !r.drop_rate.is_finite() {
        bad.push(format!("drop_rate={}", r.drop_rate));
    }
    if !r.latency_s.is_finite() {
        bad.push(format!("latency_s={}", r.latency_s));
    }
    if !r.slo_s.is_finite() {
        bad.push(format!("slo_s={}", r.slo_s));
    }
    if r.proportions.iter().any(|p| !p.is_finite()) {
        bad.push(format!("proportions={:?}", r.proportions));
    }
    if !finite_scores(&r.mean_scores) {
        bad.push(format!("mean_scores={:?}", r.mean_scores));
    }
    for o in &r.outcomes {
        if !o.feedback.is_finite() || !o.latency_s.is_finite() || !finite_scores(&o.scores) {
            bad.push(format!("outcome qa {} has non-finite feedback/latency/scores", o.qa_id));
            break;
        }
    }
    bad.into_iter()
        .map(|detail| Violation { invariant: "finiteness", slot: Some(slot), detail })
        .collect()
}

fn scan_json_finite(v: &Json, path: &str, out: &mut Vec<Violation>) {
    match v {
        Json::Num(x) if !x.is_finite() => out.push(Violation {
            invariant: "finiteness",
            slot: None,
            detail: format!("transcript field {path} is {x}"),
        }),
        Json::Arr(xs) => {
            for (i, x) in xs.iter().enumerate() {
                scan_json_finite(x, &format!("{path}[{i}]"), out);
            }
        }
        Json::Obj(m) => {
            for (k, x) in m {
                scan_json_finite(x, &format!("{path}.{k}"), out);
            }
        }
        _ => {}
    }
}

/// Every transcript line parses as JSON and contains only finite
/// numbers. Load-bearing: the JSON writer would serialize an f64 NaN as
/// a literal `NaN`, which no parser accepts — so a NaN anywhere in the
/// pipeline surfaces here even if the report-level check missed it.
pub fn check_transcript_finite(jsonl: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    for (i, line) in jsonl.lines().enumerate() {
        match Json::parse(line) {
            Ok(v) => scan_json_finite(&v, &format!("line {i}"), &mut out),
            Err(e) => out.push(Violation {
                invariant: "finiteness",
                slot: None,
                detail: format!("transcript line {i} is not valid JSON ({e}): {line}"),
            }),
        }
    }
    out
}

/// Tracks cache-staleness state across a replay: the last uncached serve
/// per QA id, the last slot each `(node, domain)` corpus actually
/// changed, and the last skew-shift flush. Mirrors the bookkeeping of
/// `prop_cache_never_serves_stale_answers` exactly.
#[derive(Default)]
pub struct StaleTracker {
    written: HashMap<usize, (usize, QualityScores)>,
    changed: HashMap<(usize, usize), usize>,
    last_skew_flush: usize,
}

impl StaleTracker {
    /// Fresh tracker for one replay.
    pub fn new() -> Self {
        Self::default()
    }

    /// A corpus ingest landed at `slot`; `added` is how many documents
    /// were actually new on the node (0 changes nothing).
    pub fn note_ingest(&mut self, node: usize, domain: usize, slot: usize, added: usize) {
        if added > 0 {
            self.changed.insert((node, domain), slot);
        }
    }

    /// A skew-shift at `slot` flushes the answer cache.
    pub fn note_skew_flush(&mut self, slot: usize) {
        self.last_skew_flush = slot;
    }

    /// Check one slot's outcomes and absorb its uncached serves.
    pub fn check_slot(
        &mut self,
        slot: usize,
        r: &SlotReport,
        ds: &SyntheticDataset,
    ) -> Vec<Violation> {
        let mut out = Vec::new();
        for o in &r.outcomes {
            if o.cached {
                let mk = |detail: String| Violation {
                    invariant: "cache-staleness",
                    slot: Some(slot),
                    detail,
                };
                let Some(&(wslot, wscores)) = self.written.get(&o.qa_id) else {
                    out.push(mk(format!("qa {} served from cache before any serve", o.qa_id)));
                    continue;
                };
                if o.scores != wscores {
                    out.push(mk(format!(
                        "qa {} cached quality diverged from the serve that wrote it",
                        o.qa_id
                    )));
                }
                if o.dropped {
                    out.push(mk(format!("qa {} is both cached and dropped", o.qa_id)));
                }
                let domain = ds.qa_pairs[o.qa_id].domain;
                if let Some(&chg) = self.changed.get(&(o.node, domain)) {
                    if wslot < chg {
                        out.push(mk(format!(
                            "qa {} cached at slot {wslot} but (node {}, domain {domain}) \
                             corpus changed at slot {chg}",
                            o.qa_id, o.node
                        )));
                    }
                }
                if wslot < self.last_skew_flush {
                    out.push(mk(format!(
                        "qa {} entry written at slot {wslot} survived the skew flush at {}",
                        o.qa_id, self.last_skew_flush
                    )));
                }
            } else if !o.dropped {
                self.written.insert(o.qa_id, (slot, o.scores));
            }
        }
        out
    }
}

/// One in-flight migration the oracle expects to complete.
struct InflightMigration {
    from: String,
    to: String,
    /// First slot the target kind must serve:
    /// `begin_slot + modeled_build_slots(rows_at_begin, to)`.
    swap_slot: usize,
}

/// Tracks reindex migrations across a replay and checks the modeled
/// swap contract against the transcript-visible per-node state: before
/// the swap boundary the node serves its old kind with an exact
/// `from->to:remaining` countdown label; from the boundary on it serves
/// the target kind with an idle label. The expected boundary is
/// recomputed here from [`modeled_build_slots`] — independently of the
/// engine — so any engine-side swap-ordering drift (early swap, late
/// swap, skipped countdown) surfaces as a `migration` violation.
#[derive(Default)]
pub struct MigrationTracker {
    inflight: BTreeMap<usize, InflightMigration>,
    any_seen: bool,
}

impl MigrationTracker {
    /// Fresh tracker for one replay.
    pub fn new() -> Self {
        Self::default()
    }

    /// A reindex was accepted before `slot`: `from` is the kind serving
    /// at that moment, `rows` the node's corpus size when the snapshot
    /// was taken. A second reindex on the same node replaces the
    /// expectation, mirroring the engine's replace policy.
    pub fn note_begin(&mut self, node: usize, from: &str, to: IndexKind, slot: usize, rows: usize) {
        self.any_seen = true;
        self.inflight.insert(
            node,
            InflightMigration {
                from: from.to_string(),
                to: to.as_str().to_string(),
                swap_slot: slot + modeled_build_slots(rows, to),
            },
        );
    }

    /// Check one slot's report against every in-flight expectation.
    pub fn check_slot(&mut self, slot: usize, r: &SlotReport) -> Vec<Violation> {
        let mut out = Vec::new();
        if !self.any_seen {
            return out;
        }
        let mk = |detail: String| Violation { invariant: "migration", slot: Some(slot), detail };
        let (Some(kinds), Some(migs)) = (&r.index_kinds, &r.migrations) else {
            out.push(mk(
                "report is missing index_kinds/migrations after a reindex event".to_string(),
            ));
            return out;
        };
        let mut swapped: Vec<usize> = Vec::new();
        for (&node, m) in &self.inflight {
            if slot < m.swap_slot {
                let remaining = m.swap_slot - slot;
                if kinds[node] != m.from {
                    out.push(mk(format!(
                        "node {node} serves {:?} {remaining} slot(s) before the modeled swap \
                         to {:?} — expected the old {:?} (early swap / unfinalized index)",
                        kinds[node], m.to, m.from
                    )));
                }
                let want = format!("{}->{}:{}", m.from, m.to, remaining);
                if migs[node] != want {
                    out.push(mk(format!(
                        "node {node} migration label is {:?}, expected {want:?}",
                        migs[node]
                    )));
                }
            } else {
                // checked every slot, so this is exactly the swap slot:
                // the first slot the target kind must serve
                if kinds[node] != m.to {
                    out.push(mk(format!(
                        "node {node} serves {:?} at its modeled swap slot, expected {:?} \
                         (late swap)",
                        kinds[node], m.to
                    )));
                }
                if migs[node] != "-" {
                    out.push(mk(format!(
                        "node {node} still shows migration {:?} at its modeled swap slot",
                        migs[node]
                    )));
                }
                swapped.push(node);
            }
        }
        for n in swapped {
            self.inflight.remove(&n);
        }
        out
    }
}

/// Per-case oracle parameters: which coordinator the timeline replays on.
#[derive(Clone, Debug)]
pub struct OracleConfig {
    /// Experiment seed for the coordinator (dataset + sampling streams).
    pub seed: u64,
    /// Allocator under test.
    pub allocator: AllocatorKind,
    /// Enable the LRU cache tier (exercises the staleness invariant).
    pub cached: bool,
    /// Skip `Scenario::validate` before the replay. Production sweeps
    /// keep this `false`; tests set it to drive deliberately-invalid
    /// timelines (the injected-bug hook) into the engine and prove the
    /// oracle catches what the validation fixes now reject.
    pub skip_validation: bool,
    /// Offset injected into the engine's reindex swap countdown (via
    /// `Coordinator::set_migration_swap_skew`). Always 0 in production
    /// sweeps; tests set ±1 to plant a swap-ordering bug and prove the
    /// `migration` invariant catches it — the tracker's expectation
    /// deliberately ignores this knob.
    pub swap_skew: i64,
}

/// Everything one checked replay produced.
pub struct CheckedCase {
    /// All violations, in slot order (empty = the case passed).
    pub violations: Vec<Violation>,
    /// The replay transcript (JSONL); partial if the run errored.
    pub transcript: String,
    /// Slots the replay ran.
    pub slots: usize,
    /// Total queries across all slots.
    pub queries: usize,
}

fn build_coordinator(
    gc: &GenConfig,
    oc: &OracleConfig,
) -> crate::Result<Coordinator> {
    let cfg = fuzz_experiment_config(gc, oc.seed, oc.allocator, oc.cached);
    let caps = vec![CapacityModel { k: 6.0, b: 0.0 }; cfg.nodes.len()];
    let mut co = CoordinatorBuilder::new(cfg).capacities(caps).build()?;
    if oc.swap_skew != 0 {
        co.set_migration_swap_skew(oc.swap_skew);
    }
    Ok(co)
}

/// Replay `sc` on a fresh coordinator, checking every invariant per
/// slot, then verify determinism: an independent replay through
/// [`ScenarioRunner::run_observed`] on a second freshly built coordinator
/// must produce a byte-identical transcript. Never panics — every
/// failure (including a mid-run error) comes back as a [`Violation`].
pub fn check_scenario(sc: &Scenario, gc: &GenConfig, oc: &OracleConfig) -> CheckedCase {
    let mut violations = Vec::new();
    let mut co = match build_coordinator(gc, oc) {
        Ok(co) => co,
        Err(e) => {
            return CheckedCase {
                violations: vec![Violation {
                    invariant: "run-error",
                    slot: None,
                    detail: format!("coordinator build failed: {e:#}"),
                }],
                transcript: String::new(),
                slots: 0,
                queries: 0,
            }
        }
    };
    let (transcript, slots, queries, completed, had_rejection) =
        replay_checked(sc, &mut co, oc, &mut violations);
    violations.extend(check_transcript_finite(&transcript));
    if completed {
        // determinism: fresh coordinator, independent replay,
        // byte-compared. Normally through the public ScenarioRunner
        // path (conservation re-checked in the hook); when the timeline
        // contains an expected down-node reindex rejection the public
        // runner would hard-error on it, so the double replay goes
        // through the checked loop again (its duplicate violations are
        // discarded — only the byte comparison matters).
        match build_coordinator(gc, oc) {
            Ok(mut co2) => {
                if had_rejection {
                    let mut dup = Vec::new();
                    let (second, _, _, _, _) = replay_checked(sc, &mut co2, oc, &mut dup);
                    if second != transcript {
                        violations.push(Violation {
                            invariant: "determinism",
                            slot: None,
                            detail: format!(
                                "independent replay diverged ({} vs {} bytes)",
                                transcript.len(),
                                second.len()
                            ),
                        });
                    }
                } else {
                    let runner = ScenarioRunner::new(sc.clone());
                    let mut hook_violations = Vec::new();
                    match runner.run_observed(&mut co2, |t, qids, r| {
                        hook_violations.extend(check_conservation(t, qids, r));
                    }) {
                        Ok(run) => {
                            violations.extend(hook_violations);
                            let second = run.transcript.to_jsonl();
                            if second != transcript {
                                violations.push(Violation {
                                    invariant: "determinism",
                                    slot: None,
                                    detail: format!(
                                        "independent replay diverged ({} vs {} bytes)",
                                        transcript.len(),
                                        second.len()
                                    ),
                                });
                            }
                        }
                        Err(e) => violations.push(Violation {
                            invariant: "determinism",
                            slot: None,
                            detail: format!(
                                "checked replay completed but the reference replay errored: {e:#}"
                            ),
                        }),
                    }
                }
            }
            Err(e) => violations.push(Violation {
                invariant: "run-error",
                slot: None,
                detail: format!("reference coordinator build failed: {e:#}"),
            }),
        }
    }
    CheckedCase { violations, transcript, slots, queries }
}

/// The checked replay loop. Mirrors [`ScenarioRunner::run`] exactly
/// (same validation, same event order, same sampling calls — the
/// determinism check above would flag any drift between the two), but
/// captures what the oracle needs along the way: the sampled query ids
/// per slot, corpus-ingest added counts, skew-flush slots, and reindex
/// begin slots. The one deliberate departure: a reindex targeting a down
/// node is an *expected* rejection (the generator emits them on
/// purpose), so the loop skips the event and keeps replaying instead of
/// aborting — the final `bool` in the tuple reports whether any such
/// rejection occurred, which routes the determinism double replay
/// through this loop instead of the rejection-intolerant public runner.
fn replay_checked(
    sc: &Scenario,
    co: &mut Coordinator,
    oc: &OracleConfig,
    violations: &mut Vec<Violation>,
) -> (String, usize, usize, bool, bool) {
    let run_error = |slot: Option<usize>, e: anyhow::Error| Violation {
        invariant: "run-error",
        slot,
        detail: format!("{e:#}"),
    };
    if !oc.skip_validation {
        if let Err(e) = sc.validate(co.nodes.len(), co.ds.num_domains()) {
            violations.push(run_error(None, e));
            return (String::new(), 0, 0, false, false);
        }
    }
    let runner = ScenarioRunner::new(sc.clone());
    let loads = runner.loads(co);
    for te in &sc.events {
        if te.slot >= loads.len() {
            violations.push(run_error(
                Some(te.slot),
                anyhow::anyhow!(
                    "event {} at slot {} beyond the run's {} slots",
                    te.event.kind(),
                    te.slot,
                    loads.len()
                ),
            ));
            return (String::new(), 0, 0, false, false);
        }
    }
    let mut transcript = RunTranscript::new(
        &sc.name,
        co.cfg.seed,
        co.nodes.len(),
        co.allocator().name(),
        loads.len(),
    );
    let mut tracker = StaleTracker::new();
    let mut mig_tracker = MigrationTracker::new();
    let mut had_rejection = false;
    let mut total_queries = 0usize;
    for (t, &load) in loads.iter().enumerate() {
        let mut burst = None;
        let mut labels = Vec::new();
        for te in sc.events_at(t) {
            labels.push(te.event.label());
            let applied = match &te.event {
                ScenarioEvent::BurstOverride { queries } => {
                    burst = Some(*queries);
                    Ok(())
                }
                ScenarioEvent::CorpusIngest { node, docs, domain } => {
                    co.ingest_corpus(*node, *domain, *docs).map(|added| {
                        tracker.note_ingest(*node, *domain, t, added);
                    })
                }
                ScenarioEvent::SkewShift { .. } => co.apply_event(&te.event).map(|()| {
                    tracker.note_skew_flush(t);
                }),
                ScenarioEvent::Reindex { node, to, .. } => {
                    // snapshot the state the expectation derives from
                    // BEFORE applying — apply mutates the node
                    let node_down = !co.active[*node];
                    let from = co.nodes[*node].index_kind.clone();
                    let rows = co.nodes[*node].corpus_size();
                    match co.apply_event(&te.event) {
                        Ok(()) if node_down => {
                            violations.push(Violation {
                                invariant: "migration",
                                slot: Some(t),
                                detail: format!(
                                    "reindex on down node {node} was accepted — must be \
                                     rejected naming node-up"
                                ),
                            });
                            Ok(())
                        }
                        Ok(()) => {
                            if let Ok(kind) = to.parse::<IndexKind>() {
                                mig_tracker.note_begin(*node, &from, kind, t, rows);
                            }
                            Ok(())
                        }
                        Err(e) if node_down && format!("{e:#}").contains("node-up") => {
                            // expected rejection: the event is skipped
                            // and the replay continues
                            had_rejection = true;
                            Ok(())
                        }
                        Err(e) => Err(e),
                    }
                }
                other => co.apply_event(other),
            };
            if let Err(e) = applied {
                violations.push(run_error(Some(t), e));
                return (transcript.to_jsonl(), t, total_queries, false, had_rejection);
            }
        }
        let qids = match co.sample_queries(burst.unwrap_or(load)) {
            Ok(q) => q,
            Err(e) => {
                violations.push(run_error(Some(t), e));
                return (transcript.to_jsonl(), t, total_queries, false, had_rejection);
            }
        };
        let report = match co.run_slot(&qids) {
            Ok(r) => r,
            Err(e) => {
                violations.push(run_error(Some(t), e));
                return (transcript.to_jsonl(), t, total_queries, false, had_rejection);
            }
        };
        transcript.record(t, &labels, &report);
        total_queries += qids.len();
        violations.extend(check_conservation(t, &qids, &report));
        violations.extend(check_proportions(t, &report));
        violations.extend(check_routing(t, &report));
        violations.extend(check_report_finite(t, &report));
        violations.extend(tracker.check_slot(t, &report, &co.ds));
        violations.extend(mig_tracker.check_slot(t, &report));
    }
    (transcript.to_jsonl(), loads.len(), total_queries, true, had_rejection)
}
