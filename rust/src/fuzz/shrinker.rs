//! Failure shrinker: minimize a violating timeline while it still fails.
//!
//! Classic greedy delta-debugging over the scenario grammar: delete
//! events one at a time, drop the arrival trace, cut the slot count down
//! to just past the last event, and reduce numeric parameters (burst
//! queries, ingest docs; reindex targets retargeted to the simplest
//! kind, `flat`) toward their simplest values — accepting every
//! candidate that still fails, looping until a fixpoint. The result is the minimal
//! repro the engine still breaks on, emitted as committable fixture TOML
//! plus the `coedge fuzz` command that replays it.

use crate::scenario::{Scenario, ScenarioEvent};

/// The minimized failing case.
pub struct ShrinkOutcome {
    /// The minimal scenario that still fails.
    pub scenario: Scenario,
    /// Fixture TOML of the minimal scenario (committable; reparses and
    /// re-serializes byte-identically).
    pub toml: String,
    /// Candidate evaluations the shrink spent.
    pub steps: usize,
}

/// Upper bound on candidate evaluations — shrinking is O(events²) in the
/// worst case and each evaluation replays the scenario twice.
const MAX_STEPS: usize = 300;

/// Minimize `sc` under `still_fails` (which must return `true` for `sc`
/// itself). Deterministic: candidates are tried in a fixed order, so the
/// same failing input always shrinks to the same minimal repro.
pub fn shrink(sc: &Scenario, mut still_fails: impl FnMut(&Scenario) -> bool) -> ShrinkOutcome {
    let mut cur = sc.clone();
    let mut steps = 0usize;
    let mut try_candidate = |cur: &mut Scenario, cand: Scenario, steps: &mut usize| -> bool {
        if *steps >= MAX_STEPS {
            return false;
        }
        *steps += 1;
        if still_fails(&cand) {
            *cur = cand;
            true
        } else {
            false
        }
    };
    loop {
        let mut progressed = false;

        // 1. event deletion, one at a time (front to back; on success the
        //    same index now holds the next event)
        let mut i = 0;
        while i < cur.events.len() {
            let mut cand = cur.clone();
            cand.events.remove(i);
            if try_candidate(&mut cur, cand, &mut steps) {
                progressed = true;
            } else {
                i += 1;
            }
        }

        // 2. drop the arrival trace (fixed per-slot load is simpler)
        if cur.trace.is_some() {
            let cand = Scenario { trace: None, ..cur.clone() };
            progressed |= try_candidate(&mut cur, cand, &mut steps);
        }

        // 3. cut slots down to just past the last event
        let min_slots = cur.events.iter().map(|e| e.slot + 1).max().unwrap_or(1);
        let slots_reducible = match cur.slots {
            Some(s) => s > min_slots,
            None => true,
        };
        if slots_reducible {
            let cand = Scenario { slots: Some(min_slots), ..cur.clone() };
            progressed |= try_candidate(&mut cur, cand, &mut steps);
        }

        // 4. numeric parameter reduction toward zero
        for idx in 0..cur.events.len() {
            let reduced = match &cur.events[idx].event {
                ScenarioEvent::BurstOverride { queries } if *queries > 0 => {
                    Some(ScenarioEvent::BurstOverride { queries: queries / 2 })
                }
                ScenarioEvent::CorpusIngest { node, docs, domain } if *docs > 0 => {
                    Some(ScenarioEvent::CorpusIngest {
                        node: *node,
                        docs: docs / 2,
                        domain: *domain,
                    })
                }
                // retarget a reindex to the simplest kind — keeps the
                // event (deletion already tried above) while removing
                // target-specific machinery from the repro
                ScenarioEvent::Reindex { node, to, shards, rescore_factor } if to != "flat" => {
                    Some(ScenarioEvent::Reindex {
                        node: *node,
                        to: "flat".to_string(),
                        shards: *shards,
                        rescore_factor: *rescore_factor,
                    })
                }
                _ => None,
            };
            if let Some(event) = reduced {
                let mut cand = cur.clone();
                cand.events[idx].event = event;
                progressed |= try_candidate(&mut cur, cand, &mut steps);
            }
        }

        if !progressed || steps >= MAX_STEPS {
            break;
        }
    }
    ShrinkOutcome { toml: cur.to_toml(), scenario: cur, steps }
}
