//! Scenario fuzzing engine: seeded timeline generator + invariant
//! oracle + failure shrinker (`coedge fuzz`).
//!
//! The paper's whole premise is scheduling under *fluctuating,
//! unpredictable* conditions (§III dynamics, §IV-B/C adaptation under
//! churn and load shifts), yet hand-written fixtures only ever exercise
//! the timelines someone thought to write down. This tier closes the
//! gap ("as many scenarios as you can imagine", per the roadmap's
//! north-star):
//!
//! - [`generator`] produces random-but-valid [`Scenario`] timelines from
//!   a seed — node churn, capacity scaling, SLO changes, zero-query
//!   bursts, boundary-`frac` skew shifts, corpus ingest, live reindex
//!   migrations toward every index kind, varied arrival traces;
//! - [`oracle`] replays each timeline on a fresh seeded coordinator and
//!   checks the engine's property invariants (conservation,
//!   proportions, routing, finiteness, cache staleness, migration swap
//!   timing) plus run-to-run transcript byte-equality;
//! - [`shrinker`] minimizes any failing timeline by event deletion and
//!   slot/parameter reduction, emitting the minimal case as committable
//!   fixture TOML + a repro command.
//!
//! [`run_fuzz`] fans the sweep out on
//! [`parallel_map`](crate::util::threadpool::parallel_map) with
//! index-ordered collection, so `BENCH_fuzz.json` and the failure
//! report are byte-identical across runs and thread counts (ADR-001:
//! modeled quantities only, never wall-clock). CI runs the sweep twice
//! and byte-diffs both artifacts.
//!
//! Every case is self-describing: case `i` of a sweep with base seed
//! `S` uses seed `S + i`, and derives its allocator and cache flag from
//! that seed — so `coedge fuzz --count 1 --seed S+i` replays exactly
//! the case a larger sweep flagged.

pub mod generator;
pub mod oracle;
pub mod shrinker;

use std::path::{Path, PathBuf};

use crate::bench_harness::{write_bench_json, BenchCase};
use crate::config::AllocatorKind;
use crate::scenario::Scenario;
use crate::util::threadpool::parallel_map;
use crate::Result;
pub use generator::{generate_scenario, GenConfig};
pub use oracle::{OracleConfig, Violation};
pub use shrinker::{shrink, ShrinkOutcome};

/// One fuzz sweep's parameters.
#[derive(Clone, Debug)]
pub struct FuzzConfig {
    /// Timelines to generate and check.
    pub count: usize,
    /// Base seed; case `i` uses seed `base + i`.
    pub seed: u64,
    /// Pin every case to one allocator; `None` derives the allocator
    /// from each case's seed, cycling all built-in kinds.
    pub allocator: Option<AllocatorKind>,
    /// Fan-out width; 0 = one worker per core. Never changes output
    /// bytes (index-ordered collection).
    pub threads: usize,
    /// Generator bounds (cluster shape, timeline size, bug injection).
    pub gen: GenConfig,
    /// Skip scenario validation before replay — the injected-bug hook
    /// for tests; production sweeps keep this `false`.
    pub skip_validation: bool,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig {
            count: 100,
            seed: 1,
            allocator: None,
            threads: 0,
            gen: GenConfig::default(),
            skip_validation: false,
        }
    }
}

/// Seed of case `i` in a sweep with base seed `base`. Additive on
/// purpose: the repro command for a flagged case is just
/// `coedge fuzz --count 1 --seed <case_seed>`.
pub fn case_seed(base: u64, index: usize) -> u64 {
    base.wrapping_add(index as u64)
}

/// Allocator a case runs under when none is pinned: derived from the
/// case seed (not the sweep index), so a single-case repro picks the
/// same allocator the sweep did.
pub fn case_allocator(seed: u64) -> AllocatorKind {
    AllocatorKind::ALL[(seed % AllocatorKind::ALL.len() as u64) as usize]
}

/// Whether a case runs with the cache tier enabled (every third seed,
/// derived from the seed for the same repro-stability reason).
pub fn case_cached(seed: u64) -> bool {
    seed % 3 == 2
}

/// Outcome of one fuzz case.
pub struct CaseOutcome {
    /// Sweep index of the case.
    pub index: usize,
    /// The case's seed (`base + index`; drives generator and replay).
    pub seed: u64,
    /// Allocator the case ran under.
    pub allocator: AllocatorKind,
    /// Whether the cache tier was enabled.
    pub cached: bool,
    /// Slots the generated timeline ran.
    pub slots: usize,
    /// Events in the generated timeline.
    pub events: usize,
    /// Total queries replayed.
    pub queries: usize,
    /// Invariant violations (empty = passed).
    pub violations: Vec<Violation>,
    /// Minimized repro, present iff the case failed.
    pub shrunk: Option<ShrinkOutcome>,
}

/// Everything one sweep produced, in case order.
pub struct FuzzReport {
    /// Base seed of the sweep.
    pub seed: u64,
    /// Per-case outcomes, index-ordered.
    pub cases: Vec<CaseOutcome>,
}

/// Run one fuzz case end to end: generate, replay under the oracle,
/// and shrink on failure.
pub fn run_case(cfg: &FuzzConfig, index: usize) -> CaseOutcome {
    let seed = case_seed(cfg.seed, index);
    let allocator = cfg.allocator.unwrap_or_else(|| case_allocator(seed));
    let cached = case_cached(seed);
    let oc = OracleConfig {
        seed,
        allocator,
        cached,
        skip_validation: cfg.skip_validation,
        swap_skew: 0,
    };
    let sc = generate_scenario(seed, &cfg.gen);
    let checked = oracle::check_scenario(&sc, &cfg.gen, &oc);
    let shrunk = if checked.violations.is_empty() {
        None
    } else {
        let fails = |cand: &Scenario| !oracle::check_scenario(cand, &cfg.gen, &oc).violations.is_empty();
        Some(shrink(&sc, fails))
    };
    CaseOutcome {
        index,
        seed,
        allocator,
        cached,
        slots: checked.slots,
        events: sc.events.len(),
        queries: checked.queries,
        violations: checked.violations,
        shrunk,
    }
}

/// Run the sweep: `cfg.count` cases fanned out on `parallel_map` with
/// index-ordered collection — the report is byte-deterministic across
/// runs and thread counts.
pub fn run_fuzz(cfg: &FuzzConfig) -> FuzzReport {
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.threads
    };
    let cases = parallel_map(cfg.count, threads, |i| run_case(cfg, i));
    FuzzReport { seed: cfg.seed, cases }
}

impl FuzzReport {
    /// The failing cases, in sweep order.
    pub fn failures(&self) -> Vec<&CaseOutcome> {
        self.cases.iter().filter(|c| !c.violations.is_empty()).collect()
    }

    /// Paper-bench cases for `BENCH_fuzz.json`: a sweep summary plus one
    /// row per allocator. Modeled quantities only (counts — never
    /// wall-clock), per ADR-001.
    pub fn to_bench_cases(&self) -> Vec<BenchCase> {
        let sum = |f: fn(&CaseOutcome) -> usize| -> f64 {
            self.cases.iter().map(|c| f(c) as f64).sum()
        };
        let mut out = vec![BenchCase::new("fuzz/summary")
            .field("cases", self.cases.len() as f64)
            .field("failures", self.failures().len() as f64)
            .field("violations", sum(|c| c.violations.len()))
            .field("events", sum(|c| c.events))
            .field("slots", sum(|c| c.slots))
            .field("queries", sum(|c| c.queries))];
        for kind in AllocatorKind::ALL {
            let cases: Vec<&CaseOutcome> =
                self.cases.iter().filter(|c| c.allocator == kind).collect();
            if cases.is_empty() {
                continue;
            }
            out.push(
                BenchCase::new(format!("fuzz/{kind}"))
                    .field("cases", cases.len() as f64)
                    .field("failures", cases.iter().filter(|c| !c.violations.is_empty()).count() as f64)
                    .field("events", cases.iter().map(|c| c.events as f64).sum())
                    .field("slots", cases.iter().map(|c| c.slots as f64).sum())
                    .field("queries", cases.iter().map(|c| c.queries as f64).sum()),
            );
        }
        out
    }

    /// Deterministic failure report: empty string when the sweep is
    /// clean, else one block per failing case with its violations, the
    /// minimized fixture TOML, and the repro command.
    pub fn failure_report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for c in self.failures() {
            let _ = writeln!(
                out,
                "case {} seed {} allocator {} cache {}",
                c.index,
                c.seed,
                c.allocator,
                if c.cached { "lru" } else { "none" }
            );
            for v in &c.violations {
                let _ = writeln!(out, "  {v}");
            }
            if let Some(s) = &c.shrunk {
                let _ = writeln!(
                    out,
                    "  minimized to {} event(s) in {} steps:",
                    s.scenario.events.len(),
                    s.steps
                );
                for line in s.toml.lines() {
                    let _ = writeln!(out, "    {line}");
                }
            }
            let _ = writeln!(
                out,
                "  repro: coedge fuzz --count 1 --seed {} --allocator {}",
                c.seed, c.allocator
            );
            out.push('\n');
        }
        out
    }

    /// Write `BENCH_fuzz.json`, the failure report, and one minimized
    /// fixture TOML per failing case into `dir`. Returns the written
    /// paths (bench json first).
    pub fn write_artifacts(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        std::fs::create_dir_all(dir)?;
        let mut paths = vec![write_bench_json(dir, "fuzz", &self.to_bench_cases())?];
        let report_path = dir.join("FUZZ_failures.txt");
        std::fs::write(&report_path, self.failure_report())?;
        paths.push(report_path);
        for c in self.failures() {
            if let Some(s) = &c.shrunk {
                let p = dir.join(format!("fuzz_min_seed{}.toml", c.seed));
                std::fs::write(&p, &s.toml)?;
                paths.push(p);
            }
        }
        Ok(paths)
    }
}
