//! Algorithm 1: Inter-Node Scheduling.
//!
//! Each query samples a node from its probability vector s_i^t; if the
//! sampled node is at capacity, it re-samples from the renormalized
//! probabilities of nodes with residual capacity. If the batch exceeds
//! total cluster capacity, all capacities are scaled proportionally
//! (lines 5–8). Outputs the per-query assignment a_i^t and per-node
//! proportions p_j^t = q_j / B^t.

use crate::util::rng::Rng;

/// Result of one inter-node scheduling round.
#[derive(Clone, Debug)]
pub struct InterScheduleResult {
    /// Node index per query.
    pub assignment: Vec<usize>,
    /// Queries per node.
    pub counts: Vec<usize>,
    /// Proportions p_j^t (sum to 1 when B > 0).
    pub proportions: Vec<f64>,
    /// Effective capacities after overload scaling.
    pub capacities: Vec<f64>,
}

/// Run Algorithm 1 with every node live.
///
/// `probs` is row-major `[B × N]` (each row sums to 1);
/// `capacities` is C_n(L^t) per node.
pub fn inter_node_schedule(
    probs: &[f32],
    n_nodes: usize,
    capacities: &[f64],
    rng: &mut Rng,
) -> InterScheduleResult {
    inter_node_schedule_masked(probs, n_nodes, capacities, &vec![true; n_nodes], rng)
}

/// Weighted sample that can never land on a down node: down nodes carry
/// weight 0, and the one residual edge case of `sample_weighted` (a draw
/// of exactly 0 selecting a zero-weight index) is diverted to the live
/// node with the largest weight (ties → lowest index). No extra RNG draws.
fn sample_live(rng: &mut Rng, weights: &[f64], active: &[bool]) -> usize {
    let a = rng.sample_weighted(weights);
    if active[a] && weights[a] > 0.0 {
        return a;
    }
    let mut best = a;
    let mut best_w = f64::NEG_INFINITY;
    for (j, (&w, &up)) in weights.iter().zip(active).enumerate() {
        if up && w > best_w {
            best_w = w;
            best = j;
        }
    }
    best
}

/// Run Algorithm 1 under a node-availability mask (scenario
/// NodeDown/NodeUp events): a down node has effective capacity 0, carries
/// no sampling weight, and is excluded from the degenerate even-split, so
/// it receives exactly zero queries. At least one node must be live (the
/// coordinator sheds all-down slots before routing).
pub fn inter_node_schedule_masked(
    probs: &[f32],
    n_nodes: usize,
    capacities: &[f64],
    active: &[bool],
    rng: &mut Rng,
) -> InterScheduleResult {
    assert_eq!(capacities.len(), n_nodes);
    assert_eq!(active.len(), n_nodes);
    assert!(n_nodes > 0);
    assert!(active.iter().any(|&up| up), "inter_node_schedule: every node is down");
    let b = probs.len() / n_nodes;
    assert_eq!(probs.len(), b * n_nodes);

    // Lines 5–8: proportional scaling under cluster overload, over the
    // live nodes only (a down node's capacity is pinned to 0).
    let mut caps: Vec<f64> = capacities
        .iter()
        .zip(active)
        .map(|(&c, &up)| if up { c } else { 0.0 })
        .collect();
    let total_cap: f64 = caps.iter().sum();
    if b as f64 > total_cap && total_cap > 0.0 {
        let excess = b as f64 - total_cap;
        for c in caps.iter_mut() {
            *c += (*c / total_cap) * excess;
        }
    } else if total_cap <= 0.0 {
        // degenerate: no capacity anywhere — split evenly over live nodes
        let n_live = active.iter().filter(|&&up| up).count();
        let even = (b as f64 / n_live as f64).ceil();
        for (c, &up) in caps.iter_mut().zip(active) {
            *c = if up { even } else { 0.0 };
        }
    }

    let mut counts = vec![0usize; n_nodes];
    let mut assignment = Vec::with_capacity(b);
    let mut weights = vec![0f64; n_nodes];
    for i in 0..b {
        let row = &probs[i * n_nodes..(i + 1) * n_nodes];
        let mut live_mass = 0.0;
        for (j, (w, &p)) in weights.iter_mut().zip(row).enumerate() {
            *w = if active[j] { p as f64 } else { 0.0 };
            live_mass += *w;
        }
        if live_mass <= 0.0 {
            // all probability mass sat on down nodes: uniform over live
            for (w, &up) in weights.iter_mut().zip(active) {
                *w = if up { 1.0 } else { 0.0 };
            }
        }
        let mut a = sample_live(rng, &weights, active);
        // Line 11: capacity-aware validation + renormalized reassignment.
        if (counts[a] as f64) >= caps[a] {
            let mut any = false;
            for j in 0..n_nodes {
                if active[j] && (counts[j] as f64) < caps[j] {
                    any = true;
                } else {
                    weights[j] = 0.0;
                }
            }
            if any {
                if weights.iter().sum::<f64>() <= 0.0 {
                    // residual capacity only at zero-probability nodes
                    for j in 0..n_nodes {
                        if active[j] && (counts[j] as f64) < caps[j] {
                            weights[j] = 1.0;
                        }
                    }
                }
                a = sample_live(rng, &weights, active);
            }
            // else: every live node saturated (can only happen from
            // rounding; keep the original sample — live by construction)
        }
        counts[a] += 1;
        assignment.push(a);
    }

    let proportions = counts
        .iter()
        .map(|&q| if b > 0 { q as f64 / b as f64 } else { 0.0 })
        .collect();
    InterScheduleResult { assignment, counts, proportions, capacities: caps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_probs(b: usize, n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; b * n]
    }

    /// Rows concentrated on node `fav`.
    fn skewed_probs(b: usize, n: usize, fav: usize, p: f32) -> Vec<f32> {
        let mut v = vec![(1.0 - p) / (n - 1) as f32; b * n];
        for i in 0..b {
            v[i * n + fav] = p;
        }
        v
    }

    #[test]
    fn conserves_queries_and_proportions() {
        let mut rng = Rng::new(3);
        let res = inter_node_schedule(&uniform_probs(500, 4), 4, &[200.0; 4], &mut rng);
        assert_eq!(res.assignment.len(), 500);
        assert_eq!(res.counts.iter().sum::<usize>(), 500);
        let psum: f64 = res.proportions.iter().sum();
        assert!((psum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn respects_capacity_limits() {
        let mut rng = Rng::new(5);
        // all queries love node 0, but it can only take 50
        let res =
            inter_node_schedule(&skewed_probs(300, 3, 0, 0.9), 3, &[50.0, 200.0, 200.0], &mut rng);
        assert!(res.counts[0] <= 51, "node0={}", res.counts[0]);
        assert_eq!(res.counts.iter().sum::<usize>(), 300);
    }

    #[test]
    fn overload_scales_proportionally() {
        let mut rng = Rng::new(7);
        // total capacity 100 < 400 queries -> scaled capacities keep ratios
        let res = inter_node_schedule(&uniform_probs(400, 2), 2, &[75.0, 25.0], &mut rng);
        assert_eq!(res.counts.iter().sum::<usize>(), 400);
        let ratio = res.capacities[0] / res.capacities[1];
        assert!((ratio - 3.0).abs() < 1e-9);
        // assignment roughly follows scaled capacity, not uniform
        assert!(res.counts[0] > res.counts[1]);
    }

    #[test]
    fn follows_probabilities_when_capacity_free() {
        let mut rng = Rng::new(9);
        let res = inter_node_schedule(&skewed_probs(1000, 3, 2, 0.8), 3, &[2000.0; 3], &mut rng);
        let f2 = res.counts[2] as f64 / 1000.0;
        assert!((f2 - 0.8).abs() < 0.05, "f2={f2}");
    }

    #[test]
    fn zero_queries() {
        let mut rng = Rng::new(1);
        let res = inter_node_schedule(&[], 3, &[10.0; 3], &mut rng);
        assert!(res.assignment.is_empty());
        assert_eq!(res.proportions, vec![0.0; 3]);
    }

    #[test]
    fn zero_capacity_degenerates_to_even_split() {
        let mut rng = Rng::new(2);
        let res = inter_node_schedule(&uniform_probs(90, 3), 3, &[0.0; 3], &mut rng);
        assert_eq!(res.counts.iter().sum::<usize>(), 90);
        for &c in &res.counts {
            assert!(c >= 20 && c <= 40, "{:?}", res.counts);
        }
    }

    #[test]
    fn masked_down_node_receives_nothing_even_when_preferred() {
        let mut rng = Rng::new(11);
        // every query loves node 0, but node 0 is down
        let res = inter_node_schedule_masked(
            &skewed_probs(400, 3, 0, 0.9),
            3,
            &[500.0; 3],
            &[false, true, true],
            &mut rng,
        );
        assert_eq!(res.counts[0], 0);
        assert!(res.assignment.iter().all(|&a| a != 0));
        assert_eq!(res.counts.iter().sum::<usize>(), 400);
        assert_eq!(res.capacities[0], 0.0);
    }

    #[test]
    fn masked_degenerate_capacity_splits_over_live_nodes_only() {
        let mut rng = Rng::new(12);
        let res = inter_node_schedule_masked(
            &uniform_probs(90, 3),
            3,
            &[0.0; 3],
            &[true, false, true],
            &mut rng,
        );
        assert_eq!(res.counts[1], 0, "{:?}", res.counts);
        assert_eq!(res.counts.iter().sum::<usize>(), 90);
        // overload still hits only the live nodes' scaled capacities
        assert_eq!(res.capacities[1], 0.0);
    }

    #[test]
    fn unmasked_wrapper_is_the_all_live_mask() {
        let probs = skewed_probs(200, 4, 2, 0.7);
        let caps = [60.0, 70.0, 10.0, 80.0];
        let mut r1 = Rng::new(13);
        let mut r2 = Rng::new(13);
        let a = inter_node_schedule(&probs, 4, &caps, &mut r1);
        let b = inter_node_schedule_masked(&probs, 4, &caps, &[true; 4], &mut r2);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    #[should_panic(expected = "every node is down")]
    fn masked_all_down_panics() {
        let mut rng = Rng::new(14);
        inter_node_schedule_masked(&uniform_probs(4, 2), 2, &[10.0; 2], &[false, false], &mut rng);
    }
}
