//! Load-balancing inter-node scheduling (paper §IV-B).
//!
//! - [`capacity`]: the initialization-phase profiling that estimates each
//!   node's capacity function C_n(L) = k_n·L + b_n (Eq. 12) via controlled
//!   query bursts and a 1% drop-rate threshold.
//! - [`inter`]: Algorithm 1 — probability-driven assignment with
//!   capacity-aware reassignment and proportional capacity scaling under
//!   cluster-wide overload.

pub mod capacity;
pub mod inter;

pub use capacity::{profile_capacity, CapacityModel};
pub use inter::{inter_node_schedule, InterScheduleResult};
