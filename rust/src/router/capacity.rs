//! Node capacity profiling (paper §IV-B initialization phase).
//!
//! The latency parameter L is swept from 5 s to 60 s in 5 s steps. At
//! L = 5 s the load is grown until the drop rate exceeds 1%, giving
//! E_{n,5}; at each subsequent level the search starts from (L/5)·E_{n,5}
//! and grows in E_{n,5} increments. A linear regression over (L, E_{n,L})
//! yields C_n(L) = k_n·L + b_n.

use crate::util::stats::linreg;

/// Fitted capacity function for one node.
#[derive(Clone, Copy, Debug)]
pub struct CapacityModel {
    pub k: f64,
    pub b: f64,
}

impl CapacityModel {
    /// Max sustainable queries under latency requirement `l_s`.
    pub fn eval(&self, l_s: f64) -> f64 {
        (self.k * l_s + self.b).max(0.0)
    }
}

/// Profile a node through a drop-rate oracle.
///
/// `drop_rate(queries, budget_s)` must return the fraction of queries the
/// node would drop serving `queries` within `budget_s` (the cluster
/// simulator provides this; in deployment it is the controlled burst).
pub fn profile_capacity(
    mut drop_rate: impl FnMut(usize, f64) -> f64,
    threshold: f64,
) -> CapacityModel {
    // Find the largest q with drop_rate(q, l) <= threshold via
    // exponential growth from a warm start + bisection. (The paper grows
    // in E_{n,5} increments — equivalent outcome; bisection needs far
    // fewer controlled bursts and is robust to non-monotone pockets the
    // adaptive intra-node solver can create at tiny loads.)
    let mut max_ok = |l: f64, warm: usize, dr: &mut dyn FnMut(usize, f64) -> f64| -> usize {
        let mut lo = 0usize;
        let mut hi = warm.max(8);
        // ensure hi violates
        while dr(hi, l) <= threshold && hi < 4_000_000 {
            lo = hi;
            hi *= 2;
        }
        // ensure lo passes (warm start may already violate)
        while lo > 0 && dr(lo, l) > threshold {
            lo /= 2;
        }
        while hi - lo > (lo / 64).max(4) {
            let mid = lo + (hi - lo) / 2;
            if dr(mid, l) <= threshold {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    };

    let levels: Vec<f64> = (1..=12).map(|i| 5.0 * i as f64).collect();
    let mut ls = Vec::new();
    let mut es = Vec::new();
    let mut warm = 8usize;
    for &l in &levels {
        let e = max_ok(l, warm, &mut drop_rate);
        ls.push(l);
        es.push(e as f64);
        // warm start the next level from the linear extrapolation
        warm = ((e as f64) * (l + 5.0) / l) as usize;
    }
    let (k, b) = linreg(&ls, &es);
    CapacityModel { k, b }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_linear_capacity() {
        // a node that serves exactly 40 q/s: drop when q > 40 * L
        let oracle = |q: usize, l: f64| -> f64 {
            let cap = 40.0 * l;
            if q as f64 <= cap {
                0.0
            } else {
                (q as f64 - cap) / q as f64
            }
        };
        let m = profile_capacity(oracle, 0.01);
        assert!((m.k - 40.0).abs() < 4.0, "k={}", m.k);
        assert!(m.eval(10.0) > 350.0 && m.eval(10.0) < 450.0, "{}", m.eval(10.0));
    }

    #[test]
    fn capacity_with_fixed_overhead() {
        // 0.5 s setup, then 20 q/s: cap(L) = 20(L - 0.5)
        let oracle = |q: usize, l: f64| -> f64 {
            let cap = (20.0 * (l - 0.5)).max(0.0);
            if q as f64 <= cap {
                0.0
            } else {
                1.0
            }
        };
        let m = profile_capacity(oracle, 0.01);
        assert!((m.k - 20.0).abs() < 2.0, "k={}", m.k);
        assert!(m.b < 0.0, "b={}", m.b); // negative intercept from overhead
    }

    #[test]
    fn eval_clamps_at_zero() {
        let m = CapacityModel { k: 10.0, b: -100.0 };
        assert_eq!(m.eval(1.0), 0.0);
    }
}
