//! # CoEdge-RAG
//!
//! A from-scratch reproduction of *"CoEdge-RAG: Optimizing Hierarchical
//! Scheduling for Retrieval-Augmented LLMs in Collaborative Edge Computing"*
//! (Hong et al., 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate implements the paper's hierarchical scheduler — online PPO
//! query identification, capacity-aware inter-node scheduling (Algorithm 1),
//! and convex intra-node model/resource allocation (Eq. 13–29) — together
//! with every substrate it depends on: a vector database, a full lexical +
//! semantic metrics suite (ROUGE/BLEU/METEOR/BERTScore), synthetic
//! domain-partitioned corpora, a calibrated edge-LLM serving simulator,
//! deterministic text embeddings, and a PJRT runtime that executes the
//! JAX/Pallas-authored policy network from AOT-compiled HLO artifacts.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! Layer-2 JAX graphs (which call Layer-1 Pallas kernels) to HLO text once;
//! [`runtime`] loads and executes them through `xla::PjRtClient`.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod util;
pub mod config;
pub mod text;
pub mod corpus;
pub mod vecdb;
pub mod cache;
pub mod metrics;
pub mod llmsim;
pub mod workload;
pub mod policy;
pub mod bandit;
pub mod runtime;
pub mod router;
pub mod intranode;
pub mod cluster;
pub mod coordinator;
pub mod scenario;
pub mod server;
pub mod bench_harness;

/// Crate-wide result type (anyhow-based, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
