//! Serving-engine scaling sweep: synchronous vs pipelined slot execution
//! across cluster sizes far beyond the paper's 4-node testbed.
//!
//!     cargo bench --bench serving
//!
//! Sweeps 5 → 50 → 500 nodes × growing queries/slot under the paper's
//! PPO allocator and the random floor, running every case through both
//! the synchronous loop and the [`PipelinedExecutor`] — and asserting
//! their reports are bitwise identical, the same invariant
//! `tests/scenarios.rs` pins on the committed goldens. Emits
//! `BENCH_serving.json` whose committed comparison surface is modeled
//! only (drop rate, modeled latency, modeled pipeline occupancy);
//! wall-clock fields (`*_wall_s`, `speedup`) are present for local
//! reading but stripped by CI's double-run diff per ADR-001.
//!
//! Flags (after `--`):
//! - `--smoke`: reduced tiers (5/50 nodes) for CI's `serving-smoke`.
//! - `--bench-dir DIR`: directory for `BENCH_serving.json` (default `.`).

use coedge_rag::bench_harness::{write_bench_json, BenchCase, Table};
use coedge_rag::config::{
    AllocatorKind, CacheSpec, DatasetKind, ExperimentConfig, IndexSpec, IntraStrategy, NodeConfig,
};
use coedge_rag::coordinator::pipeline::{modeled_pipeline_occupancy, PipelineConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder, PipelinedExecutor, SlotReport};
use coedge_rag::llmsim::model::ModelSize;
use coedge_rag::router::capacity::CapacityModel;
use coedge_rag::util::rng::Rng;
use coedge_rag::util::timer::Timer;

const SLOTS: usize = 4;
const DOMAINS: usize = 6;

/// Synthetic N-node cluster grown from the paper testbed's shape:
/// round-robin primary domains, small per-node corpora so the 500-node
/// tier stays index-build-bound on routing rather than on ingest.
fn cluster_cfg(n_nodes: usize, queries_per_slot: usize, allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.seed = 7;
    cfg.qa_per_domain = 40;
    cfg.docs_per_domain = 60;
    cfg.queries_per_slot = queries_per_slot;
    cfg.slots = SLOTS;
    cfg.allocator = allocator;
    // fixed small intra plan: the sweep isolates the scheduling and
    // serving fan-out, not the per-node convex solver
    cfg.intra = IntraStrategy::small_param(1);
    cfg.nodes = (0..n_nodes)
        .map(|i| NodeConfig {
            name: format!("edge-{i:03}"),
            gpu_speeds: vec![1.0],
            pool: vec![ModelSize::Small],
            primary_domains: vec![i % DOMAINS],
            corpus_docs: 24,
            index: IndexSpec::default(),
            cache: CacheSpec::default(),
        })
        .collect();
    cfg
}

fn build(cfg: &ExperimentConfig) -> Coordinator {
    CoordinatorBuilder::new(cfg.clone())
        .capacities(vec![CapacityModel { k: 2.0, b: 0.0 }; cfg.nodes.len()])
        .build()
        .expect("build coordinator")
}

/// Pre-sample the sweep's slot loads outside the coordinator so the sync
/// and pipelined runs consume identical query sequences.
fn sample_slots(cfg: &ExperimentConfig, qa_count: usize) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(cfg.seed ^ 0x5e71);
    (0..cfg.slots)
        .map(|_| (0..cfg.queries_per_slot).map(|_| rng.below(qa_count)).collect())
        .collect()
}

fn run_sync(co: &mut Coordinator, slots: &[Vec<usize>]) -> Vec<SlotReport> {
    slots.iter().map(|qids| co.run_slot(qids).expect("slot")).collect()
}

/// Bitwise comparison of everything modeled the two executors produced —
/// the bench-level version of the golden-replay invariant.
fn assert_bitwise_equal(sync: &[SlotReport], piped: &[SlotReport]) {
    assert_eq!(sync.len(), piped.len());
    for (t, (a, b)) in sync.iter().zip(piped).enumerate() {
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "latency slot {t}");
        assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits(), "drop slot {t}");
        assert_eq!(
            a.mean_scores.rouge_l.to_bits(),
            b.mean_scores.rouge_l.to_bits(),
            "rouge slot {t}"
        );
        let nodes_a: Vec<usize> = a.outcomes.iter().map(|o| o.node).collect();
        let nodes_b: Vec<usize> = b.outcomes.iter().map(|o| o.node).collect();
        assert_eq!(nodes_a, nodes_b, "routing slot {t}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let bench_dir = args
        .iter()
        .position(|a| a == "--bench-dir")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| ".".to_string());
    let bench_dir = std::path::PathBuf::from(bench_dir);

    let tiers: &[usize] = if smoke { &[5, 50] } else { &[5, 50, 500] };
    let loads: &[usize] = if smoke { &[20, 60] } else { &[100, 1000] };
    let allocators = [AllocatorKind::Random, AllocatorKind::Ppo];

    let mut cases: Vec<BenchCase> = Vec::new();
    let mut table = Table::new(&[
        "case", "nodes", "q/slot", "drop %", "lat(s)", "occup", "sync s", "pipe s", "speedup",
    ]);
    for &alloc in &allocators {
        for &n_nodes in tiers {
            for &qps in loads {
                let cfg = cluster_cfg(n_nodes, qps, alloc);
                let mut co = build(&cfg);
                let slots = sample_slots(&cfg, co.ds.qa_pairs.len());

                let t = Timer::start();
                let sync_reports = run_sync(&mut co, &slots);
                let sync_s = t.secs();

                let mut co2 = build(&cfg);
                let pcfg = PipelineConfig { depth: 2, encode_threads: 2 };
                let t = Timer::start();
                let pipe_reports = PipelinedExecutor::new(pcfg)
                    .run(&mut co2, &slots)
                    .expect("pipelined run");
                let pipe_s = t.secs();

                assert_bitwise_equal(&sync_reports, &pipe_reports);

                // modeled comparison surface (deterministic, committed)
                let drop_rate = sync_reports.iter().map(|r| r.drop_rate).sum::<f64>()
                    / sync_reports.len() as f64;
                let latency = sync_reports.iter().map(|r| r.latency_s).sum::<f64>()
                    / sync_reports.len() as f64;
                let slot_queries: Vec<usize> = slots.iter().map(|s| s.len()).collect();
                let serve_s: Vec<f64> =
                    sync_reports.iter().map(|r| r.latency_s).collect();
                let occupancy = modeled_pipeline_occupancy(&slot_queries, &serve_s);

                let name = format!("serve/{}/n{n_nodes}/q{qps}", alloc.as_str());
                let speedup = if pipe_s > 0.0 { sync_s / pipe_s } else { 0.0 };
                table.row(vec![
                    name.clone(),
                    n_nodes.to_string(),
                    qps.to_string(),
                    format!("{:.1}", drop_rate * 100.0),
                    format!("{latency:.3}"),
                    format!("{occupancy:.4}"),
                    format!("{sync_s:.3}"),
                    format!("{pipe_s:.3}"),
                    format!("{speedup:.2}"),
                ]);
                cases.push(
                    BenchCase::new(name)
                        .field("nodes", n_nodes as f64)
                        .field("queries_per_slot", qps as f64)
                        .field("slots", SLOTS as f64)
                        .field("drop_rate", drop_rate)
                        .field("modeled_latency_s", latency)
                        .field("pipeline_occupancy", occupancy)
                        // wall-clock fields below: stripped by CI's
                        // determinism diff per ADR-001
                        .field("sync_wall_s", sync_s)
                        .field("pipe_wall_s", pipe_s)
                        .field("speedup", speedup),
                );
            }
        }
    }
    table.print();
    match write_bench_json(&bench_dir, "serving", &cases) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_serving.json write failed: {e}"),
    }
}
