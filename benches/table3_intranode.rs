//! Reproduces Table III: intra-node scheduling vs Small/Mid/Mixed.1/
//! Mixed.2 fixed deployments across latency SLOs L ∈ {5, 10, 15} s on
//! DomainQA (500 q) and PPC (400 q), reporting all six quality metrics +
//! DropRate.
//!
//!     cargo bench --bench table3_intranode

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, IntraStrategy};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};

fn strategies(gpus: usize) -> Vec<(&'static str, IntraStrategy)> {
    vec![
        ("Small-Param", IntraStrategy::small_param(gpus)),
        ("Mid-Param", IntraStrategy::mid_param(gpus)),
        ("Mixed-Param.1", IntraStrategy::mixed1(gpus)),
        ("Mixed-Param.2", IntraStrategy::mixed2(gpus)),
        ("Intra-node", IntraStrategy::Solver),
    ]
}

fn main() {
    println!("===== Table III — intra-node scheduling vs fixed deployments =====");
    println!("paper highlights: L=5 Mid/Mixed.2 drop 44–67% catastrophically while");
    println!("Small & Intra stay <4%; L=10/15 Intra leads every metric with ~0 drops\n");
    for (ds, name, queries) in [
        (DatasetKind::DomainQa, "DomainQA", 500usize),
        (DatasetKind::Ppc, "PPC", 400usize),
    ] {
        for slo in [5.0, 10.0, 15.0] {
            println!("--- {name}, L = {slo} s ---");
            let mut t = Table::new(&[
                "strategy", "R-1", "R-2", "R-L", "BLEU-4", "METEOR", "BERT", "Drop%",
            ]);
            for (label, strat) in strategies(2) {
                let mut cfg = ExperimentConfig::paper_cluster(ds);
                cfg.allocator = AllocatorKind::Ppo;
                cfg.qa_per_domain = 80;
                cfg.docs_per_domain = 100;
                cfg.queries_per_slot = queries;
                cfg.slo_s = slo;
                cfg.intra = strat;
                for n in cfg.nodes.iter_mut() {
                    n.corpus_docs = 200;
                }
                let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
                let reports = co.run(6).unwrap();
                let m = Coordinator::tail_mean(&reports, 4);
                let drop = reports.iter().rev().take(4).map(|r| r.drop_rate).sum::<f64>() / 4.0;
                t.row(vec![
                    label.into(),
                    format!("{:.3}", m.rouge1),
                    format!("{:.3}", m.rouge2),
                    format!("{:.3}", m.rouge_l),
                    format!("{:.3}", m.bleu4),
                    format!("{:.3}", m.meteor),
                    format!("{:.3}", m.bert_score),
                    format!("{:.2}", drop * 100.0),
                ]);
                eprintln!("{name} L={slo} {label} done");
            }
            t.print();
            println!();
        }
    }
    println!("shape check: Intra-node in the top-2 everywhere; Mid/Mixed.2 collapse at L=5;");
    println!("Small plateaus as L relaxes while Intra keeps improving.");
}
