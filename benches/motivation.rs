//! Reproduces the paper's §II motivation study: Fig. 1 (quality vs
//! allocation strategy), Fig. 2 (latency vs temporal skew), Fig. 3a
//! (model deployments vs latency budget) and Fig. 3b (latency vs memory /
//! query split) on the 3-node motivation testbed.
//!
//!     cargo bench --bench motivation

use coedge_rag::bench_harness::{print_series, Table};
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig, IntraStrategy};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};
use coedge_rag::llmsim::latency::LatencyGroundTruth;
use coedge_rag::llmsim::model::{standard_pool, ModelSize};
use coedge_rag::workload::SkewPattern;

fn motivation_cfg(allocator: AllocatorKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::motivation_cluster();
    cfg.allocator = allocator;
    cfg.qa_per_domain = 120;
    cfg.docs_per_domain = 120;
    cfg.s_iid = 0.4;
    cfg.queries_per_slot = 500;
    cfg.slo_s = 60.0; // generous: isolate quality effects
    cfg
}

/// Fig. 1: generation quality for Random / Domain / Oracle allocation.
fn fig1() {
    println!("\n===== Fig. 1 — generation quality vs allocation strategy =====");
    println!("paper: Random 31.9% lower Rouge-L / 15.4% lower BERTScore than Oracle;");
    println!("       Domain in between (misses cross-domain knowledge)\n");
    let mut t = Table::new(&["strategy", "Rouge-L", "BERTScore", "vs-oracle R-L"]);
    let mut oracle_rl = None;
    let mut rows = Vec::new();
    for (name, kind) in [
        ("Random", AllocatorKind::Random),
        ("Domain", AllocatorKind::Domain),
        ("Oracle", AllocatorKind::Oracle),
    ] {
        let mut co = CoordinatorBuilder::new(motivation_cfg(kind)).build().unwrap();
        let reports = co.run(3).unwrap(); // 3 × 500 = 1500 queries
        let m = Coordinator::tail_mean(&reports, 3);
        if name == "Oracle" {
            oracle_rl = Some(m.rouge_l);
        }
        rows.push((name, m.rouge_l, m.bert_score));
    }
    let orl = oracle_rl.unwrap();
    for (name, rl, bs) in rows {
        t.row(vec![
            name.into(),
            format!("{rl:.3}"),
            format!("{bs:.3}"),
            format!("{:+.1}%", (rl / orl - 1.0) * 100.0),
        ]);
    }
    t.print();
}

/// Fig. 2: end-to-end latency under balanced / moderate / high skew for
/// Domain vs Oracle allocation.
fn fig2() {
    println!("\n===== Fig. 2 — latency vs temporal query skew =====");
    println!("paper: Domain allocation +47.2% (moderate) / +93.7% (high) vs balanced;");
    println!("       Oracle 25.3–33.6% lower latency than Domain under skew\n");
    let skews = [
        ("balanced (500/500/500)", SkewPattern::Balanced),
        ("moderate (750/375/375)", SkewPattern::Primary { domain: 3, frac: 0.5 }),
        ("high (1000/250/250)", SkewPattern::Primary { domain: 3, frac: 2.0 / 3.0 }),
    ];
    let mut t = Table::new(&["skew", "Domain lat(s)", "Oracle lat(s)", "oracle saving"]);
    let mut base: Option<f64> = None;
    for (name, skew) in skews {
        let lat = |kind: AllocatorKind| -> f64 {
            let mut cfg = motivation_cfg(kind);
            cfg.queries_per_slot = 1500;
            cfg.slo_s = 600.0; // §II measures raw end-to-end latency, no hard SLO
            cfg.skew = skew.clone();
            let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
            let reports = co.run(2).unwrap();
            reports.iter().map(|r| r.latency_s).sum::<f64>() / 2.0
        };
        let ld = lat(AllocatorKind::Domain);
        let lo = lat(AllocatorKind::Oracle);
        if base.is_none() {
            base = Some(ld);
        }
        t.row(vec![
            name.into(),
            format!("{ld:.2} ({:+.1}% vs balanced)", (ld / base.unwrap() - 1.0) * 100.0),
            format!("{lo:.2}"),
            format!("{:.1}%", (1.0 - lo / ld) * 100.0),
        ]);
    }
    t.print();
}

/// Fig. 3a: quality of 1B-only / hybrid / 3B-only deployments vs latency
/// budget, 1000 requests on one dual-role node.
fn fig3a() {
    println!("\n===== Fig. 3a — deployments vs latency budget (1000 reqs) =====");
    println!("paper: <50 s the 1B-only wins (no timeouts); >50 s hybrid jumps ahead;");
    println!("       3B needs >70 s to unleash 0.584 Rouge-L\n");
    let budgets = [30.0, 45.0, 60.0, 80.0, 100.0, 120.0]; // extended: our sim 3B is ~1.5x slower than the paper testbed (DESIGN.md §5)
    let mut series: Vec<(&str, Vec<f64>)> = Vec::new();
    for (name, strat) in [
        ("1B-only", IntraStrategy::Fixed(vec![vec![(ModelSize::Small, 1.0)]])),
        (
            "hybrid 50/50",
            IntraStrategy::Fixed(vec![vec![(ModelSize::Small, 0.4), (ModelSize::Mid, 0.6)]]),
        ),
        ("3B-only", IntraStrategy::Fixed(vec![vec![(ModelSize::Mid, 1.0)]])),
    ] {
        let mut ys = Vec::new();
        for &budget in &budgets {
            let mut cfg = motivation_cfg(AllocatorKind::Oracle);
            cfg.nodes.truncate(1);
            cfg.nodes[0].pool = vec![ModelSize::Small, ModelSize::Mid];
            cfg.nodes[0].primary_domains = vec![0, 1, 2, 3, 4, 5];
            cfg.nodes[0].corpus_docs = 400;
            cfg.s_iid = 1.0;
            cfg.queries_per_slot = 1000;
            cfg.slo_s = budget;
            cfg.intra = strat.clone();
            let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
            let reports = co.run(1).unwrap();
            ys.push(reports[0].mean_scores.rouge_l);
        }
        series.push((name, ys));
    }
    print_series("Rouge-L vs latency budget (s)", "budget", &budgets, &series);
}

/// Fig. 3b: latency vs GPU-memory fraction given to the 3B model × query
/// ratio routed to it (fixed 1000 queries, small+mid co-deployed).
fn fig3b() {
    println!("\n===== Fig. 3b — latency vs memory fraction / query ratio =====");
    println!("paper: starving 3B (45–50% mem) while sending it 90% of queries");
    println!("       inflates latency up to +34%; starving 1B (80–83% mem to 3B)");
    println!("       inflates tail latency 28–62% when 1B gets more queries\n");
    let gt = LatencyGroundTruth::default();
    let pool = standard_pool();
    let (small, mid) = (&pool[0], &pool[1]);
    let mem_fracs = [0.45, 0.50, 0.60, 0.70, 0.80, 0.83];
    let ratios = [0.5, 0.6, 0.7, 0.8, 0.9];
    let mut series = Vec::new();
    for &ratio in &ratios {
        let ys: Vec<f64> = mem_fracs
            .iter()
            .map(|&mem3b| {
                let q = 1000.0;
                let l_mid = gt.latency(mid, q * ratio, mem3b);
                let l_small = gt.latency(small, q * (1.0 - ratio), (1.0 - mem3b).max(small.min_mem));
                l_mid.max(l_small)
            })
            .collect();
        series.push((format!("{:.0}% to 3B", ratio * 100.0), ys));
    }
    let named: Vec<(&str, Vec<f64>)> =
        series.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
    print_series(
        "makespan (s) vs memory fraction for the 3B model",
        "mem3b",
        &mem_fracs,
        &named,
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args.iter().find(|a| a.starts_with("--only=")).map(|a| a[7..].to_string());
    let run = |name: &str| only.as_deref().map(|o| o == name).unwrap_or(true);
    if run("fig1") {
        fig1();
    }
    if run("fig2") {
        fig2();
    }
    if run("fig3a") {
        fig3a();
    }
    if run("fig3b") {
        fig3b();
    }
}
