//! Reproduces Table I: held-out RMSE of linear / quadratic / exponential /
//! cubic latency surrogates for the 1B / 3B / 8B models.
//!
//!     cargo bench --bench table1_latfit

use coedge_rag::bench_harness::Table;
use coedge_rag::intranode::latfit::{FitFamily, LatencyProfiler};
use coedge_rag::llmsim::latency::LatencyGroundTruth;
use coedge_rag::llmsim::model::standard_pool;

fn main() {
    println!("===== Table I — RMSE across surrogate families =====");
    println!("paper (s): LLaMA-1B 1.449/1.141/1.130/1.118, 3B 1.183/0.674/0.839/0.936,");
    println!("           8B 2.289/1.033/2.136/2.402  (linear/quad/exp/cubic)");
    println!("paper picks the quadratic (NRMSE 1.87–6%): best accuracy-tractability balance\n");
    // Table-I setting: a realistic profiling budget (coarse burst grid,
    // 6% measurement noise — controlled bursts on a live node are
    // expensive and noisy). The production scheduler uses the denser
    // default grid; here we compare families under the conditions the
    // paper fits in (§IV-C), where the 10-parameter cubic overfits.
    let mut gt = LatencyGroundTruth::default();
    gt.noise_frac = 0.06;
    let prof = LatencyProfiler { q_max: 600.0, q_levels: 7, r_levels: 5, delta_t: 0.05 };
    let mut t = Table::new(&["Model", "Linear", "Quadratic", "Exponential", "Cubic", "NRMSE(quad)"]);
    for (i, m) in standard_pool().iter().enumerate() {
        let res = prof.compare_families(&gt, m, 100 + i as u64);
        let get = |f: FitFamily| res.iter().find(|(x, _)| *x == f).unwrap().1;
        // NRMSE of the quadratic relative to the latency range on a probe grid
        let mut lats = Vec::new();
        for qi in 1..=10 {
            for ri in 0..5 {
                let q = 2400.0 * qi as f64 / 10.0;
                let r = m.min_mem + (1.0 - m.min_mem) * ri as f64 / 4.0;
                lats.push(gt.latency(m, q, r));
            }
        }
        let range = lats.iter().cloned().fold(f64::MIN, f64::max)
            - lats.iter().cloned().fold(f64::MAX, f64::min);
        t.row(vec![
            m.name.clone(),
            format!("{:.3}", get(FitFamily::Linear)),
            format!("{:.3}", get(FitFamily::Quadratic)),
            format!("{:.3}", get(FitFamily::Exponential)),
            format!("{:.3}", get(FitFamily::Cubic)),
            format!("{:.2}%", get(FitFamily::Quadratic) / range * 100.0),
        ]);
    }
    t.print();
    println!("\nshape check: quadratic ≪ linear for every model, and its NRMSE lands in the");
    println!("paper's 1.9–6% band. Deviation: cubic edges out quadratic on our simulator");
    println!("(the synthetic ground truth has q²·r cross terms only the cubic basis spans;");
    println!("the paper's testbed showed cubic overfitting instead). The production solver");
    println!("keeps the paper's choice — the quadratic — since it is the convex surrogate");
    println!("Eq. 13 requires; the cubic is not convexity-safe.");
}
