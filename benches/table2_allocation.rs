//! Reproduces Table II: Random / MAB / PPO / Oracle allocation quality on
//! DomainQA and PPC across ROUGE-1/2/L, BLEU-4, METEOR, BERTScore.
//!
//! The PPO identifier runs through the AOT/PJRT path when artifacts are
//! built (the production three-layer configuration), and needs a warmup
//! phase — the paper's system is likewise trained online before the
//! reported measurement window.
//!
//!     cargo bench --bench table2_allocation

use std::sync::Arc;

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};
use coedge_rag::metrics::QualityScores;
use coedge_rag::policy::ppo::Backend;
use coedge_rag::runtime::PolicyRuntime;

fn backend() -> Backend {
    match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => Backend::Pjrt(Arc::new(rt)),
        Err(_) => Backend::Reference,
    }
}

fn run(dataset: DatasetKind, kind: AllocatorKind) -> QualityScores {
    let mut cfg = ExperimentConfig::paper_cluster(dataset);
    cfg.allocator = kind;
    cfg.qa_per_domain = 100;
    cfg.docs_per_domain = 110;
    cfg.queries_per_slot = if dataset == DatasetKind::DomainQa { 600 } else { 450 };
    cfg.slo_s = 60.0; // quality comparison: latency not binding (paper isolates identification)
    cfg.slots = 16;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 220;
    }
    let be = if kind == AllocatorKind::Ppo { backend() } else { Backend::Reference };
    let mut co = CoordinatorBuilder::new(cfg).backend(be).build().unwrap();
    let slots = if matches!(kind, AllocatorKind::Ppo | AllocatorKind::Mab) { 16 } else { 5 };
    let reports = co.run(slots).unwrap();
    Coordinator::tail_mean(&reports, 4)
}

fn main() {
    println!("===== Table II — query-allocation quality =====");
    println!("paper DomainQA R-L: Random .438 | MAB .531 | PPO .589 | Oracle .609");
    println!("paper PPC      R-L: Random .373 | MAB .471 | PPO .528 | Oracle .541\n");
    for (ds, name) in [(DatasetKind::DomainQa, "DomainQA"), (DatasetKind::Ppc, "PPC")] {
        println!("--- {name} ---");
        let mut t = Table::new(&["alloc", "R-1", "R-2", "R-L", "BLEU-4", "METEOR", "BERTScore"]);
        for (label, kind) in [
            ("Random", AllocatorKind::Random),
            ("MAB", AllocatorKind::Mab),
            ("PPO", AllocatorKind::Ppo),
            ("Oracle", AllocatorKind::Oracle),
        ] {
            let m = run(ds, kind);
            t.row(vec![
                label.into(),
                format!("{:.3}", m.rouge1),
                format!("{:.3}", m.rouge2),
                format!("{:.3}", m.rouge_l),
                format!("{:.3}", m.bleu4),
                format!("{:.3}", m.meteor),
                format!("{:.3}", m.bert_score),
            ]);
            eprintln!("{name}/{label} done");
        }
        t.print();
        println!("shape check: Random < MAB < PPO ≤ Oracle on every metric\n");
    }
}
