//! Reproduces Fig. 6: the intra-node solver's query and GPU-memory
//! proportions per model size across latency SLOs, on both datasets.
//!
//!     cargo bench --bench fig6_proportions

use coedge_rag::bench_harness::Table;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::CoordinatorBuilder;

fn main() {
    println!("===== Fig. 6 — query/resource proportions per model size =====");
    println!("paper: strict L → everything on small; moderate L → mid-heavy (72%/46%");
    println!("queries); relaxed L → most queries (65%/69%) to large models, memory");
    println!("scaling super-proportionally for large models\n");
    for (ds, name, queries) in [
        (DatasetKind::DomainQa, "DomainQA", 500usize),
        (DatasetKind::Ppc, "PPC", 400usize),
    ] {
        println!("--- {name} ---");
        let mut tq = Table::new(&["L (s)", "small q%", "mid q%", "large q%"]);
        let mut tm = Table::new(&["L (s)", "small mem%", "mid mem%", "large mem%"]);
        for slo in [5.0, 10.0, 15.0, 25.0] {
            let mut cfg = ExperimentConfig::paper_cluster(ds);
            cfg.allocator = AllocatorKind::Ppo;
            cfg.qa_per_domain = 80;
            cfg.docs_per_domain = 100;
            cfg.queries_per_slot = queries;
            cfg.slo_s = slo;
            for n in cfg.nodes.iter_mut() {
                n.corpus_docs = 200;
            }
            let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
            let reports = co.run(6).unwrap();
            let mut q = [0.0f64; 3];
            let mut m = [0.0f64; 3];
            let tail = &reports[reports.len() - 3..];
            for r in tail {
                for i in 0..3 {
                    q[i] += r.size_query_share[i] / tail.len() as f64;
                    m[i] += r.size_mem_share[i] / tail.len() as f64;
                }
            }
            tq.row_f(&format!("{slo}"), &[q[0] * 100.0, q[1] * 100.0, q[2] * 100.0], 1);
            tm.row_f(&format!("{slo}"), &[m[0] * 100.0, m[1] * 100.0, m[2] * 100.0], 1);
            eprintln!("{name} L={slo} done");
        }
        println!("query share (%):");
        tq.print();
        println!("memory share (%):");
        tm.print();
        println!();
    }
    println!("shape check: small→mid→large shift as L relaxes, with large models'");
    println!("memory share exceeding their query share (non-linear scaling).");
}
