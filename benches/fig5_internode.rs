//! Reproduces Fig. 5: generation quality vs primary-domain concentration
//! (0.5 → 0.9) with and without inter-node scheduling, on DomainQA
//! (2000 q / 15 s) and PPC (1500 q / 15 s).
//!
//!     cargo bench --bench fig5_internode

use coedge_rag::bench_harness::print_series;
use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
use coedge_rag::coordinator::{Coordinator, CoordinatorBuilder};
use coedge_rag::workload::SkewPattern;

fn build(dataset: DatasetKind, inter: bool) -> Coordinator {
    let mut cfg = ExperimentConfig::paper_cluster(dataset);
    cfg.allocator = AllocatorKind::Ppo;
    cfg.inter_enabled = inter;
    cfg.qa_per_domain = 80;
    cfg.docs_per_domain = 100;
    // little cross-node redundancy: off-primary nodes barely cover a
    // domain, so overload spills genuinely cost quality (paper's setting)
    cfg.s_iid = 0.12;
    cfg.overlap = 0.1;
    // Workload sized so that the nodes holding the skewed domain cannot
    // absorb the concentrated load alone — the regime Fig. 5 studies.
    cfg.queries_per_slot = if dataset == DatasetKind::DomainQa { 2600 } else { 2000 };
    cfg.slo_s = 15.0;
    for n in cfg.nodes.iter_mut() {
        n.corpus_docs = 180;
    }
    let mut co = CoordinatorBuilder::new(cfg).build().unwrap();
    co.cfg.skew = SkewPattern::Balanced;
    co.run(8).unwrap(); // online warmup of the identifier
    // Freeze learning for the measurement sweep: the x-axis must vary only
    // the skew, not the identifier's training progress.
    co.freeze_learning();
    co
}

fn main() {
    println!("===== Fig. 5 — quality vs primary-domain concentration =====");
    println!("paper DomainQA: inter-node R-L .527→.485 vs w/o .474→.416 (frac .5→.9)");
    println!("paper PPC:      inter-node R-L .446→.425 vs w/o .422→.383\n");
    let fracs = [0.5, 0.6, 0.7, 0.8, 0.9];
    for (ds, name) in [(DatasetKind::DomainQa, "DomainQA"), (DatasetKind::Ppc, "PPC")] {
        let mut rl = [Vec::new(), Vec::new()];
        let mut bs = [Vec::new(), Vec::new()];
        let mut dr = [Vec::new(), Vec::new()];
        for (bi, inter) in [true, false].into_iter().enumerate() {
            let mut co = build(ds, inter);
            for &f in &fracs {
                co.cfg.skew = SkewPattern::Primary { domain: 3, frac: f };
                let reports = co.run(2).unwrap();
                let n = reports.len() as f64;
                rl[bi].push(reports.iter().map(|r| r.mean_scores.rouge_l).sum::<f64>() / n);
                bs[bi].push(reports.iter().map(|r| r.mean_scores.bert_score).sum::<f64>() / n);
                dr[bi].push(reports.iter().map(|r| r.drop_rate).sum::<f64>() / n * 100.0);
                eprintln!("{name} inter={inter} frac={f} done");
            }
        }
        print_series(
            &format!("{name}: Rouge-L"),
            "frac",
            &fracs,
            &[("with inter-node", rl[0].clone()), ("w/o inter-node", rl[1].clone())],
        );
        print_series(
            &format!("{name}: BERTScore"),
            "frac",
            &fracs,
            &[("with inter-node", bs[0].clone()), ("w/o inter-node", bs[1].clone())],
        );
        print_series(
            &format!("{name}: drop rate (%)"),
            "frac",
            &fracs,
            &[("with inter-node", dr[0].clone()), ("w/o inter-node", dr[1].clone())],
        );
    }
    println!("\nshape check: quality decreases with skew everywhere; the inter-node");
    println!("curve stays above w/o at every concentration (paper: +12.7%/+8.2% mean R-L).");
}
