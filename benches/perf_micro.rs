//! Performance microbenchmarks for the §Perf pass: every hot path on the
//! request loop, with items/s so regressions are obvious.
//!
//!     cargo bench --bench perf_micro
//!
//! Flags (after `--`):
//! - `--smoke`: run only the quantized-retrieval sweep at reduced tiers
//!   (CI's `retrieval-perf-smoke` double-runs this and byte-diffs the
//!   modeled fields of `BENCH_retrieval.json`; wall-clock fields are
//!   excluded per ADR-001).
//! - `--bench-dir DIR`: directory for the `BENCH_*.json` dumps
//!   (default `.`).

use std::sync::Arc;

use coedge_rag::bench_harness::{bench, write_bench_json, BenchCase, PhaseBreakdown};
use coedge_rag::cache::{
    quantize_embedding, CacheEntry, CachePayload, EntryTag, EvictPolicy, PolicyCache, QueryCache,
};
use coedge_rag::corpus::{build_dataset, domainqa_spec};
use coedge_rag::metrics::Evaluator;
use coedge_rag::policy::mlp;
use coedge_rag::policy::params::{PolicyParams, EMBED_DIM};
use coedge_rag::runtime::{PolicyRuntime, UpdateBatch};
use coedge_rag::text::embed::{l2_normalize, Embedder};
use coedge_rag::util::rng::Rng;
use coedge_rag::vecdb::{
    FlatIndex, Hit, HnswIndex, IvfIndex, QuantizedFlatIndex, ShardedIndex, VectorIndex,
};

/// Random unit vector in the embedding space (shared across sweeps).
fn random_unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..EMBED_DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

/// Stream the seeded corpus for one tier into an index. Each engine in the
/// retrieval sweep re-derives the identical vectors from the same seed, so
/// indexes are built (and dropped) one at a time — peak memory stays at
/// ~one engine even at the 1.2M-chunk tier.
fn fill_index(index: &mut dyn VectorIndex, n: usize, seed: u64) -> f64 {
    let (_, build_s) = coedge_rag::util::timer::timed(|| {
        let mut rng = Rng::new(seed);
        for i in 0..n {
            let v = random_unit(&mut rng);
            index.add(i, &v);
        }
        index.finalize(7);
    });
    build_s
}

/// Recall@k of batched hits against the flat ground-truth id sets.
fn recall_vs(truth: &[Vec<usize>], hits: &[Vec<Hit>]) -> f64 {
    let mut got = 0usize;
    let mut want = 0usize;
    for (hs, t) in hits.iter().zip(truth) {
        want += t.len();
        got += hs.iter().filter(|h| t.contains(&h.id)).count();
    }
    got as f64 / want.max(1) as f64
}

/// Quantized retrieval hot-path sweep: flat vs quantized-flat (exact
/// rescore_factor=4 and approximate rescore_factor=1) vs sharded-quantized,
/// with recall@5 against flat as a modeled (deterministic) field. Full mode
/// tops out at a 1.2M-chunk tier; smoke mode runs the two small tiers.
/// Emits `BENCH_retrieval.json` into `bench_dir`.
fn retrieval_sweep(smoke: bool, bench_dir: &std::path::Path) {
    const K: usize = 5;
    let tiers: &[usize] =
        if smoke { &[1_200, 12_000] } else { &[12_000, 120_000, 1_200_000] };
    let mut cases: Vec<BenchCase> = Vec::new();
    for &n in tiers {
        let iters = if smoke {
            2
        } else if n >= 1_000_000 {
            2
        } else if n >= 100_000 {
            3
        } else {
            10
        };
        let seed = 0xC0ED ^ (n as u64);
        let queries: Vec<Vec<f32>> = {
            let mut qrng = Rng::new(seed ^ 0x51_u64);
            (0..64).map(|_| random_unit(&mut qrng)).collect()
        };

        // flat: the exactness + speed baseline, and the recall ground truth
        let mut flat = FlatIndex::new(EMBED_DIM);
        let build_s = fill_index(&mut flat, n, seed);
        println!("  [{n} chunks] flat ingest {build_s:.1}s");
        let truth: Vec<Vec<usize>> =
            flat.search_batch(&queries, K).iter().map(|hs| hs.iter().map(|h| h.id).collect()).collect();
        let r = bench(&format!("flat               top-{K} {n} chunks x64"), 1, iters, || {
            std::hint::black_box(flat.search_batch(&queries, K));
        });
        println!("{}", r.throughput_line(64.0));
        cases.push(
            BenchCase::new(format!("flat n={n}"))
                .field("corpus", n as f64)
                .field("k", K as f64)
                .field("recall_at5", 1.0)
                .field("items_per_s", 64.0 / r.mean_s)
                .timing(&r),
        );
        drop(flat);

        // quantized-flat at the exact (default) and approximate settings
        for rf in [4usize, 1] {
            let mut quant = QuantizedFlatIndex::new(EMBED_DIM, rf);
            let build_s = fill_index(&mut quant, n, seed);
            println!("  [{n} chunks] quantized rf={rf} ingest {build_s:.1}s");
            let recall = recall_vs(&truth, &quant.search_batch(&queries, K));
            let r = bench(&format!("quantized rf={rf}     top-{K} {n} chunks x64"), 1, iters, || {
                std::hint::black_box(quant.search_batch(&queries, K));
            });
            println!("{}  (recall@{K} {recall:.3})", r.throughput_line(64.0));
            cases.push(
                BenchCase::new(format!("quantized rf={rf} n={n}"))
                    .field("corpus", n as f64)
                    .field("k", K as f64)
                    .field("rescore_factor", rf as f64)
                    .field("recall_at5", recall)
                    .field("items_per_s", 64.0 / r.mean_s)
                    .timing(&r),
            );
        }

        // sharded-quantized: 8 shards of the exact engine, batched fan-out
        let mut sharded = ShardedIndex::from_fn(8, |_| QuantizedFlatIndex::new(EMBED_DIM, 4));
        let build_s = fill_index(&mut sharded, n, seed);
        println!("  [{n} chunks] sharded-quantized8 ingest {build_s:.1}s");
        let recall = recall_vs(&truth, &sharded.search_batch(&queries, K));
        let r = bench(&format!("sharded-quantized8 top-{K} {n} chunks x64"), 1, iters, || {
            std::hint::black_box(sharded.search_batch(&queries, K));
        });
        println!("{}  (recall@{K} {recall:.3})", r.throughput_line(64.0));
        cases.push(
            BenchCase::new(format!("sharded-quantized8 n={n}"))
                .field("corpus", n as f64)
                .field("k", K as f64)
                .field("rescore_factor", 4.0)
                .field("shards", 8.0)
                .field("recall_at5", recall)
                .field("items_per_s", 64.0 / r.mean_s)
                .timing(&r),
        );
    }
    match write_bench_json(bench_dir, "retrieval", &cases) {
        Ok(path) => println!("  retrieval sweep written to {}", path.display()),
        Err(e) => println!("  (BENCH_retrieval.json not written: {e})"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let bench_dir = args
        .iter()
        .position(|a| a == "--bench-dir")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| ".".to_string());
    let bench_dir = std::path::PathBuf::from(bench_dir);

    // --- quantized retrieval hot path ---
    retrieval_sweep(smoke, &bench_dir);
    if smoke {
        return;
    }

    let mut rng = Rng::new(1);
    let embedder = Embedder::default();
    let ds = build_dataset(&domainqa_spec(60, 200), 3);

    // --- embedding ---
    let texts: Vec<String> = ds.qa_pairs.iter().take(256).map(|q| q.query.clone()).collect();
    let r = bench("embed 256 queries", 3, 20, || {
        for t in &texts {
            std::hint::black_box(embedder.embed(t));
        }
    });
    println!("{}", r.throughput_line(256.0));

    // --- vector search: corpus-size sweep over index kinds ---
    // 1.2k / 12k / 120k-chunk tiers × {flat, flat-batched, ivf, hnsw,
    // sharded-flat}: quantifies the IVF crossover claimed in vecdb/ivf.rs
    // and the sharded batched speedup over single-threaded flat at the
    // 120k tier. Per-query items/s on every line.
    let queries: Vec<Vec<f32>> = (0..64).map(|_| random_unit(&mut rng)).collect();
    for &n in &[1_200usize, 12_000, 120_000] {
        let iters = if n >= 100_000 { 3 } else { 10 };
        let nlist = ((n as f64).sqrt() as usize).max(8);
        let nprobe = (nlist / 10).max(1);
        let mut flat = FlatIndex::new(EMBED_DIM);
        let mut ivf = IvfIndex::new(EMBED_DIM, nlist, nprobe);
        let mut hnsw = HnswIndex::new(EMBED_DIM, 16, 64, 48, 11);
        let mut sharded = ShardedIndex::from_fn(8, |_| FlatIndex::new(EMBED_DIM));
        let (_, build_s) = coedge_rag::util::timer::timed(|| {
            for i in 0..n {
                let v = random_unit(&mut rng);
                flat.add(i, &v);
                ivf.add(i, &v);
                hnsw.add(i, &v);
                sharded.add(i, &v);
            }
            ivf.finalize(7);
        });
        println!("  [{n} chunks] ingest+train {build_s:.1}s (ivf nlist={nlist} nprobe={nprobe})");
        let r = bench(&format!("flat          top-5 {n} chunks x64"), 1, iters, || {
            for q in &queries {
                std::hint::black_box(flat.search(q, 5));
            }
        });
        println!("{}", r.throughput_line(64.0));
        let r = bench(&format!("flat batched  top-5 {n} chunks x64"), 1, iters, || {
            std::hint::black_box(flat.search_batch(&queries, 5));
        });
        println!("{}", r.throughput_line(64.0));
        let r = bench(&format!("ivf           top-5 {n} chunks x64"), 1, iters, || {
            for q in &queries {
                std::hint::black_box(ivf.search(q, 5));
            }
        });
        println!("{}", r.throughput_line(64.0));
        let r = bench(&format!("hnsw          top-5 {n} chunks x64"), 1, iters, || {
            for q in &queries {
                std::hint::black_box(hnsw.search(q, 5));
            }
        });
        println!("{}", r.throughput_line(64.0));
        let r = bench(&format!("sharded-flat8 top-5 {n} chunks x64"), 1, iters, || {
            std::hint::black_box(sharded.search_batch(&queries, 5));
        });
        println!("{}", r.throughput_line(64.0));
    }

    // --- retrieval cache: hit-rate × corpus-size grid ---
    // Streams of 256 queries where `repeat` of the stream re-asks one of
    // 8 hot queries: quantifies what an LRU retrieval cache buys at each
    // corpus tier, and how the win scales with the repeat rate. Results
    // also land in BENCH_cache.json (machine-readable perf trajectory).
    let mut cache_cases: Vec<BenchCase> = Vec::new();
    for &n in &[1_200usize, 12_000] {
        let iters = 10;
        let mut index = FlatIndex::new(EMBED_DIM);
        for i in 0..n {
            let v = random_unit(&mut rng);
            index.add(i, &v);
        }
        for &repeat in &[0.0f64, 0.5, 0.9] {
            let hot: Vec<Vec<f32>> = (0..8).map(|_| random_unit(&mut rng)).collect();
            let stream: Vec<Vec<f32>> = (0..256)
                .map(|_| {
                    if rng.chance(repeat) {
                        hot[rng.below(hot.len())].clone()
                    } else {
                        random_unit(&mut rng)
                    }
                })
                .collect();
            let keys: Vec<Vec<i8>> = stream.iter().map(|q| quantize_embedding(q)).collect();

            let r0 = bench(&format!("cache off  top-5 {n} chunks rep={repeat}"), 1, iters, || {
                for q in &stream {
                    std::hint::black_box(index.search(q, 5));
                }
            });
            println!("{}", r0.throughput_line(256.0));

            // each timed pass starts from a COLD cache, so misses really
            // search and the timing scales with the repeat rate (a warm
            // persistent cache would hit 100% at every repeat level and
            // measure nothing but map lookups)
            let mut hits = 0usize;
            let mut lookups = 0usize;
            let r1 = bench(&format!("cache lru  top-5 {n} chunks rep={repeat}"), 1, iters, || {
                let mut cache = PolicyCache::new(EvictPolicy::Lru, 64 * 1024 * 1024);
                for (q, key) in stream.iter().zip(&keys) {
                    lookups += 1;
                    if cache.get(key).is_some() {
                        hits += 1;
                        continue;
                    }
                    let found = index.search(q, 5);
                    cache.insert(
                        key.clone(),
                        CacheEntry {
                            tag: EntryTag { node: 0, domain: 0 },
                            guard: 0,
                            payload: CachePayload::Hits(found),
                        },
                    );
                }
            });
            let hit_rate = hits as f64 / lookups.max(1) as f64;
            println!("{}  (hit rate {:.2})", r1.throughput_line(256.0), hit_rate);

            cache_cases.push(
                BenchCase::new(format!("off n={n} rep={repeat}"))
                    .field("corpus", n as f64)
                    .field("repeat_frac", repeat)
                    .field("hit_rate", 0.0)
                    .field("items_per_s", 256.0 / r0.mean_s)
                    .timing(&r0),
            );
            cache_cases.push(
                BenchCase::new(format!("lru n={n} rep={repeat}"))
                    .field("corpus", n as f64)
                    .field("repeat_frac", repeat)
                    .field("hit_rate", hit_rate)
                    .field("items_per_s", 256.0 / r1.mean_s)
                    .timing(&r1),
            );
        }
    }
    match write_bench_json(&bench_dir, "cache", &cache_cases) {
        Ok(path) => println!("  cache sweep written to {}", path.display()),
        Err(e) => println!("  (BENCH_cache.json not written: {e})"),
    }

    // --- metrics suite ---
    let ev = Evaluator::default();
    let pairs: Vec<(Vec<String>, Vec<String>)> = ds
        .qa_pairs
        .iter()
        .take(128)
        .map(|qa| (qa.answer_tokens.clone(), ds.qa_pairs[(qa.id + 7) % ds.qa_pairs.len()].answer_tokens.clone()))
        .collect();
    let r = bench("full metric suite x128 pairs", 2, 15, || {
        for (g, rf) in &pairs {
            std::hint::black_box(ev.score_tokens(g, rf));
        }
    });
    println!("{}", r.throughput_line(128.0));
    let r = bench("feedback (LCS+BERT) x128 pairs", 2, 15, || {
        for (g, rf) in &pairs {
            std::hint::black_box(ev.feedback(g, rf, 1.0, 0.5));
        }
    });
    println!("{}", r.throughput_line(128.0));

    // --- policy forward: rust vs PJRT ---
    let params = PolicyParams::init(4, 5);
    let x: Vec<f32> = (0..64 * EMBED_DIM).map(|_| rng.normal() as f32 * 0.3).collect();
    let r = bench("rust mlp fwd b=64", 3, 30, || {
        std::hint::black_box(mlp::forward(&params, &x, 64));
    });
    println!("{}", r.throughput_line(64.0));
    if let Ok(rt) = PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        let rt = Arc::new(rt);
        let r = bench("pjrt policy fwd b=64", 3, 30, || {
            std::hint::black_box(rt.forward(&params, &x, 64).unwrap());
        });
        println!("{}", r.throughput_line(64.0));
        // ppo update (b=256)
        let xb: Vec<f32> = (0..256 * EMBED_DIM).map(|_| rng.normal() as f32 * 0.3).collect();
        let probs = mlp::forward(&params, &xb, 256);
        let mut batch = UpdateBatch::default();
        batch.x = xb;
        for i in 0..256 {
            let a = i % 4;
            batch.actions.push(a);
            batch.old_logp.push(probs[i * 4 + a].max(1e-12).ln());
            batch.rewards.push(if a == 0 { 1.0 } else { -0.3 });
        }
        let mut p2 = params.clone();
        let r = bench("pjrt ppo update b=256", 2, 15, || {
            std::hint::black_box(rt.update(&mut p2, &batch).unwrap());
        });
        println!("{}  ({:.1} ms / 1000 queries; paper: 30 ms)", r.throughput_line(256.0), r.mean_s * 1e3 / 256.0 * 1000.0);
        let mut p3 = params.clone();
        let r = bench("rust ppo update b=256", 2, 15, || {
            std::hint::black_box(coedge_rag::policy::grad::update_host(&mut p3, &batch));
        });
        println!("{}", r.throughput_line(256.0));
    } else {
        println!("(pjrt benches skipped: run `make artifacts`)");
    }

    // --- intra-node solver ---
    use coedge_rag::intranode::latfit::LatencyProfiler;
    use coedge_rag::intranode::solver::{solve_node, SolverInput};
    use coedge_rag::llmsim::gpu::GpuState;
    use coedge_rag::llmsim::latency::LatencyGroundTruth;
    use coedge_rag::llmsim::model::standard_pool;
    let pool = standard_pool();
    let gt = LatencyGroundTruth::default();
    let prof = LatencyProfiler::default();
    let fits: Vec<Vec<_>> = pool
        .iter()
        .map(|m| (0..2).map(|g| prof.fit_production(&gt, m, 3 + g as u64)).collect())
        .collect();
    let gpus = vec![GpuState::new(1.0), GpuState::new(1.1)];
    let quality = vec![1.2, 1.37, 1.5];
    let r = bench("intra-node solve (2 GPUs, 3 models)", 3, 30, || {
        std::hint::black_box(solve_node(&SolverInput {
            pool: &pool,
            gpus: &gpus,
            fits: &fits,
            quality: &quality,
            queries: 500,
            budget_s: 12.0,
            mem_cap: 1.0,
        }));
    });
    println!("{}", r.throughput_line(1.0));

    // --- end-to-end slot ---
    use coedge_rag::config::{AllocatorKind, DatasetKind, ExperimentConfig};
    use coedge_rag::coordinator::CoordinatorBuilder;
    use coedge_rag::policy::ppo::Backend;
    let mut cfg = ExperimentConfig::paper_cluster(DatasetKind::DomainQa);
    cfg.qa_per_domain = 60;
    cfg.docs_per_domain = 80;
    cfg.queries_per_slot = 1000;
    cfg.allocator = AllocatorKind::Ppo;
    // production path: PJRT backend when artifacts exist
    let be = match PolicyRuntime::load(&PolicyRuntime::default_dir()) {
        Ok(rt) => Backend::Pjrt(Arc::new(rt)),
        Err(_) => Backend::Reference,
    };
    // live per-phase accounting through the SlotObserver hook
    let phases = PhaseBreakdown::new();
    let mut co = CoordinatorBuilder::new(cfg)
        .backend(be)
        .observer(Box::new(phases.clone()))
        .build()
        .unwrap();
    let r = bench("e2e slot (1000 queries, 4 nodes)", 1, 8, || {
        let qids = co.sample_queries(1000).unwrap();
        std::hint::black_box(co.run_slot(&qids).unwrap());
    });
    println!("{}", r.throughput_line(1000.0));
    phases.print();
}
