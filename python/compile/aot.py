"""AOT compilation: lower the Layer-2 JAX graphs to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (per node count N in --nodes, default 3,4,6):
    artifacts/policy_fwd_n{N}_b{B}.hlo.txt     (B = 1 and 64)
    artifacts/ppo_update_n{N}_b{B}.hlo.txt     (B = 256)
    artifacts/manifest.json                    (shapes + hyperparams)

Python runs ONLY here (``make artifacts``); the Rust coordinator loads
these artifacts at startup and executes them via PJRT on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

FWD_BATCHES = (1, 64)
UPD_BATCH = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_policy_fwd(n_actions: int, batch: int) -> str:
    params = [_spec(s) for s in model.param_shapes(n_actions)]
    x = _spec((batch, model.EMBED_DIM))
    lowered = jax.jit(model.policy_fwd).lower(params, x)
    return to_hlo_text(lowered)


def lower_ppo_update(n_actions: int, batch: int) -> str:
    params = [_spec(s) for s in model.param_shapes(n_actions)]
    adam_m = [_spec(s) for s in model.param_shapes(n_actions)]
    adam_v = [_spec(s) for s in model.param_shapes(n_actions)]
    step = _spec(())
    x = _spec((batch, model.EMBED_DIM))
    onehot = _spec((batch, n_actions))
    reward = _spec((batch,))
    old_logp = _spec((batch,))
    mask = _spec((batch,))
    lowered = jax.jit(model.ppo_update).lower(
        params, adam_m, adam_v, step, x, onehot, reward, old_logp, mask
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--nodes", default="3,4,6",
                    help="comma-separated node counts to compile for")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    node_counts = [int(s) for s in args.nodes.split(",") if s]

    manifest = {
        "embed_dim": model.EMBED_DIM,
        "hidden": list(model.HIDDEN),
        "param_names": list(model.PARAM_NAMES),
        "hyperparams": {
            "learning_rate": model.LEARNING_RATE,
            "clip_eps": model.CLIP_EPS,
            "entropy_beta": model.ENTROPY_BETA,
            "adam_b1": model.ADAM_B1,
            "adam_b2": model.ADAM_B2,
            "adam_eps": model.ADAM_EPS,
            "ln_eps": model.LN_EPS,
        },
        "artifacts": [],
    }

    for n in node_counts:
        shapes = [list(s) for s in model.param_shapes(n)]
        for b in FWD_BATCHES:
            name = f"policy_fwd_n{n}_b{b}"
            text = lower_policy_fwd(n, b)
            path = os.path.join(args.out, name + ".hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": name, "kind": "policy_fwd", "n_actions": n,
                "batch": b, "file": name + ".hlo.txt",
                "param_shapes": shapes,
            })
            print(f"wrote {path} ({len(text)} chars)")
        name = f"ppo_update_n{n}_b{UPD_BATCH}"
        text = lower_ppo_update(n, UPD_BATCH)
        path = os.path.join(args.out, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": "ppo_update", "n_actions": n,
            "batch": UPD_BATCH, "file": name + ".hlo.txt",
            "param_shapes": shapes,
        })
        print(f"wrote {path} ({len(text)} chars)")

    # manifest written LAST: it is the Makefile's freshness sentinel.
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
