"""Pallas kernels: fused dense+bias+ReLU, layer norm, row softmax.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's policy
network runs on an RTX 4090; here the kernels are written TPU-style —
BlockSpec tiles sized for VMEM, matmuls shaped for the 128×128 MXU
(block sizes are multiples of 128 where the model dims allow), and the
HBM↔VMEM schedule expressed through the grid/BlockSpec instead of CUDA
threadblocks. On CPU we execute under ``interpret=True`` for correctness;
TPU perf is estimated analytically in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: MXU-friendly where possible. The policy net is small
# (256-256-128-64-N), so K is never tiled — a full K-slab of activations
# plus a (K × BLOCK_N) weight tile fits comfortably in VMEM
# (256×256 fp32 = 256 KiB « 16 MiB).
BLOCK_B = 128
BLOCK_N = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (BLOCK_B × BLOCK_N) output tile: o = act(x @ w + b)."""
    x = x_ref[...]  # (bb, K)
    w = w_ref[...]  # (K, bn)
    b = b_ref[...]  # (1, bn)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def dense(x, w, b, relu: bool = True):
    """Fused ``act(x @ w + b)`` via a Pallas grid over (batch, out) tiles.

    x: (B, K), w: (K, N), b: (N,) -> (B, N).
    Works for any B, N (grid cells are ceil-divided; Pallas pads/masks the
    ragged edge tiles).
    """
    B, K = x.shape
    K2, N = w.shape
    assert K == K2, f"inner dims mismatch: {K} vs {K2}"
    assert b.shape == (N,)
    bb = min(BLOCK_B, B)
    bn = min(BLOCK_N, N)
    grid = (pl.cdiv(B, bb), pl.cdiv(N, bn))
    return pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=True,
    )(x, w, b.reshape(1, N))


def _layer_norm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]  # (bb, D)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layer norm with affine params. x: (B, D)."""
    B, D = x.shape
    assert gamma.shape == (D,) and beta.shape == (D,)
    bb = min(BLOCK_B, B)
    return pl.pallas_call(
        functools.partial(_layer_norm_kernel, eps=eps),
        grid=(pl.cdiv(B, bb),),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=True,
    )(x, gamma.reshape(1, D), beta.reshape(1, D))


def _row_softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def row_softmax(x):
    """Numerically-stable row softmax. x: (B, N)."""
    B, N = x.shape
    bb = min(BLOCK_B, B)
    return pl.pallas_call(
        _row_softmax_kernel,
        grid=(pl.cdiv(B, bb),),
        in_specs=[pl.BlockSpec((bb, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=True,
    )(x)
