"""Layer-1 Pallas kernels for the CoEdge-RAG policy network.

All kernels run with ``interpret=True``: the CPU PJRT backend cannot
execute Mosaic custom-calls, and interpret mode lowers the kernels to plain
HLO ops that round-trip through the HLO-text AOT path (see aot.py).
"""

from .policy_mlp import dense, layer_norm, row_softmax  # noqa: F401
