"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in policy_mlp.py must match these references to
float32 tolerance across shapes — enforced by python/tests/test_kernels.py
(hypothesis sweeps) and reused by the Layer-2 PPO update graph, which
differentiates through this jnp path (identical math to the kernels).
"""

import jax
import jax.numpy as jnp


def dense_ref(x, w, b, relu: bool = True):
    """act(x @ w + b) — reference for kernels.dense."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.reshape(1, -1)
    return jnp.maximum(y, 0.0) if relu else y


def layer_norm_ref(x, gamma, beta, eps: float = 1e-5):
    """Row-wise layer norm — reference for kernels.layer_norm."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * gamma.reshape(1, -1) + beta.reshape(1, -1)


def row_softmax_ref(x):
    """Numerically-stable row softmax — reference for kernels.row_softmax."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
